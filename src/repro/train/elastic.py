"""Elastic scaling: resume a run on a different mesh.

Checkpoints store full logical arrays (mesh-agnostic), so elasticity is:
build the new mesh, derive shardings from the *same* logical-axis rules, and
``device_put`` on restore.  A lost pod therefore costs one restore, not a
re-run: resume on ``(pods-1, data, model)`` — the `pod` axis is pure DP, so
the optimizer state stays valid (batch size drops; the schedule can be
re-scaled by the caller).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import DEFAULT_RULES
from repro.models.model import LM
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import init_state, state_shardings


def reshard_state(state, model: LM, new_mesh: Mesh, rules=DEFAULT_RULES):
    """Re-place an in-memory state onto a new mesh."""
    sh = state_shardings(model, state, new_mesh, rules)
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s), state, sh)


def restore_elastic(ckpt_dir: str, model: LM, run_cfg, new_mesh: Mesh,
                    key, rules=DEFAULT_RULES, step=None):
    """Restore the newest checkpoint directly onto `new_mesh`."""
    mgr = CheckpointManager(ckpt_dir, keep=run_cfg.keep_checkpoints)
    like = jax.eval_shape(lambda: init_state(model, key, run_cfg))
    sh = state_shardings(model, like, new_mesh, rules)
    state, extra = mgr.restore(like=like, step=step, shardings=sh)
    return state, extra
