"""Fault-tolerant, mesh-agnostic checkpointing.

Format: one zstd-compressed msgpack blob per checkpoint holding every leaf as
(dtype, shape, raw bytes) keyed by its tree path, plus a manifest with
blake2b digests for integrity.  Writes are atomic (tmp + rename); restores
skip corrupted/partial checkpoints and fall back to the previous step —
that's the node-failure story: a killed writer never poisons the run.

Mesh-agnostic: leaves are stored as *full logical arrays*; ``load_pytree``
re-shards to whatever mesh/sharding the restoring job passes (elastic
restart on a different topology).
"""
from __future__ import annotations

import hashlib
import os
import re
import threading
import time
from typing import Any, Optional

import jax
import msgpack
import numpy as np

try:
    import zstandard
    _ZC = zstandard.ZstdCompressor(level=3)
    _ZD = zstandard.ZstdDecompressor()
    _DECOMP_ERROR: type[Exception] = zstandard.ZstdError
except ModuleNotFoundError:          # hermetic env: stdlib zlib, same API
    import zlib

    class _ZlibCodec:
        @staticmethod
        def compress(b: bytes) -> bytes:
            return zlib.compress(b, 3)

        @staticmethod
        def decompress(b: bytes) -> bytes:
            return zlib.decompress(b)

    _ZC = _ZD = _ZlibCodec()         # type: ignore[assignment]
    _DECOMP_ERROR = zlib.error


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def _to_host(x) -> np.ndarray:
    if isinstance(x, jax.Array):
        return np.asarray(jax.device_get(x))
    return np.asarray(x)


def save_pytree(path: str, tree: Any, extra: dict | None = None) -> str:
    """Atomic single-file checkpoint of an arbitrary array pytree."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {}
    digests = {}
    for p, leaf in flat:
        k = _path_str(p)
        a = _to_host(leaf)
        raw = a.tobytes()
        payload[k] = {"dtype": str(a.dtype), "shape": list(a.shape),
                      "data": raw}
        digests[k] = hashlib.blake2b(raw, digest_size=16).hexdigest()
    blob = msgpack.packb({"leaves": payload,
                          "manifest": {"digests": digests,
                                       "extra": extra or {},
                                       "time": time.time()}},
                         use_bin_type=True)
    comp = _ZC.compress(blob)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)                      # atomic commit
    return path


def load_pytree(path: str, like: Any = None, shardings: Any = None,
                verify: bool = True) -> Any:
    """Restore a checkpoint.  ``like`` rebuilds the exact pytree structure;
    ``shardings`` (a matching pytree of NamedSharding) re-shards on load."""
    with open(path, "rb") as f:
        blob = _ZD.decompress(f.read())
    obj = msgpack.unpackb(blob, raw=False)
    leaves, digests = obj["leaves"], obj["manifest"]["digests"]
    if verify:
        for k, v in leaves.items():
            got = hashlib.blake2b(v["data"], digest_size=16).hexdigest()
            if got != digests[k]:
                raise IOError(f"checkpoint {path}: digest mismatch at {k}")
    arrays = {k: np.frombuffer(v["data"], dtype=v["dtype"])
              .reshape(v["shape"]) for k, v in leaves.items()}
    if like is None:
        return arrays
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    for (p, leaf), sh in zip(flat, shard_flat):
        k = _path_str(p)
        if k not in arrays:
            raise KeyError(f"checkpoint {path} missing leaf {k}")
        a = arrays[k]
        want = np.dtype(leaf.dtype) if hasattr(leaf, "dtype") else a.dtype
        a = a.astype(want, copy=False)
        out.append(jax.device_put(a, sh) if sh is not None else jnp_like(a))
    return jax.tree_util.tree_unflatten(treedef, out)


def jnp_like(a: np.ndarray):
    import jax.numpy as jnp
    return jnp.asarray(a)


def checkpoint_extra(path: str) -> dict:
    with open(path, "rb") as f:
        blob = _ZD.decompress(f.read())
    return msgpack.unpackb(blob, raw=False)["manifest"]["extra"]


class CheckpointManager:
    """Step-numbered checkpoints with retention, async save, auto-resume."""

    STEP_RE = re.compile(r"step_(\d+)\.ckpt$")

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.ckpt")

    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            m = self.STEP_RE.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.all_steps()
        return s[-1] if s else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state: Any, extra: dict | None = None):
        """Host-offload synchronously, write (a)synchronously, prune."""
        self.wait()
        host = jax.tree.map(_to_host, state)

        def _write():
            save_pytree(self._path(step), host, extra={"step": step,
                                                       **(extra or {})})
            self._prune()
        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore `step` (or the newest *valid* checkpoint).  Corrupted or
        partial files are skipped — crash-during-save never bricks a run."""
        self.wait()
        candidates = ([step] if step is not None
                      else list(reversed(self.all_steps())))
        last_err: Exception | None = None
        for s in candidates:
            try:
                tree = load_pytree(self._path(s), like=like,
                                   shardings=shardings)
                extra = checkpoint_extra(self._path(s))
                return tree, extra
            except (IOError, KeyError, ValueError,
                    msgpack.UnpackException, _DECOMP_ERROR) as e:
                last_err = e
                continue
        raise FileNotFoundError(
            f"no valid checkpoint in {self.dir}: {last_err}")
