"""Training loop substrate: loss, train_step factory, Trainer orchestration.

Production features:
  * microbatch gradient accumulation (``lax.scan``; constant HLO size)
  * remat (activation checkpointing) through the model's scanned blocks
  * chunked cross-entropy — never materializes (B, S, V) f32 logits for the
    150k-vocab archs; the head matmul is recomputed per chunk on backward
  * optional int8 error-feedback gradient compression across the `pod`
    (DCN) axis via partial shard_map — see distributed/compression.py
  * mixed precision: f32 master params, bf16 activations (model casts at use)
  * fault tolerance: CheckpointManager auto-resume, data cursor in the
    checkpoint, deterministic RNG per step
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.distributed.compat import shard_map
from repro.distributed.sharding import (
    DEFAULT_RULES, ShardingRules, shard_params_tree)
from repro.models.model import LM
from repro.train.optimizer import adamw_init, adamw_update, make_schedule
from repro.train.checkpoint import CheckpointManager


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """logits (..., V) f32, labels (...) int32; mean over unmasked."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_lm_loss(hidden, head_w, labels, mask, chunk: int = 1024):
    """CE over the vocab without materializing full logits.

    hidden: (B, S, D); head_w: (D, V); labels/mask: (B, S).
    The per-chunk head matmul + logsumexp is rematerialized on backward.
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk != 0:            # fall back: irregular lengths (tests)
        logits = (hidden @ head_w.astype(hidden.dtype)).astype(jnp.float32)
        return softmax_xent(logits, labels, mask)
    n = S // chunk

    @jax.checkpoint
    def one(h, y, m):
        logits = (h @ head_w.astype(h.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        m = m.astype(jnp.float32)
        return jnp.sum((logz - gold) * m), jnp.sum(m)

    def body(carry, xs):
        tot, cnt = carry
        h, y, m = xs
        s, c = one(h, y, m)
        return (tot + s, cnt + c), None

    xs = (hidden.reshape(B, n, chunk, D).swapaxes(0, 1),
          labels.reshape(B, n, chunk).swapaxes(0, 1),
          mask.reshape(B, n, chunk).swapaxes(0, 1))
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), xs)
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss_fn(model: LM, params, batch, run_cfg: RunConfig,
               chunked: bool | None = None):
    """Next-token loss for every family; handles the VLM patch prefix."""
    cfg = model.cfg
    tokens = batch["tokens"]
    patch = batch.get("patch_embeds")
    remat = run_cfg.parallel.remat != "none"
    labels = tokens[:, 1:]
    if chunked is None:
        chunked = cfg.vocab_size >= 32_000
    n_patch = (cfg.frontend.num_positions
               if cfg.frontend.kind == "vision_patches" else 0)
    hidden, aux = model.hidden(params, tokens, patch, remat=remat)
    # predict token t+1 from hidden at (n_patch + t)
    h = hidden[:, n_patch:-1]
    mask = jnp.ones_like(labels, jnp.float32)
    head_w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    if chunked:
        ce = chunked_lm_loss(h, head_w, labels, mask)
    else:
        logits = (h @ head_w.astype(h.dtype)).astype(jnp.float32)
        ce = softmax_xent(logits, labels, mask)
    moe_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    return ce + moe_w * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# train step factory
# ---------------------------------------------------------------------------

def init_state(model: LM, key, run_cfg: RunConfig) -> dict:
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if run_cfg.parallel.grad_compression == "int8_ef":
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def state_shardings(model: LM, state, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES):
    logical = model.logical()
    p_sh = shard_params_tree(mesh, state["params"], logical, rules)
    out = {"params": p_sh,
           "opt": {"m": shard_params_tree(mesh, state["opt"]["m"], logical,
                                          rules),
                   "v": shard_params_tree(mesh, state["opt"]["v"], logical,
                                          rules),
                   "count": NamedSharding(mesh, P())},
           "step": NamedSharding(mesh, P())}
    if "ef" in state:
        out["ef"] = shard_params_tree(mesh, state["ef"], logical, rules)
    return out


def make_train_step(model: LM, run_cfg: RunConfig,
                    mesh: Mesh | None = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics), jit-ready."""
    pcfg = run_cfg.parallel
    ocfg = run_cfg.optimizer
    sched = make_schedule(ocfg)
    compress = (pcfg.grad_compression == "int8_ef" and mesh is not None
                and "pod" in mesh.shape and mesh.shape["pod"] > 1)

    def loss_fn(params, mb):
        # Mixed precision: cast the f32 master params to bf16 on their
        # *shards*, before XLA's FSDP all-gather — halves param-gather
        # wire bytes vs gathering f32 and casting at use (the model's
        # per-use astype then becomes a no-op).
        if pcfg.cast_bf16:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
        return lm_loss_fn(model, params, mb, run_cfg)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accum_grads(params, batch):
        """Microbatched accumulation with a scan (constant HLO size)."""
        A = pcfg.microbatches
        if A <= 1:
            (loss, m), grads = grad_fn(params, batch)
            return loss, m, grads
        def split(x):
            return x.reshape((A, x.shape[0] // A) + x.shape[1:])
        mbs = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

        def body(carry, mb):
            acc, ltot = carry
            (loss, m), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / A,
                               acc, grads)
            return (acc, ltot + loss / A), m
        (grads, loss), ms = jax.lax.scan(body, (zero, 0.0), mbs)
        m = jax.tree.map(lambda x: x[-1], ms)
        return loss, m, grads

    if not compress:
        def train_step(state, batch):
            loss, m, grads = accum_grads(state["params"], batch)
            new_p, new_opt, om = adamw_update(grads, state["opt"],
                                              state["params"], ocfg, sched)
            out = {"params": new_p, "opt": new_opt,
                   "step": state["step"] + 1}
            if "ef" in state:
                out["ef"] = state["ef"]
            return out, {"loss": loss, **m, **om}
        return train_step

    # ---- int8 error-feedback compression across the pod (DCN) axis -------
    from repro.distributed.compression import compressed_psum_mean

    def train_step(state, batch):
        def per_pod(params, batch, ef):
            loss, m, grads = accum_grads(params, batch)
            grads, ef = compressed_psum_mean(grads, "pod", ef)
            loss = jax.lax.pmean(loss, "pod")
            return loss, m, grads, ef

        wrapped = shard_map(
            per_pod, mesh=mesh,
            in_specs=(P(), P("pod"), P()),
            out_specs=(P(), P(), P(), P()),
            axis_names={"pod"}, check_vma=False)
        loss, m, grads, ef = wrapped(state["params"], batch, state["ef"])
        new_p, new_opt, om = adamw_update(grads, state["opt"],
                                          state["params"], ocfg, sched)
        return ({"params": new_p, "opt": new_opt, "ef": ef,
                 "step": state["step"] + 1},
                {"loss": loss, **jax.tree.map(lambda x: x, m), **om})
    return train_step


# ---------------------------------------------------------------------------
# Trainer orchestration (checkpoint/restart, logging, stragglers)
# ---------------------------------------------------------------------------

@dataclass
class TrainState:
    """Thin holder for the live state dict + bookkeeping."""
    state: dict
    step: int = 0


class Trainer:
    def __init__(self, model: LM, run_cfg: RunConfig, data,
                 mesh: Mesh | None = None, rules=DEFAULT_RULES):
        self.model = model
        self.run_cfg = run_cfg
        self.data = data
        self.mesh = mesh
        self.rules = rules
        self.ckpt = CheckpointManager(run_cfg.checkpoint_dir,
                                      keep=run_cfg.keep_checkpoints)
        self.metrics_log: list[dict] = []

        step_fn = make_train_step(model, run_cfg, mesh)
        if mesh is not None:
            self._jit_step = jax.jit(step_fn, donate_argnums=(0,))
        else:
            self._jit_step = jax.jit(step_fn, donate_argnums=(0,))

    def init_or_restore(self, key) -> dict:
        state = init_state(self.model, key, self.run_cfg)
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, extra = self.ckpt.restore(like=state)
            self.start_step = int(extra.get("step", latest))
        else:
            self.start_step = 0
        return state

    def train(self, state: dict, steps: int, log_cb: Callable | None = None):
        rc = self.run_cfg
        t0 = time.perf_counter()
        step = self.start_step if hasattr(self, "start_step") else 0
        for i in range(step, step + steps):
            batch = self.data.batch_at(i)
            state, metrics = self._jit_step(state, batch)
            if (i + 1) % rc.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i + 1
                m["sec_per_step"] = (time.perf_counter() - t0) / (i + 1 - step)
                self.metrics_log.append(m)
                if log_cb:
                    log_cb(m)
            if (i + 1) % rc.checkpoint_every == 0:
                self.ckpt.save(i + 1, state, extra={"step": i + 1,
                                                    "cursor": i + 1})
        self.ckpt.save(step + steps, state,
                       extra={"step": step + steps, "cursor": step + steps})
        self.ckpt.wait()
        return state
