"""Data pipeline: deterministic synthetic sources + straggler-tolerant
prefetch.

Sources are *stateless*: ``batch_at(step)`` derives the batch from the step
index alone (counter-based RNG), so the checkpoint cursor is just the step —
resume is exact by construction, and any worker can recompute any batch
(elastic re-balancing).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np


class SyntheticTokens:
    """Zipf-ish token stream for LM training shapes."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 patch_spec: tuple[int, int] | None = None):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed = seed
        self.patch_spec = patch_spec          # (num_positions, embed_dim)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        # zipf-like marginal so the loss curve is non-trivial
        ranks = np.arange(1, self.vocab + 1)
        p = 1.0 / ranks
        p /= p.sum()
        toks = rng.choice(self.vocab, size=(self.batch, self.seq_len), p=p)
        out = {"tokens": jnp.asarray(toks, jnp.int32)}
        if self.patch_spec is not None:
            n, d = self.patch_spec
            out["patch_embeds"] = jnp.asarray(
                rng.standard_normal((self.batch, n, d)), jnp.bfloat16)
        return out


class HierarchicalTask:
    """Super/sub-class sequence classification (the paper's Fig 6a/b data).

    Each subclass s (of superclass g(s)) has a token distribution =
    superclass base mixture + subclass perturbation; a sequence is iid draws.
    A classifier must infer the distribution — learnable by a small
    transformer with mean pooling, and the hierarchy makes specialists
    genuinely better *within* their superclass (the paper's premise).
    """

    def __init__(self, num_super: int = 10, subs_per_super: int = 8,
                 vocab: int = 512, seq_len: int = 32, seed: int = 0,
                 super_strength: float = 3.0, sub_strength: float = 1.2):
        self.num_super = num_super
        self.subs_per_super = subs_per_super
        self.num_sub = num_super * subs_per_super
        self.vocab, self.seq_len = vocab, seq_len
        rng = np.random.default_rng(seed)
        base = rng.standard_normal((num_super, vocab)) * super_strength
        pert = rng.standard_normal((self.num_sub, vocab)) * sub_strength
        logits = base[np.arange(self.num_sub) // subs_per_super] + pert
        self.dists = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        self.sub_of_super = np.arange(self.num_sub) // subs_per_super

    def sample(self, n: int, seed: int = 0,
               subclasses: Optional[np.ndarray] = None):
        rng = np.random.default_rng((seed, 777))
        subs = (rng.integers(0, self.num_sub, n) if subclasses is None
                else rng.choice(subclasses, n))
        toks = np.stack([rng.choice(self.vocab, self.seq_len,
                                    p=self.dists[s]) for s in subs])
        return (jnp.asarray(toks, jnp.int32),
                jnp.asarray(subs, jnp.int32),
                jnp.asarray(self.sub_of_super[subs], jnp.int32))

    def batch_iter(self, batch: int, seed: int = 0,
                   subclasses: Optional[np.ndarray] = None):
        step = 0
        while True:
            x, sub, sup = self.sample(batch, seed=(seed * 100003 + step),
                                      subclasses=subclasses)
            yield {"x": x, "sub": sub, "sup": sup}
            step += 1


class PrefetchLoader:
    """Deadline-bounded background prefetch (straggler mitigation).

    A slow ``batch_at`` (network stall, bad host) never blocks the step
    longer than ``deadline_s``: the loader hands out the freshest *backup*
    batch instead and counts the event.  On a real cluster the backup comes
    from a replicated sample store; here it is the previous batch.
    """

    def __init__(self, source, depth: int = 2, deadline_s: float = 5.0):
        self.source = source
        self.deadline_s = deadline_s
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.stats = {"stragglers": 0, "batches": 0}
        self._backup: Any = None
        self._stop = threading.Event()
        self._next_step = 0
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = 0
        while not self._stop.is_set():
            b = self.source.batch_at(step)
            self.q.put((step, b))
            step += 1

    def batch_at(self, step: int):
        """Step-ordered fetch with deadline."""
        deadline = time.monotonic() + self.deadline_s
        while True:
            try:
                s, b = self.q.get(timeout=max(0.0, deadline -
                                              time.monotonic()))
            except queue.Empty:
                self.stats["stragglers"] += 1
                if self._backup is None:    # cold start: block once
                    s, b = self.q.get()
                else:
                    self.stats["batches"] += 1
                    return self._backup
            self._backup = b
            self.stats["batches"] += 1
            if s >= step:
                return b
            # stale early batches are drained (after resume at step > 0)

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
