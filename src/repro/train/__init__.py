from repro.train.checkpoint import (
    save_pytree, load_pytree, CheckpointManager,
)
from repro.train.optimizer import (
    adamw_init, adamw_update, make_schedule, global_norm,
)
from repro.train.trainer import TrainState, Trainer, make_train_step
from repro.train.data import SyntheticTokens, HierarchicalTask, PrefetchLoader
