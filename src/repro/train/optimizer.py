"""In-house AdamW + schedules (optax is not available offline).

Optimizer state is a params-shaped pytree, so it inherits the params'
shardings (FSDP'd moments for free).  Updates are fully jit-compatible.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def make_schedule(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = jnp.asarray(step).astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "linear":
            t = jnp.clip((step - cfg.warmup_steps) /
                         max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
            decay = 1.0 - t
        else:  # cosine
            t = jnp.clip((step - cfg.warmup_steps) /
                         max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
            decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return cfg.lr * warm * decay
    return sched


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), tree), norm


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig,
                 schedule: Callable | None = None):
    """Returns (new_params, new_opt_state, metrics)."""
    sched = schedule or make_schedule(cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = opt_state["count"] + 1
    lr = sched(count)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_m = treedef.unflatten([n[1] for n in new])
    new_v = treedef.unflatten([n[2] for n in new])
    return new_p, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
