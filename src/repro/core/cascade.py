"""Super-Sub dynamic inference (paper Fig 6a/b, Fig S1a).

Two-stage cascade: a generalist *super* network predicts the superclass; if a
specialist exists for that superclass it is context-switched in and produces
the final subclass; otherwise the generalist finishes the job (the paper's
workflow, Fig 6a).

Only a context-switching fabric runs this efficiently: with dual slots the
specialist of batch *i* loads while the super network of batch *i+1*
executes (Fig S1a's 8-cycles-for-4-images pipeline).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.context import ContextDescriptor, ContextSwitchEngine


@dataclass
class CascadeMember:
    name: str
    apply_fn: Callable              # (params, x) -> class logits
    weights_fn: Callable[[], Any]
    covers: int | None = None       # superclass id this specialist covers


class SuperSubCascade:
    """Dynamic-inference cascade driven by a ContextSwitchEngine."""

    def __init__(self, engine: ContextSwitchEngine,
                 super_net: CascadeMember,
                 specialists: Sequence[CascadeMember],
                 generalist: CascadeMember,
                 sub_of_super: np.ndarray):
        """``sub_of_super[sub_id] -> super_id`` label hierarchy."""
        self.engine = engine
        self.super_net = super_net
        self.generalist = generalist
        self.specialists = {m.covers: m for m in specialists}
        self.sub_of_super = np.asarray(sub_of_super)
        for m in [super_net, generalist, *specialists]:
            engine.register(ContextDescriptor(
                name=m.name, apply_fn=m.apply_fn, weights_fn=m.weights_fn))

    # ------------------------------------------------------------ inference
    def static_infer(self, x) -> np.ndarray:
        """Paper's 'static inference': generalist only."""
        self.engine.preload(self.generalist.name)
        self.engine.switch(self.generalist.name)
        logits = self.engine.run(x)
        return np.asarray(jnp.argmax(logits, -1))

    def dynamic_infer(self, x) -> dict:
        """Paper's 'dynamic inference' for one batch (Fig 6a workflow)."""
        self.engine.preload(self.super_net.name)
        self.engine.switch(self.super_net.name)
        super_logits = self.engine.run(x)
        super_pred = int(np.asarray(jnp.argmax(super_logits.mean(0))))
        member = self.specialists.get(super_pred, self.generalist)
        self.engine.preload(member.name)
        self.engine.switch(member.name)       # hidden if already resident
        sub_logits = self.engine.run(x)
        sub_pred = np.asarray(jnp.argmax(sub_logits, -1))
        if member is not self.generalist:
            # specialist predicts within-superclass ids -> map to global ids
            local_to_global = np.where(self.sub_of_super == super_pred)[0]
            sub_pred = local_to_global[sub_pred]
        return {"super": super_pred, "sub": sub_pred}

    def _specialist_pass(self, x, super_pred: int) -> dict:
        """Switch to the specialist for `super_pred` and finish the batch."""
        m = self.specialists.get(super_pred, self.generalist)
        self.engine.preload(m.name)           # no-op if resident/in flight
        self.engine.switch(m.name, wait=True)
        logits = self.engine.run(x)
        pred = np.asarray(jnp.argmax(logits, -1))
        if m is not self.generalist:
            # specialist predicts within-superclass ids -> map to global ids
            l2g = np.where(self.sub_of_super == super_pred)[0]
            pred = l2g[pred]
        return {"super": super_pred, "sub": pred}

    def dynamic_infer_pipelined(self, batches: Sequence[Any]) -> list:
        """Fig S1(a): one batch is always in flight — while batch i's
        specialist weights stream into the shadow slot, the super net
        classifies batch i+1 (and batch i's own specialist pass overlaps
        the load too).  Prime with batch 0, drain batch i-1 after
        classifying batch i, flush the last batch at the end; the
        specialist load is never awaited in the same step it was issued,
        so it hides behind real execution (engine stats show
        ``hidden_load_seconds > 0`` — tested)."""
        results: list[dict] = []
        in_flight: Optional[tuple[Any, int]] = None   # (batch, super_pred)
        self.engine.preload(self.super_net.name, block=True)
        for x in batches:
            self.engine.switch(self.super_net.name, wait=True)
            sup = self.engine.run(x)
            sp = int(np.asarray(jnp.argmax(sup.mean(0))))
            member = self.specialists.get(sp, self.generalist)
            self.engine.preload(member.name)  # streams while we keep running
            if in_flight is not None:         # drain the previous batch
                results.append(self._specialist_pass(*in_flight))
            in_flight = (x, sp)
        if in_flight is not None:             # flush
            results.append(self._specialist_pass(*in_flight))
        return results

    # ------------------------------------------------------------ accuracy
    def evaluate(self, xs, sub_labels, batch: int = 256) -> dict:
        """Fig 6(b): dynamic vs static subclass accuracy."""
        sub_labels = np.asarray(sub_labels)
        static_hits = dyn_hits = n = 0
        for i in range(0, len(xs), batch):
            xb, yb = xs[i:i + batch], sub_labels[i:i + batch]
            static_hits += (self.static_infer(xb) == yb).sum()
            out = self.dynamic_infer(xb)
            dyn_hits += (out["sub"] == yb).sum()
            n += len(yb)
        return {"static_acc": static_hits / n, "dynamic_acc": dyn_hits / n,
                "improvement": (dyn_hits - static_hits) / n}
