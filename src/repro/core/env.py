"""Runtime environment control: platform, precision, XLA flags.

Launchers and benchmark drivers call these BEFORE the first jax
computation so the backend initializes the way the run was asked for —
and ``describe()`` afterwards so every BENCH/report records the platform
the numbers actually came from (a "GPU" result measured on a CPU
fallback is the classic silent benchmark lie).

Two kinds of knob live here:

  * jax config (``set_platform``, ``enable_x64``) — effective any time
    before the first computation touches the backend.
  * process environment (``set_host_device_count``, the XLA GPU latency
    flags) — these edit ``XLA_FLAGS``, which XLA reads once at backend
    initialization.  Setting them after jax has initialized its backend
    raises instead of silently doing nothing; subprocess workers (and
    the CI multi-device job) export ``XLA_FLAGS`` before python starts,
    which is always safe.

``set_host_device_count`` is how the sharded-page-bank tests and the CI
``multi-device`` job fake a 4-device mesh on one CPU host:
``--xla_force_host_platform_device_count=N`` splits the host platform
into N devices, enough for ``shard_map`` placement without hardware.
"""
from __future__ import annotations

import os
from typing import Optional

__all__ = ["backend_initialized", "describe", "enable_x64",
           "gpu_latency_hiding_flags", "set_host_device_count",
           "set_platform"]

# flags vetted for serving-shaped GPU programs: overlap collective /
# host-transfer latency behind compute instead of serializing on it
_GPU_LATENCY_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def backend_initialized() -> bool:
    """Whether jax has already initialized a backend (after which the
    process-environment knobs below can no longer take effect)."""
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:                       # pragma: no cover - jax internals
        return False


def _add_xla_flags(*flags: str) -> None:
    if backend_initialized():
        raise RuntimeError(
            "XLA_FLAGS edits are read once at backend initialization and "
            "jax has already initialized; set flags before the first jax "
            f"computation (wanted: {' '.join(flags)})")
    cur = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in flags if f not in cur]
    if missing:
        os.environ["XLA_FLAGS"] = " ".join(([cur] if cur else []) + missing)


def set_platform(name: Optional[str]) -> None:
    """Pin jax to one platform ("cpu", "gpu", "tpu"); None keeps jax's
    own detection order."""
    if name is None:
        return
    import jax
    jax.config.update("jax_platforms", name)


def enable_x64(on: bool = True) -> None:
    """Toggle 64-bit mode (f64/i64 as default wide types)."""
    import jax
    jax.config.update("jax_enable_x64", bool(on))


def set_host_device_count(n: Optional[int]) -> None:
    """Force the host (CPU) platform to expose ``n`` devices — a fake
    multi-device topology for mesh/shard_map runs without hardware.
    Must run before backend initialization; None is a no-op."""
    if n is None:
        return
    if n < 1:
        raise ValueError(f"host device count must be >= 1, got {n}")
    _add_xla_flags(f"--xla_force_host_platform_device_count={n}")


def gpu_latency_hiding_flags() -> None:
    """Enable XLA GPU's latency-hiding scheduler flags (no-op for the
    backend on CPU/TPU; the flags are only read by the GPU compiler)."""
    _add_xla_flags(*_GPU_LATENCY_FLAGS)


def describe() -> dict:
    """The environment a run ACTUALLY executed under (initializes the
    backend if nothing has yet): platform, device count/kind, x64 mode,
    and any forced host device count — recorded into BENCH meta so
    cross-machine diffs can tell a real topology from a faked one."""
    import jax
    dev = jax.devices()[0]
    flags = os.environ.get("XLA_FLAGS", "")
    forced = None
    for tok in flags.split():
        if tok.startswith("--xla_force_host_platform_device_count="):
            forced = int(tok.split("=", 1)[1])
    return {
        "backend": dev.platform,
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "x64": bool(jax.config.read("jax_enable_x64")),
        "forced_host_devices": forced,
    }
