"""Unified reconfiguration policy — the single source of truth for slot
allocation, LRU eviction, and lookahead prefetch.

The paper's dual-slot fabric needs three decisions made over and over:

  * which slot a context load may claim (never the ACTIVE one),
  * which resident context to evict when every slot is occupied (LRU,
    never the active one, never a load in flight — a queued load is a
    commitment on the single configuration port and cannot be cancelled),
  * which upcoming contexts to stream into shadow slots while the active
    one executes (lookahead prefetch, the self-loading next-configuration
    fetch of LUTstructions applied to model weights).

Before this module those decisions were re-implemented inline in the
discrete-event simulator, the live driver, the streaming server, and the
launcher — four copies that could (and did) drift.  ``ReconfigPolicy`` is
the one implementation: a pure, deterministic state machine with no clocks
and no threads.  The simulator and the live ``ContextSwitchEngine`` feed it
the same events and perform the actions it returns on their own substrate
("simulate what you fly"); the property tests in ``tests/test_policy.py``
assert that both drivers produce identical action traces.

State model (mirrors the engine's slot states):

  * ``resident``  — contexts whose weights are in a slot, LRU order
                    (least-recent first); evictable unless active
  * ``pending``   — contexts queued/streaming on the configuration port;
                    pinned until ``complete`` moves them to resident
  * ``active``    — the context the select signal points at; never evicted

Invariant: ``len(resident) + len(pending) <= num_slots``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping, Optional, Sequence


@dataclass(frozen=True)
class EnsureDecision:
    """What must happen so `net` can occupy a slot.

    ``evictions`` are performed first (in order), then — iff ``load`` —
    a load is issued on the configuration port.  ``load=False`` means the
    net is already resident or pending (nothing to do).
    """
    net: str
    evictions: tuple[str, ...] = ()
    load: bool = False


class ReconfigPolicy:
    """Deterministic LRU + lookahead-prefetch slot policy.

    Pure bookkeeping: callers perform the physical work (device transfers,
    slot flips) and report events back.  Every decision is appended to
    ``trace`` so independent drivers can be compared action-for-action.
    """

    def __init__(self, num_slots: int = 2,
                 lookahead: Optional[int] = None):
        assert num_slots >= 2, "dynamic reconfiguration needs >= 2 slots"
        self.num_slots = num_slots
        self.lookahead = lookahead          # None = unbounded window
        self.resident: list[str] = []       # LRU order, most-recent last
        self.pending: list[str] = []        # issue order on the config port
        self.active: Optional[str] = None
        self.trace: list[tuple[str, str]] = []

    # ------------------------------------------------------------- queries
    def occupied(self) -> int:
        return len(self.resident) + len(self.pending)

    def is_resident(self, net: str) -> bool:
        return net in self.resident

    def is_pending(self, net: str) -> bool:
        return net in self.pending

    def holds(self, net: str) -> bool:
        return net in self.resident or net in self.pending

    # ----------------------------------------------------------- decisions
    def ensure(self, net: str, active: Optional[str] = None,
               protect: Iterable[str] = ()) -> Optional[EnsureDecision]:
        """Decide how `net` gets a slot; apply the decision to bookkeeping.

        ``active`` protects that context from eviction (pass ``None`` at a
        quiescent point — e.g. between runs — when even the previously
        active context may be overwritten).  ``protect`` shields further
        contexts (prefetch passes the ones needed *sooner* than `net`, so
        lookahead never cannibalizes its own earlier fetches).  Returns
        ``None`` when infeasible right now: every slot is pinned.
        Infeasibility never mutates state, so callers may simply retry
        later (the engine defers, the simulator stops prefetching).
        """
        if self.holds(net):
            return EnsureDecision(net=net)
        protect = set(protect)
        need = self.occupied() - self.num_slots + 1
        victims: tuple[str, ...] = ()
        if need > 0:
            candidates = [n for n in self.resident
                          if n != active and n not in protect]
            if len(candidates) < need:
                return None
            victims = tuple(candidates[:need])      # LRU first
        for v in victims:
            self.resident.remove(v)
            if v == self.active:
                self.active = None
            self.trace.append(("evict", v))
        self.pending.append(net)
        self.trace.append(("load", net))
        return EnsureDecision(net=net, evictions=victims, load=True)

    def prefetch(self, upcoming: Sequence[str],
                 active: Optional[str] = None,
                 limit: Optional[int] = None) -> list[EnsureDecision]:
        """Plan shadow-slot loads for the upcoming contexts (in need order)
        while `active` executes — the paper's hidden reconfiguration.

        Applies each decision to bookkeeping; the caller performs the
        physical evictions/loads in order.  A context needed sooner is
        protected from being evicted for one needed later; planning stops
        at the first infeasible target (the configuration port serves
        nearer needs first)."""
        order: list[str] = []
        seen: set[str] = set()
        for n in upcoming:
            if n not in seen:
                seen.add(n)
                order.append(n)
        out: list[EnsureDecision] = []
        if limit is None:
            limit = self.lookahead
        for j, net in enumerate(order):
            if limit is not None and len(out) >= limit:
                break
            if self.holds(net):
                continue
            dec = self.ensure(net, active=active, protect=order[:j])
            if dec is None:
                break
            out.append(dec)
        return out

    def rank_contexts(self, pressure: Mapping[str, float],
                      load_cost: Optional[Mapping[str, float]] = None,
                      cost_weight: float = 1.0) -> list[str]:
        """Order contexts by serving priority (highest first).

        ``pressure`` is queue pressure per context (e.g. queued request
        count, optionally age-boosted by the caller for starvation
        freedom); ``load_cost`` the estimated seconds to make a context
        resident (0 for resident/pending ones — switching is O(1)).
        Score = pressure − cost_weight·load_cost: a busy resident context
        beats a slightly busier cold one, amortizing switches.  Ties break
        by name for determinism.
        """
        load_cost = load_cost or {}

        def score(net: str) -> tuple:
            cost = 0.0 if self.holds(net) else float(load_cost.get(net, 0.0))
            return (-(pressure[net] - cost_weight * cost), net)

        return sorted((n for n, p in pressure.items() if p > 0), key=score)

    # -------------------------------------------------------------- events
    def complete(self, net: str):
        """A load finished: the context is resident (most-recently used)."""
        if net in self.pending:
            self.pending.remove(net)
        if net not in self.resident:
            self.resident.append(net)
            self.trace.append(("complete", net))

    def activate(self, net: str) -> Optional[str]:
        """The select signal flipped to `net`; returns the previous active.

        A still-pending net is completed first (the caller just blocked on
        its load).  Bumps `net` to most-recently-used.
        """
        if net in self.pending:
            self.complete(net)
        if net not in self.resident:
            raise KeyError(f"activate({net!r}): not resident")
        self.resident.remove(net)
        self.resident.append(net)
        prev, self.active = self.active, net
        self.trace.append(("activate", net))
        return prev

    def abort(self, net: str):
        """A queued/streaming load failed: free its commitment."""
        if net in self.pending:
            self.pending.remove(net)

    def release(self, net: str):
        """The context was evicted outside a policy decision (explicit
        ``engine.evict`` / conventional-baseline teardown)."""
        if net == self.active:
            self.active = None
        if net in self.resident:
            self.resident.remove(net)
            self.trace.append(("evict", net))

    def deactivate(self):
        """Park the select signal (slot stays resident)."""
        self.active = None

    # ---------------------------------------------------------------- misc
    def reset(self):
        self.resident.clear()
        self.pending.clear()
        self.active = None
        self.trace.clear()

    def actions(self, kinds: Iterable[str] = ("load", "evict",
                                              "activate")) -> list[tuple]:
        """Trace filtered to the decision kinds drivers must agree on."""
        want = set(kinds)
        return [t for t in self.trace if t[0] in want]

    def snapshot(self) -> dict:
        return {"resident": list(self.resident),
                "pending": list(self.pending), "active": self.active}

    def __repr__(self):
        return (f"ReconfigPolicy(slots={self.num_slots}, "
                f"resident={self.resident}, pending={self.pending}, "
                f"active={self.active!r})")
