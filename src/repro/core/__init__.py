from repro.core.context import (
    ContextState, ContextDescriptor, ContextSlot, ContextSwitchEngine,
    ContextStore,
)
from repro.core.policy import EnsureDecision, ReconfigPolicy
from repro.core.scheduler import (
    simulate_conventional, simulate_preloaded, simulate_dynamic, time_saving,
)
from repro.core import hwmodel
from repro.core.cascade import SuperSubCascade
