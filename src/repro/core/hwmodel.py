"""The paper's hardware numbers (Fig 5, Supplementary) and derived models.

These constants are transcribed from the paper and drive (a) the Fig 5
benchmark tables, (b) the critical-path composition model (Fig 5c), and
(c) calibration of the discrete-event scheduler's load/switch times.

Nothing here executes on device — it is the calibrated analytic model that
replaces SPICE/VTR, per DESIGN.md §9 assumption (3)/(4).
"""
from __future__ import annotations


# ---------------------------------------------------------------------------
# Fig 5(a): area (lambda^2) — layouts drawn with lambda design rules
# ---------------------------------------------------------------------------

AREA_LAMBDA2 = {
    "CB": {
        "sram_1cfg": 1298.0,
        "fefet_1cfg": 110.0,
        "fefet_2cfg": 375.0,
        "fefet_chen42_1cfg": 473.0,     # prior FeFET work [ref 42]
    },
    "LUT": {
        "sram_1cfg": 972.0,
        "fefet_1cfg": 180.0,
        "fefet_2cfg": 360.0,
        "fefet_chen42_1cfg": 352.0,
    },
}

# paper-stated area ratios (% of SRAM single-config) — validation targets
AREA_RATIO_CLAIMS = {
    ("CB", "fefet_1cfg"): 0.085,
    ("CB", "fefet_2cfg"): 0.289,
    ("CB", "fefet_chen42_1cfg"): 0.364,
    ("LUT", "fefet_1cfg"): 0.185,
    ("LUT", "fefet_2cfg"): 0.370,
    ("LUT", "fefet_chen42_1cfg"): 0.362,
}

# headline reductions for the dual-config design (abstract): LUT 63.0 %,
# CB 71.1 % area reduction vs SRAM
HEADLINE_AREA_REDUCTION = {"LUT": 0.630, "CB": 0.711}

# ---------------------------------------------------------------------------
# Fig 5(b): primitive delay / power (HSPICE, 45 nm PTM + calibrated FeFET)
# Values stated in the text; others encoded as paper-stated ratios.
# ---------------------------------------------------------------------------

LUT_READ_DELAY_PS = {
    # SRAM and FeFET-2cfg LUT delays are not stated numerically; they are
    # CALIBRATED (bisection over the Fig 5c composition model) so the
    # published average critical-path deltas (-8.6 % / +9.6 %) come out
    # exactly — see tests/test_hwmodel.py.  Orderings stated in the text
    # (FeFET-1cfg second-best NV; FeFET-2cfg < RRAM; RRAM slowest) hold.
    "sram_1cfg": 153.4,           # calibrated (pass-gate mux tree + buffer)
    "fefet_1cfg": 124.3,          # stated: 124.3 ps for 6-input LUT
    "fefet_2cfg": 155.1,          # calibrated (+ config-select mux stage)
    "rram_1cfg": 165.0,           # longest latency among NV LUTs (stated)
    "mtj_1cfg": 118.0,            # best NV latency (FeFET stated 2nd best)
}

LUT_READ_POWER_UW = {
    "fefet_1cfg": 13.1,           # stated: 13.1 uW, smallest of all
    "fefet_2cfg": 14.8,           # "increases slightly, < MTJ 1cfg"
    "mtj_1cfg": 16.0,
    "sram_1cfg": 15.2,
    "rram_1cfg": 15.6,
}

CB_DELAY_PS = {
    "sram_1cfg": 3.9,
    "fefet_1cfg": 7.8,            # stated: ~2x SRAM CB; 7.8 ps simulated
    "fefet_2cfg": 7.8,            # same branch structure (series enable FET)
}

# power ratios vs SRAM CB (stated: ~95 % / ~85 % less power)
CB_POWER_VS_SRAM = {"fefet_1cfg": 0.05, "fefet_2cfg": 0.15, "sram_1cfg": 1.0}
SB_POWER_REDUCTION = {"fefet_vs_sram": 0.536}     # abstract: 53.6 % SB power cut
CB_POWER_REDUCTION = {"fefet_vs_sram": 0.827}     # abstract: 82.7 % CB power cut

# ---------------------------------------------------------------------------
# Fig 5(c): critical-path composition model over the 7 VTR benchmarks.
#
# The paper's VTR runs show the critical path is LUT-delay dominated; the
# FeFET single-config FPGA is -8.6 % vs SRAM on average and the
# dual-config FPGA is +9.6 %.  We model the path as
#     T = a * d_LUT + b * d_CB + c * d_SB
# with per-benchmark (a, b, c) primitive counts (representative VTR-scale
# profiles), and *calibrate* the SRAM primitive delays so the published
# average deltas are met.  The per-benchmark spread is then a prediction.
# ---------------------------------------------------------------------------

VTR_BENCHMARKS = {
    #                 LUT levels, CB hops, SB hops  (representative profiles)
    "stereovision0": (10, 22, 14),
    "blob_merge":    (12, 26, 17),
    "sha":           (14, 30, 20),
    "spree":         (9, 20, 13),
    "boundtop":      (11, 24, 15),
    "diffeq2":       (13, 28, 18),
    "or1200":        (12, 27, 17),
}

SB_DELAY_PS = {"sram_1cfg": 5.2, "fefet_1cfg": 9.5, "fefet_2cfg": 9.5}

CRITICAL_PATH_CLAIMS = {"fefet_1cfg": -0.086, "fefet_2cfg": +0.096}


def critical_path_ps(tech: str, bench: str) -> float:
    a, b, c = VTR_BENCHMARKS[bench]
    lut = {"sram_1cfg": LUT_READ_DELAY_PS["sram_1cfg"],
           "fefet_1cfg": LUT_READ_DELAY_PS["fefet_1cfg"],
           "fefet_2cfg": LUT_READ_DELAY_PS["fefet_2cfg"],
           "rram_1cfg": LUT_READ_DELAY_PS["rram_1cfg"],
           "mtj_1cfg": LUT_READ_DELAY_PS["mtj_1cfg"]}[tech]
    cb = CB_DELAY_PS.get(tech, CB_DELAY_PS["sram_1cfg"])
    sb = SB_DELAY_PS.get(tech, SB_DELAY_PS["sram_1cfg"])
    return a * lut + b * cb + c * sb


def critical_path_delta(tech: str) -> float:
    """Average critical-path delta vs SRAM over the 7 VTR benchmarks."""
    deltas = []
    for bench in VTR_BENCHMARKS:
        t = critical_path_ps(tech, bench)
        s = critical_path_ps("sram_1cfg", bench)
        deltas.append((t - s) / s)
    return sum(deltas) / len(deltas)


# ---------------------------------------------------------------------------
# Fig 6 / S9 workload constants
# ---------------------------------------------------------------------------

ICAP_BANDWIDTH_GBPS = 3.2        # Xilinx ICAP port (paper: 3.2 Gb/s, ref 54)

# Representative bitstream sizes and Vitis-AI U250 latencies.  The paper
# treats these as measured-but-unpublished; we pick public-order-of-magnitude
# values (U250 full bitstream ~ 70 MB region-scale partials) such that the
# published saving ranges are met — see benchmarks/fig6d_case2.py.
NETWORKS = {
    #            bitstream_Mb   exec_ms per inference batch
    "resnet50":   (180.0, 19.5),
    "cnv":        (90.0, 2.1),
    "mobilenetv1": (120.0, 4.3),
}


def reconfig_time_s(bitstream_megabits: float) -> float:
    """Paper's formula: bitstream size / ICAP throughput (3.2 Gb/s)."""
    return bitstream_megabits * 1e6 / (ICAP_BANDWIDTH_GBPS * 1e9)


# TPU-side constants for the adapted engine (DESIGN.md mapping): loading a
# context = weight bytes / effective host->HBM streaming bandwidth.
TPU_HOST_TO_HBM_GBPS = 25.0      # PCIe gen4-ish effective
TPU_SWITCH_SECONDS = 2e-6        # pointer swap + dispatch enqueue


def context_load_time_s(param_bytes: int,
                        gbps: float = TPU_HOST_TO_HBM_GBPS) -> float:
    return param_bytes / (gbps * 1e9)
