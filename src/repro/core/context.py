"""Context-switching execution engine — the paper's contribution on TPU.

The paper's FPGA holds **two local copies** of every configuration primitive
(2T-2FeFET switches, dual LUT banks): the inactive copy is programmed while
the active one executes, and switching is a <1 ns select-signal flip.

Mapping here (see DESIGN.md §2):
  * a *context* = weight pytree + its jitted executables ("fabric programs")
  * a *slot*    = device-resident buffer set; ``num_slots=2`` is the paper's
    dual-configuration design (more slots = the time-multiplexed FPGA of
    Trimberger'97, supported but costing HBM exactly as the paper notes it
    costs area)
  * *preload*   = asynchronous host->device streaming into a non-active slot
    (the serial enable transistor == the slot state machine: an executing
    step can never read a LOADING slot)
  * *switch*    = O(1) pointer swap; no device data movement, no recompile

Executables are compiled at registration ("synthesis time"), never at switch
time.  A non-volatile context store (checkpoint dir) plays the role of the
FeFET's retention: contexts survive process restarts.
"""
from __future__ import annotations

import enum
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from repro.core.policy import ReconfigPolicy
from repro.core.telemetry import Telemetry, safe_ratio


class ContextState(enum.Enum):
    EMPTY = "empty"
    LOADING = "loading"      # enable transistor OFF: invisible to execution
    READY = "ready"          # resident, selectable
    ACTIVE = "active"        # the select signal points here


@dataclass
class ContextDescriptor:
    """A registered configuration: how to compute and where weights come from.

    ``base`` enables *partial reconfiguration* (the paper's Fig 1(b)
    analogue at weight-tensor granularity): ``weights_fn`` then returns
    only the leaves that DIFFER from the base context; the loader streams
    just the delta and assembles the slot from the base's resident buffers
    + the delta.  Super-Sub cascades with a shared backbone load their
    specialists this way (head-only deltas)."""
    name: str
    apply_fn: Callable                    # (params, *inputs) -> outputs
    weights_fn: Callable[[], Any]         # -> host weight pytree (or delta)
    shardings: Any = None                 # optional NamedSharding pytree
    donate_params: bool = False
    base: Optional[str] = None            # delta-load on top of this context
    meta: dict = field(default_factory=dict)


@dataclass
class ContextSlot:
    idx: int
    state: ContextState = ContextState.EMPTY
    name: Optional[str] = None
    buffers: Any = None                   # device weight pytree
    bytes_resident: int = 0
    ready_event: threading.Event = field(default_factory=threading.Event)


def _nbytes(tree) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(tree)
               if hasattr(x, "nbytes"))


def _overlay(base, delta):
    """Merge a (possibly partial) delta pytree over a base pytree: dict
    nodes merge key-wise, anything else in the delta replaces the base."""
    if isinstance(delta, dict) and isinstance(base, dict):
        out = dict(base)
        for k, v in delta.items():
            out[k] = _overlay(base[k], v) if k in base else v
        return out
    return delta


class ContextSwitchEngine:
    """Dual-slot (by default) context-switching executor.

    All slot-allocation / eviction / prefetch *decisions* are delegated to
    a ``ReconfigPolicy`` (``repro.core.policy``) — the same object the
    discrete-event simulator runs — so the engine only performs the
    physical work: device transfers, slot state flips, stats.
    """

    def __init__(self, num_slots: int = 2, mesh=None,
                 store: "ContextStore | None" = None,
                 policy: ReconfigPolicy | None = None,
                 telemetry: Telemetry | None = None):
        assert num_slots >= 2, "dynamic reconfiguration needs >= 2 slots"
        if policy is None:
            policy = ReconfigPolicy(num_slots=num_slots)
        assert policy.num_slots == num_slots, \
            (policy.num_slots, num_slots)
        self.policy = policy
        self.slots = [ContextSlot(i) for i in range(num_slots)]
        self.mesh = mesh
        self.store = store
        self._contexts: dict[str, ContextDescriptor] = {}
        self._executables: dict[tuple, Any] = {}
        self._pending: dict[str, Future] = {}
        self._deferred: dict[str, Future] = {}    # waiting for a free slot
        self._lock = threading.RLock()
        # one configuration port, like the FPGA's single config interface:
        self._loader = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix="ctx-loader")
        # Shared measurement layer: stats live in the server-wide registry
        # under ``ctx.`` (dict call-sites unchanged — MetricView), spans go
        # to the shared tracer on one track per slot (``ctxslot<i>``), and
        # the clock is injected so simulated engines tick virtual time.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._clock = self.telemetry.clock
        self._trace = self.telemetry.tracer
        self.stats = self.telemetry.view("ctx.")
        self.stats.update({
            "loads": 0, "load_seconds": 0.0, "bytes_loaded": 0,
            "switches": 0, "switch_seconds": 0.0, "evictions": 0,
            "hidden_load_seconds": 0.0, "context_changes": 0,
        })
        # overlap accounting (all guarded by self._lock).  One loader
        # thread => at most one load window open at a time.
        self._exec_busy_until = 0.0
        self._runs_in_flight = 0
        self._run_started_at: Optional[float] = None
        self._load_started_at: Optional[float] = None
        self._load_hidden_accum = 0.0     # exec∩load overlap, completed runs

    # ------------------------------------------------------------- registry
    def register(self, desc: ContextDescriptor,
                 example_inputs: tuple = (), compile_now: bool = True):
        """Register a context; AOT-compile its executable ("synthesis")."""
        with self._lock:
            self._contexts[desc.name] = desc
        if compile_now and example_inputs:
            self._get_executable(desc, example_inputs)

    def _sig(self, inputs: tuple) -> tuple:
        def one(x):
            if hasattr(x, "shape"):
                return (tuple(x.shape), str(getattr(x, "dtype", "?")))
            return type(x).__name__
        return tuple(one(x) for x in jax.tree.leaves(inputs))

    def _get_executable(self, desc: ContextDescriptor, inputs: tuple):
        key = (desc.name, self._sig(inputs))
        with self._lock:
            if key in self._executables:
                return self._executables[key]
        fn = jax.jit(desc.apply_fn,
                     donate_argnums=(0,) if desc.donate_params else ())
        with self._lock:
            self._executables[key] = fn
        return fn

    # --------------------------------------------------------------- slots
    def _find_slot(self, name: str) -> Optional[ContextSlot]:
        for s in self.slots:
            if s.name == name and s.state in (ContextState.READY,
                                              ContextState.ACTIVE):
                return s
        return None

    # ------------------------------------------------------------- loading
    def _active_name(self) -> Optional[str]:
        a = self.active
        return a.name if a is not None else None

    def _evict_name_unlocked(self, name: str, demote_ok: bool = False):
        """Free the slot holding `name` (policy already decided this)."""
        for s in self.slots:
            if s.name == name and s.state in (ContextState.READY,
                                              ContextState.ACTIVE):
                if s.state == ContextState.ACTIVE and not demote_ok:
                    raise RuntimeError(
                        f"policy evicted ACTIVE context {name!r} "
                        "without allow_evict_active")
                if self._trace.enabled:
                    self._trace.instant(f"evict:{name}", f"ctxslot{s.idx}",
                                        ts=self._clock())
                s.state = ContextState.EMPTY
                s.name, s.buffers, s.bytes_resident = None, None, 0
                self.stats["evictions"] += 1
                return
        # slot already gone (e.g. explicit evict raced ahead) — fine.

    def _submit_unlocked(self, desc: ContextDescriptor) -> Future:
        fut = self._loader.submit(self._do_load, desc)
        return fut

    def preload(self, name: str, block: bool = False,
                allow_evict_active: bool = False) -> Future:
        """Start loading `name` into a non-active slot (overlaps execution).

        This is the paper's dynamic reconfiguration: the call returns
        immediately; the active context keeps executing.  Repeated preloads
        of an in-flight name return the same future.  Victim selection is
        the policy's: it evicts the LRU non-active resident; when every
        slot is pinned (ACTIVE or loading) the request is *deferred* and
        resubmitted automatically as soon as a slot frees up.

        ``allow_evict_active`` marks a quiescent point (no run in flight):
        the policy may then overwrite even the currently selected context,
        exactly like the simulator's between-runs decision.
        """
        desc = self._contexts[name]
        with self._lock:
            slot = self._find_slot(name)
            if slot is not None:                        # already resident
                f: Future = Future()
                f.set_result(slot)
                return f
            pending = self._pending.get(name)
            if pending is not None and not pending.done():
                return pending                          # already in flight
            decision = self.policy.ensure(
                name, active=None if allow_evict_active
                else self._active_name())
            if decision is None:                        # all slots pinned
                ph: Future = Future()
                self._pending[name] = ph
                self._deferred[name] = ph
                fut = ph
            else:
                for v in decision.evictions:
                    self._evict_name_unlocked(
                        v, demote_ok=allow_evict_active)
                fut = self._submit_unlocked(desc)
                self._pending[name] = fut
        if block:
            fut.result()
        return fut

    def prefetch(self, upcoming: "list[str]",
                 limit: Optional[int] = None) -> "list[Future]":
        """Stream upcoming contexts into shadow slots per the policy's
        lookahead plan (hidden behind the active context's execution).

        One atomic policy consultation under the engine lock — the same
        ``ReconfigPolicy.prefetch`` call the simulator makes, so live and
        simulated prefetch/evict decisions are literally the same code.
        """
        futs: list[Future] = []
        with self._lock:
            known = [n for n in upcoming
                     if n in self._contexts and n not in self._deferred]
            for dec in self.policy.prefetch(
                    known, active=self._active_name(), limit=limit):
                for v in dec.evictions:
                    self._evict_name_unlocked(v)
                fut = self._submit_unlocked(self._contexts[dec.net])
                self._pending[dec.net] = fut
                futs.append(fut)
            self._kick_deferred_unlocked()   # evictions may free deferred
        return futs

    def _kick_deferred_unlocked(self):
        """Resubmit deferred loads whose slot just became available (FIFO:
        the configuration port serves requests in arrival order)."""
        for name in list(self._deferred):
            decision = self.policy.ensure(name, active=self._active_name())
            if decision is None:
                break                                   # still no room
            ph = self._deferred.pop(name)
            for v in decision.evictions:
                self._evict_name_unlocked(v)
            real = self._submit_unlocked(self._contexts[name])

            def _chain(f: Future, ph: Future = ph):
                exc = f.exception()
                if exc is not None:
                    ph.set_exception(exc)
                else:
                    ph.set_result(f.result())
            real.add_done_callback(_chain)

    def _claim_slot(self, name: str) -> ContextSlot:
        """Runs on the loader thread.  The policy freed a slot when this
        load was admitted, so an EMPTY slot exists by the time the single
        port gets to it; the wait loop is a defensive backstop."""
        deadline = time.monotonic() + 60.0
        while True:
            with self._lock:
                for slot in self.slots:
                    if slot.state == ContextState.EMPTY:
                        slot.state = ContextState.LOADING
                        slot.name = name
                        slot.ready_event.clear()
                        return slot
            if time.monotonic() > deadline:             # pragma: no cover
                raise RuntimeError(f"no slot became loadable for {name!r}")
            time.sleep(0.001)

    def _do_load(self, desc: ContextDescriptor):
        slot = self._claim_slot(desc.name)
        t0 = self._clock()
        with self._lock:
            self._load_started_at = t0
            self._load_hidden_accum = 0.0
        try:
            host = desc.weights_fn()
            # stream tensor-by-tensor (the two-step WL programming
            # analogue); device_put is async w.r.t. this thread until the
            # final barrier.
            if desc.shardings is not None:
                bufs = jax.tree.map(jax.device_put, host, desc.shardings)
            else:
                bufs = jax.tree.map(jax.device_put, host)
            jax.block_until_ready(bufs)
            wire_bytes = _nbytes(bufs)        # what actually crossed H2D
            if desc.base is not None:
                # partial reconfiguration: only the delta crossed the wire;
                # unchanged tensors are shared with the base's device
                # buffers (zero-copy on device).
                base_slot = self._find_slot(desc.base)
                if base_slot is None:
                    raise RuntimeError(
                        f"delta context {desc.name!r} needs base "
                        f"{desc.base!r} resident")
                bufs = _overlay(base_slot.buffers, bufs)
        except BaseException:
            with self._lock:                 # failed load never wedges a slot
                slot.state = ContextState.EMPTY
                slot.name, slot.buffers, slot.bytes_resident = None, None, 0
                slot.ready_event.set()
                self.policy.abort(desc.name)
                self._load_started_at = None
                self._kick_deferred_unlocked()
            if self._trace.enabled:
                self._trace.instant(f"load-failed:{desc.name}",
                                    f"ctxslot{slot.idx}", ts=self._clock())
            raise
        now = self._clock()
        dt = now - t0
        with self._lock:
            slot.buffers = bufs
            slot.bytes_resident = _nbytes(bufs)
            slot.state = ContextState.READY
            slot.ready_event.set()
            self.policy.complete(desc.name)
            self.stats["loads"] += 1
            self.stats["load_seconds"] += dt
            self.stats["bytes_loaded"] += wire_bytes
            # overlap accounting: execution time inside [t0, now] counts
            # this load as *hidden* reconfiguration.  Runs that completed
            # during the window accumulated their clamped overlap in
            # _load_hidden_accum (see run()); a run still in flight
            # contributes the part since max(run_start, load_start).
            hidden = self._load_hidden_accum
            if self._run_started_at is not None:
                hidden += now - max(self._run_started_at, t0)
            hidden = max(0.0, min(dt, hidden))
            self.stats["hidden_load_seconds"] += hidden
            self._load_started_at = None
            self._kick_deferred_unlocked()
        if self._trace.enabled:
            # the span carries the SAME t0/now the accounting above used,
            # so a hidden-load fraction recomputed from exported spans
            # reproduces the engine's number (tested to < 1%).
            self._trace.span(f"load:{desc.name}", f"ctxslot{slot.idx}",
                             t0, now, args={"bytes": wire_bytes,
                                            "hidden_s": round(hidden, 6)})
        return slot

    # ------------------------------------------------------------ switching
    def switch(self, name: str, wait: bool = True,
               timeout: float = 120.0) -> float:
        """Activate a resident context.  Returns the switch latency in s.

        O(1): no device data movement.  If the context is still LOADING and
        ``wait``, blocks until READY (the paper's case where t_load >
        t_exec and reconfiguration is only partially hidden).
        """
        t0 = self._clock()
        deadline = t0 + timeout
        checked_done: Optional[Future] = None
        while True:
            # residency check and activation under ONE lock acquisition: a
            # concurrent eviction (loader kick, another client's prefetch)
            # between them could otherwise activate an emptied slot.
            with self._lock:
                slot = self._find_slot(name)
                if slot is not None:
                    prev = None
                    for s in self.slots:
                        if s.state == ContextState.ACTIVE:
                            s.state = ContextState.READY
                            prev = s.name
                    slot.state = ContextState.ACTIVE
                    self.policy.activate(name)
                    now = self._clock()
                    dt = now - t0
                    self.stats["switches"] += 1
                    if prev != name:     # an actual select-signal flip
                        self.stats["context_changes"] += 1
                        if self._trace.enabled:
                            self._trace.instant(
                                f"switch:{name}", f"ctxslot{slot.idx}",
                                ts=now, args={"from": prev})
                    self.stats["switch_seconds"] += dt
                    self._kick_deferred_unlocked()  # prev became evictable
                    return dt
                pending = self._pending.get(name)
            if pending is None:
                raise KeyError(f"context {name!r} not resident; preload first")
            if pending.done():
                if pending.exception() is not None:
                    pending.result()         # surface the load failure
                if pending is checked_done:
                    # re-checked residency under the lock after this future
                    # resolved and the slot is still gone: evicted again
                    raise KeyError(
                        f"context {name!r} not resident; preload first")
                # the load may have finished between our locked residency
                # check and here — loop once to re-check under the lock
                checked_done = pending
                continue
            if not wait:
                raise RuntimeError(f"context {name!r} still loading")
            remaining = deadline - self._clock()
            if remaining <= 0:
                raise TimeoutError(f"context {name!r} did not become READY")
            pending.result(remaining)

    def deactivate(self):
        """Park the select signal: ACTIVE -> READY (slot stays resident)."""
        with self._lock:
            for s in self.slots:
                if s.state == ContextState.ACTIVE:
                    s.state = ContextState.READY
            self.policy.deactivate()
            self._kick_deferred_unlocked()

    @property
    def active(self) -> Optional[ContextSlot]:
        for s in self.slots:
            if s.state == ContextState.ACTIVE:
                return s
        return None

    # ------------------------------------------------------------ execution
    def run(self, *inputs):
        """Execute the active context on `inputs`."""
        slot = self.active
        if slot is None:
            raise RuntimeError("no ACTIVE context; call switch() first")
        fn = self._get_executable(self._contexts[slot.name], inputs)
        return self.run_step(fn, *inputs, slot=slot)

    def run_step(self, fn, *inputs, block: bool = True, slot=None):
        """Token-granular execution: run one externally-jitted program
        against the ACTIVE slot's weight buffers, with the engine's
        hidden-load (overlap) accounting.

        This is how the continuous-batching step engine drives the fabric:
        each decode step is one ``run_step`` call, so a context switch
        between any two steps is an O(1) select flip and a shadow-slot
        load overlaps *steps*, not whole batches.  ``fn`` receives the
        slot buffers as its first argument (``fn(params, *inputs)``) — the
        engine never captures weights, the slot may be evicted and
        reloaded between calls.  ``slot`` pins a pre-resolved slot so a
        caller that looked up an executable for it (``run``) can't race a
        concurrent switch into mismatched fn/buffers.
        """
        if slot is None:
            slot = self.active
        if slot is None:
            raise RuntimeError("no ACTIVE context; call switch() first")
        t0 = self._clock()
        with self._lock:
            self._runs_in_flight += 1
            if self._run_started_at is None:
                self._run_started_at = t0
        try:
            out = fn(slot.buffers, *inputs)
            if block:
                out = jax.block_until_ready(out)
        finally:
            now = self._clock()
            with self._lock:
                self._runs_in_flight -= 1
                self._exec_busy_until = now
                if self._load_started_at is not None:
                    # clamp this run's overlap to the open load window
                    self._load_hidden_accum += max(
                        0.0, now - max(t0, self._load_started_at))
                if self._runs_in_flight == 0:
                    self._run_started_at = None
            if self._trace.enabled:
                # same t0/now as the overlap accounting — see _do_load.
                self._trace.span(f"run:{slot.name}", f"ctxslot{slot.idx}",
                                 t0, now)
        return out

    def run_async(self, *inputs):
        """Dispatch without blocking (JAX async dispatch overlaps the load)."""
        slot = self.active
        if slot is None:
            raise RuntimeError("no ACTIVE context; call switch() first")
        desc = self._contexts[slot.name]
        fn = self._get_executable(desc, inputs)
        return fn(slot.buffers, *inputs)

    # --------------------------------------------------------------- misc
    def hidden_load_fraction(self) -> float:
        """Share of reconfiguration time hidden behind execution (the
        paper's headline metric) — single source for every report."""
        with self._lock:
            return safe_ratio(self.stats["hidden_load_seconds"],
                              self.stats["load_seconds"])

    def resident(self) -> list[str]:
        return [s.name for s in self.slots
                if s.state in (ContextState.READY, ContextState.ACTIVE)]

    def evict(self, name: str):
        with self._lock:
            s = self._find_slot(name)
            if s is None:
                return
            if s.state == ContextState.ACTIVE:
                raise RuntimeError("cannot evict the ACTIVE context")
            s.state = ContextState.EMPTY
            s.name, s.buffers, s.bytes_resident = None, None, 0
            self.stats["evictions"] += 1
            self.policy.release(name)
            self._kick_deferred_unlocked()

    def shutdown(self):
        self._loader.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Non-volatile context store (FeFET retention analogue)
# ---------------------------------------------------------------------------

class ContextStore:
    """Persist contexts to disk; reload without recompute (non-volatility)."""

    def __init__(self, root: str):
        self.root = root

    def save(self, name: str, weights) -> str:
        from repro.train.checkpoint import save_pytree
        import os
        path = os.path.join(self.root, f"ctx_{name}")
        save_pytree(path, weights)
        return path

    def weights_fn(self, name: str) -> Callable[[], Any]:
        from repro.train.checkpoint import load_pytree
        import os
        path = os.path.join(self.root, f"ctx_{name}")
        return lambda: load_pytree(path)
