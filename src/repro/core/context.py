"""Context-switching execution engine — the paper's contribution on TPU.

The paper's FPGA holds **two local copies** of every configuration primitive
(2T-2FeFET switches, dual LUT banks): the inactive copy is programmed while
the active one executes, and switching is a <1 ns select-signal flip.

Mapping here (see DESIGN.md §2):
  * a *context* = weight pytree + its jitted executables ("fabric programs")
  * a *slot*    = device-resident buffer set; ``num_slots=2`` is the paper's
    dual-configuration design (more slots = the time-multiplexed FPGA of
    Trimberger'97, supported but costing HBM exactly as the paper notes it
    costs area)
  * *preload*   = asynchronous host->device streaming into a non-active slot
    (the serial enable transistor == the slot state machine: an executing
    step can never read a LOADING slot)
  * *switch*    = O(1) pointer swap; no device data movement, no recompile

Executables are compiled at registration ("synthesis time"), never at switch
time.  A non-volatile context store (checkpoint dir) plays the role of the
FeFET's retention: contexts survive process restarts.
"""
from __future__ import annotations

import enum
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np


class ContextState(enum.Enum):
    EMPTY = "empty"
    LOADING = "loading"      # enable transistor OFF: invisible to execution
    READY = "ready"          # resident, selectable
    ACTIVE = "active"        # the select signal points here


@dataclass
class ContextDescriptor:
    """A registered configuration: how to compute and where weights come from.

    ``base`` enables *partial reconfiguration* (the paper's Fig 1(b)
    analogue at weight-tensor granularity): ``weights_fn`` then returns
    only the leaves that DIFFER from the base context; the loader streams
    just the delta and assembles the slot from the base's resident buffers
    + the delta.  Super-Sub cascades with a shared backbone load their
    specialists this way (head-only deltas)."""
    name: str
    apply_fn: Callable                    # (params, *inputs) -> outputs
    weights_fn: Callable[[], Any]         # -> host weight pytree (or delta)
    shardings: Any = None                 # optional NamedSharding pytree
    donate_params: bool = False
    base: Optional[str] = None            # delta-load on top of this context
    meta: dict = field(default_factory=dict)


@dataclass
class ContextSlot:
    idx: int
    state: ContextState = ContextState.EMPTY
    name: Optional[str] = None
    buffers: Any = None                   # device weight pytree
    bytes_resident: int = 0
    ready_event: threading.Event = field(default_factory=threading.Event)


def _nbytes(tree) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(tree)
               if hasattr(x, "nbytes"))


def _overlay(base, delta):
    """Merge a (possibly partial) delta pytree over a base pytree: dict
    nodes merge key-wise, anything else in the delta replaces the base."""
    if isinstance(delta, dict) and isinstance(base, dict):
        out = dict(base)
        for k, v in delta.items():
            out[k] = _overlay(base[k], v) if k in base else v
        return out
    return delta


class ContextSwitchEngine:
    """Dual-slot (by default) context-switching executor."""

    def __init__(self, num_slots: int = 2, mesh=None,
                 store: "ContextStore | None" = None):
        assert num_slots >= 2, "dynamic reconfiguration needs >= 2 slots"
        self.slots = [ContextSlot(i) for i in range(num_slots)]
        self.mesh = mesh
        self.store = store
        self._contexts: dict[str, ContextDescriptor] = {}
        self._executables: dict[tuple, Any] = {}
        self._pending: dict[str, Future] = {}
        self._lock = threading.RLock()
        # one configuration port, like the FPGA's single config interface:
        self._loader = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix="ctx-loader")
        self.stats = {
            "loads": 0, "load_seconds": 0.0, "bytes_loaded": 0,
            "switches": 0, "switch_seconds": 0.0, "evictions": 0,
            "hidden_load_seconds": 0.0,
        }
        self._exec_busy_until = 0.0       # for overlap accounting

    # ------------------------------------------------------------- registry
    def register(self, desc: ContextDescriptor,
                 example_inputs: tuple = (), compile_now: bool = True):
        """Register a context; AOT-compile its executable ("synthesis")."""
        with self._lock:
            self._contexts[desc.name] = desc
        if compile_now and example_inputs:
            self._get_executable(desc, example_inputs)

    def _sig(self, inputs: tuple) -> tuple:
        def one(x):
            if hasattr(x, "shape"):
                return (tuple(x.shape), str(getattr(x, "dtype", "?")))
            return type(x).__name__
        return tuple(one(x) for x in jax.tree.leaves(inputs))

    def _get_executable(self, desc: ContextDescriptor, inputs: tuple):
        key = (desc.name, self._sig(inputs))
        with self._lock:
            if key in self._executables:
                return self._executables[key]
        fn = jax.jit(desc.apply_fn,
                     donate_argnums=(0,) if desc.donate_params else ())
        with self._lock:
            self._executables[key] = fn
        return fn

    # --------------------------------------------------------------- slots
    def _find_slot(self, name: str) -> Optional[ContextSlot]:
        for s in self.slots:
            if s.name == name and s.state in (ContextState.READY,
                                              ContextState.ACTIVE):
                return s
        return None

    def _victim_slot(self) -> ContextSlot:
        """EMPTY first, then a READY (never ACTIVE, never LOADING)."""
        for s in self.slots:
            if s.state == ContextState.EMPTY:
                return s
        for s in self.slots:
            if s.state == ContextState.READY:
                return s
        raise RuntimeError(
            "no loadable slot: all slots ACTIVE/LOADING "
            "(the paper's design point: one executes while one loads)")

    # ------------------------------------------------------------- loading
    def preload(self, name: str, block: bool = False) -> Future:
        """Start loading `name` into a non-active slot (overlaps execution).

        This is the paper's dynamic reconfiguration: the call returns
        immediately; the active context keeps executing.  Repeated preloads
        of an in-flight name return the same future; when every slot is
        busy (one ACTIVE + others LOADING) the request queues behind the
        single configuration port and claims its slot when it runs.
        """
        desc = self._contexts[name]
        with self._lock:
            if self._find_slot(name) is not None:       # already resident
                f: Future = Future()
                f.set_result(self._find_slot(name))
                return f
            pending = self._pending.get(name)
            if pending is not None and not pending.done():
                return pending                          # already in flight
            fut = self._loader.submit(self._do_load, desc)
            self._pending[name] = fut
        if block:
            fut.result()
        return fut

    def _claim_slot(self, name: str) -> ContextSlot:
        """Runs on the loader thread: by the time a queued load executes,
        the port is free and a non-active slot is claimable."""
        deadline = time.monotonic() + 60.0
        while True:
            with self._lock:
                try:
                    slot = self._victim_slot()
                except RuntimeError:
                    slot = None
                if slot is not None:
                    if slot.state == ContextState.READY:
                        self.stats["evictions"] += 1
                    slot.state = ContextState.LOADING
                    slot.name = name
                    slot.ready_event.clear()
                    return slot
            if time.monotonic() > deadline:             # pragma: no cover
                raise RuntimeError(f"no slot became loadable for {name!r}")
            time.sleep(0.001)

    def _do_load(self, desc: ContextDescriptor):
        slot = self._claim_slot(desc.name)
        t0 = time.perf_counter()
        host = desc.weights_fn()
        # stream tensor-by-tensor (the two-step WL programming analogue);
        # device_put is async w.r.t. this thread until the final barrier.
        if desc.shardings is not None:
            bufs = jax.tree.map(jax.device_put, host, desc.shardings)
        else:
            bufs = jax.tree.map(jax.device_put, host)
        jax.block_until_ready(bufs)
        wire_bytes = _nbytes(bufs)            # what actually crossed H2D
        if desc.base is not None:
            # partial reconfiguration: only the delta crossed the wire;
            # unchanged tensors are shared with the base's device buffers
            # (zero-copy on device).
            base_slot = self._find_slot(desc.base)
            if base_slot is None:
                raise RuntimeError(
                    f"delta context {desc.name!r} needs base "
                    f"{desc.base!r} resident")
            bufs = _overlay(base_slot.buffers, bufs)
        dt = time.perf_counter() - t0
        with self._lock:
            slot.buffers = bufs
            slot.bytes_resident = _nbytes(bufs)
            slot.state = ContextState.READY
            slot.ready_event.set()
            self.stats["loads"] += 1
            self.stats["load_seconds"] += dt
            self.stats["bytes_loaded"] += wire_bytes
            # overlap accounting: time this load spent while execution was
            # in flight counts as *hidden* reconfiguration
            hidden = max(0.0, min(self._exec_busy_until, time.perf_counter())
                         - (time.perf_counter() - dt))
            self.stats["hidden_load_seconds"] += max(0.0, min(hidden, dt))
        return slot

    # ------------------------------------------------------------ switching
    def switch(self, name: str, wait: bool = True,
               timeout: float = 120.0) -> float:
        """Activate a resident context.  Returns the switch latency in s.

        O(1): no device data movement.  If the context is still LOADING and
        ``wait``, blocks until READY (the paper's case where t_load >
        t_exec and reconfiguration is only partially hidden).
        """
        t0 = time.perf_counter()
        slot = self._find_slot(name)
        if slot is None:
            pending = self._pending.get(name)
            if pending is None:
                raise KeyError(f"context {name!r} not resident; preload first")
            if not wait:
                raise RuntimeError(f"context {name!r} still loading")
            pending.result(timeout)
            slot = self._find_slot(name)
            if slot is None:
                raise TimeoutError(f"context {name!r} did not become READY")
        with self._lock:
            for s in self.slots:
                if s.state == ContextState.ACTIVE:
                    s.state = ContextState.READY
            slot.state = ContextState.ACTIVE
        dt = time.perf_counter() - t0
        self.stats["switches"] += 1
        self.stats["switch_seconds"] += dt
        return dt

    @property
    def active(self) -> Optional[ContextSlot]:
        for s in self.slots:
            if s.state == ContextState.ACTIVE:
                return s
        return None

    # ------------------------------------------------------------ execution
    def run(self, *inputs):
        """Execute the active context on `inputs`."""
        slot = self.active
        if slot is None:
            raise RuntimeError("no ACTIVE context; call switch() first")
        desc = self._contexts[slot.name]
        fn = self._get_executable(desc, inputs)
        t0 = time.perf_counter()
        out = fn(slot.buffers, *inputs)
        out = jax.block_until_ready(out)
        self._exec_busy_until = time.perf_counter()
        return out

    def run_async(self, *inputs):
        """Dispatch without blocking (JAX async dispatch overlaps the load)."""
        slot = self.active
        if slot is None:
            raise RuntimeError("no ACTIVE context; call switch() first")
        desc = self._contexts[slot.name]
        fn = self._get_executable(desc, inputs)
        return fn(slot.buffers, *inputs)

    # --------------------------------------------------------------- misc
    def resident(self) -> list[str]:
        return [s.name for s in self.slots
                if s.state in (ContextState.READY, ContextState.ACTIVE)]

    def evict(self, name: str):
        with self._lock:
            s = self._find_slot(name)
            if s is None:
                return
            if s.state == ContextState.ACTIVE:
                raise RuntimeError("cannot evict the ACTIVE context")
            s.state = ContextState.EMPTY
            s.name, s.buffers, s.bytes_resident = None, None, 0
            self.stats["evictions"] += 1

    def shutdown(self):
        self._loader.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Non-volatile context store (FeFET retention analogue)
# ---------------------------------------------------------------------------

class ContextStore:
    """Persist contexts to disk; reload without recompute (non-volatility)."""

    def __init__(self, root: str):
        self.root = root

    def save(self, name: str, weights) -> str:
        from repro.train.checkpoint import save_pytree
        import os
        path = os.path.join(self.root, f"ctx_{name}")
        save_pytree(path, weights)
        return path

    def weights_fn(self, name: str) -> Callable[[], Any]:
        from repro.train.checkpoint import load_pytree
        import os
        path = os.path.join(self.root, f"ctx_{name}")
        return lambda: load_pytree(path)
