"""Reconfiguration scheduling: discrete-event timing model + live driver.

Reproduces the paper's three timing case studies analytically and drives the
real ``ContextSwitchEngine`` with the same schedules so model and measurement
can be compared (EXPERIMENTS.md §Paper-validation):

  * conventional FPGA        — serial: every switch pays full reconfiguration
  * preloaded (Fig 6c/d)     — all contexts resident; switch cost ~0
  * dynamic reconfig (Fig 6e/f, S9) — next context loads *during* current
    execution; visible reconfiguration = max(0, t_load - t_exec_available)

Invariants checked by property tests:
  * preloaded saving  in [0, 1)       (paper: ideal bound 100 %)
  * dynamic  saving   in [0, 0.5] for alternating 2-net schedules and
    <= 1 - 1/(k+1) in general        (paper: ideal bound 50 % for their case)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class Run:
    """One execution of a network: `net` must be resident when it starts."""
    net: str
    exec_time: float
    repeat: int = 1


def simulate_conventional(schedule: Sequence[Run],
                          load_time: dict[str, float]) -> float:
    """Single-configuration FPGA: reconfigure serially on every net change."""
    t, current = 0.0, None
    for r in schedule:
        if r.net != current:
            t += load_time[r.net]
            current = r.net
        t += r.exec_time * r.repeat
    return t


def simulate_preloaded(schedule: Sequence[Run],
                       load_time: dict[str, float],
                       switch_time: float = 0.0,
                       preload_upfront: bool = False) -> float:
    """All contexts resident (paper case 2: two preloaded configurations).

    ``preload_upfront`` charges the one-time initial loads (the paper's
    comparison excludes them, as they happen once at deployment).
    """
    t, current = 0.0, None
    if preload_upfront:
        t += sum(load_time[n] for n in {r.net for r in schedule})
    for r in schedule:
        if r.net != current:
            t += switch_time
            current = r.net
        t += r.exec_time * r.repeat
    return t


def simulate_dynamic(schedule: Sequence[Run],
                     load_time: dict[str, float],
                     num_slots: int = 2,
                     switch_time: float = 0.0) -> float:
    """Dynamic reconfiguration with `num_slots` resident slots.

    Event simulation: while run i executes in its slot, the loader (one
    configuration port, like the FPGA's single config interface) streams the
    weights of upcoming non-resident nets into free slots, evicting
    least-recently-used non-active residents to make room.  Visible stall
    before run i = remaining load time for its net.  This is the paper's
    'reconfigure while executing' timeline (Fig 6e), generalized to
    arbitrary schedules and slot counts.
    """
    resident: list[str] = []                 # LRU order, newest last
    t = 0.0
    loader_free_at = 0.0
    load_done_at: dict[str, float] = {}

    def occupied() -> int:
        return len(resident) + len(load_done_at)

    def ensure_queued(net: str, now: float, active: str | None):
        """Queue a load, evicting an LRU non-active resident if needed."""
        nonlocal loader_free_at
        if net in resident or net in load_done_at:
            return True
        while occupied() >= num_slots:
            victim = next((n for n in resident if n != active), None)
            if victim is None:
                return False                 # only the active net resident
            resident.remove(victim)
        start = max(now, loader_free_at)
        loader_free_at = start + load_time[net]
        load_done_at[net] = loader_free_at
        return True

    for i, r in enumerate(schedule):
        ensure_queued(r.net, t, active=None)
        if r.net not in resident:            # visible stall: remaining load
            t = max(t, load_done_at.pop(r.net))
            resident.append(r.net)
        else:
            resident.remove(r.net)
            resident.append(r.net)           # MRU
        t += switch_time
        # prefetch upcoming nets while this one executes (hidden loads)
        for nxt in schedule[i + 1:]:
            if not ensure_queued(nxt.net, t, active=r.net):
                break
        t += r.exec_time * r.repeat
    return t


def time_saving(baseline: float, ours: float) -> float:
    return (baseline - ours) / baseline


# ---------------------------------------------------------------------------
# live driver: runs the same schedule on a real ContextSwitchEngine
# ---------------------------------------------------------------------------

def run_schedule_live(engine, schedule: Sequence[Run], inputs: dict,
                      dynamic: bool = True) -> dict:
    """Drive the real engine; returns measured wall/clock decomposition.

    dynamic=True  — preload next context while the current one runs
    dynamic=False — conventional: evict + blocking load on every change
    """
    import time as _time
    t0 = _time.perf_counter()
    stalls = 0.0
    for i, r in enumerate(schedule):
        if not dynamic:
            # single-configuration FPGA: a net change always reloads.
            prev = engine.active.name if engine.active else None
            if prev != r.net:
                for name in list(engine.resident()):
                    if name != prev:
                        engine.evict(name)          # only the active stays
                ts = _time.perf_counter()
                engine.preload(r.net, block=True)
                stalls += _time.perf_counter() - ts
                engine.switch(r.net)
                if prev is not None:
                    engine.evict(prev)              # old config overwritten
        else:
            ts = _time.perf_counter()
            engine.preload(r.net)            # no-op if resident
            engine.switch(r.net, wait=True)  # stall only if load incomplete
            stalls += _time.perf_counter() - ts
            if i + 1 < len(schedule) and schedule[i + 1].net != r.net:
                engine.preload(schedule[i + 1].net)   # hidden behind run()
        for _ in range(r.repeat):
            engine.run(*inputs[r.net])
    return {"total": _time.perf_counter() - t0, "visible_stalls": stalls}
