"""Reconfiguration scheduling: discrete-event timing model + live driver.

Reproduces the paper's three timing case studies analytically and drives the
real ``ContextSwitchEngine`` with the same schedules so model and measurement
can be compared (EXPERIMENTS.md §Paper-validation):

  * conventional FPGA        — serial: every switch pays full reconfiguration
  * preloaded (Fig 6c/d)     — all contexts resident; switch cost ~0
  * dynamic reconfig (Fig 6e/f, S9) — next context loads *during* current
    execution; visible reconfiguration = max(0, t_load - t_exec_available)

Invariants checked by property tests:
  * preloaded saving  in [0, 1)       (paper: ideal bound 100 %)
  * dynamic  saving   in [0, 0.5] for alternating 2-net schedules and
    <= 1 - 1/(k+1) in general        (paper: ideal bound 50 % for their case)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.policy import ReconfigPolicy


@dataclass(frozen=True)
class Run:
    """One execution of a network: `net` must be resident when it starts."""
    net: str
    exec_time: float
    repeat: int = 1


def simulate_conventional(schedule: Sequence[Run],
                          load_time: dict[str, float]) -> float:
    """Single-configuration FPGA: reconfigure serially on every net change."""
    t, current = 0.0, None
    for r in schedule:
        if r.net != current:
            t += load_time[r.net]
            current = r.net
        t += r.exec_time * r.repeat
    return t


def simulate_preloaded(schedule: Sequence[Run],
                       load_time: dict[str, float],
                       switch_time: float = 0.0,
                       preload_upfront: bool = False) -> float:
    """All contexts resident (paper case 2: two preloaded configurations).

    ``preload_upfront`` charges the one-time initial loads (the paper's
    comparison excludes them, as they happen once at deployment).
    """
    t, current = 0.0, None
    if preload_upfront:
        t += sum(load_time[n] for n in {r.net for r in schedule})
    for r in schedule:
        if r.net != current:
            t += switch_time
            current = r.net
        t += r.exec_time * r.repeat
    return t


def simulate_dynamic(schedule: Sequence[Run],
                     load_time: dict[str, float],
                     num_slots: int = 2,
                     switch_time: float = 0.0,
                     policy: Optional[ReconfigPolicy] = None,
                     telemetry=None) -> float:
    """Dynamic reconfiguration with `num_slots` resident slots.

    Event simulation: while run i executes in its slot, the loader (one
    configuration port, like the FPGA's single config interface) streams the
    weights of upcoming non-resident nets into free slots, evicting
    least-recently-used non-active residents to make room.  Visible stall
    before run i = remaining load time for its net.  This is the paper's
    'reconfigure while executing' timeline (Fig 6e), generalized to
    arbitrary schedules and slot counts.

    Which net loads where — and which resident is evicted — is decided by
    the shared ``ReconfigPolicy``, the exact object that drives the live
    ``ContextSwitchEngine``; this function only advances the clock.  Pass
    ``policy`` to inspect its decision trace afterwards.

    ``telemetry`` (a ``repro.core.telemetry.Telemetry``) makes the
    simulator emit the SAME metric keys the live engine writes —
    ``ctx.loads`` / ``ctx.load_seconds`` / ``ctx.hidden_load_seconds`` /
    ``ctx.switches`` / ``ctx.context_changes`` — plus ``load:``/``run:``
    spans on virtual-time tracks, so a simulated timeline opens in
    Perfetto exactly like a measured one.
    """
    pol = policy if policy is not None else ReconfigPolicy(num_slots)
    assert pol.num_slots == num_slots, (pol.num_slots, num_slots)
    t = 0.0
    loader_free_at = 0.0
    load_done_at: dict[str, float] = {}
    load_spans: list[tuple[str, float, float]] = []   # (net, start, done)
    exec_spans: list[tuple[str, float, float]] = []
    stats = trace = None
    if telemetry is not None:
        stats = telemetry.view("ctx.")
        for k in ("loads", "switches", "context_changes"):
            stats.setdefault(k, 0)
        for k in ("load_seconds", "hidden_load_seconds", "switch_seconds",
                  "visible_stall_seconds"):
            stats.setdefault(k, 0.0)
        trace = telemetry.tracer
    current = None

    def fire_completions(now: float):
        """Report finished loads to the policy, in completion order."""
        for net, done in sorted(load_done_at.items(), key=lambda kv: kv[1]):
            if done <= now:
                pol.complete(net)
                del load_done_at[net]

    def queue_load(net: str, now: float):
        nonlocal loader_free_at
        start = max(now, loader_free_at)
        loader_free_at = start + load_time[net]
        load_done_at[net] = loader_free_at
        load_spans.append((net, start, loader_free_at))
        if stats is not None:
            stats["loads"] += 1
            stats["load_seconds"] += load_time[net]

    for i, r in enumerate(schedule):
        fire_completions(t)
        decision = pol.ensure(r.net, active=None)   # quiescent: between runs
        if decision is not None and decision.load:
            queue_load(r.net, t)
        if not pol.is_resident(r.net):       # visible stall: remaining load
            done = load_done_at.pop(r.net)
            if stats is not None and done > t:
                stats["visible_stall_seconds"] += done - t
            t = max(t, done)
            pol.complete(r.net)
        pol.activate(r.net)
        if stats is not None:
            stats["switches"] += 1
            stats["switch_seconds"] += switch_time
            if r.net != current:
                stats["context_changes"] += 1
        current = r.net
        t += switch_time
        fire_completions(t)
        # prefetch upcoming nets while this one executes (hidden loads)
        upcoming = [nxt.net for nxt in schedule[i + 1:]]
        for dec in pol.prefetch(upcoming, active=r.net):
            queue_load(dec.net, t)
        fire_completions(t)                  # zero-cost loads land instantly
        exec_spans.append((r.net, t, t + r.exec_time * r.repeat))
        t += r.exec_time * r.repeat

    if stats is not None:
        # hidden = load time overlapped by execution, clamped per load —
        # the same definition the live engine accumulates online
        for _, l0, l1 in load_spans:
            ov = sum(max(0.0, min(l1, e1) - max(l0, e0))
                     for _, e0, e1 in exec_spans)
            stats["hidden_load_seconds"] += min(ov, l1 - l0)
        if trace is not None and trace.enabled:
            for net, l0, l1 in load_spans:
                trace.span(f"load:{net}", "sim-loader", l0, l1)
            for net, e0, e1 in exec_spans:
                trace.span(f"run:{net}", "sim-exec", e0, e1)
    return t


def time_saving(baseline: float, ours: float) -> float:
    return (baseline - ours) / baseline


# ---------------------------------------------------------------------------
# live driver: runs the same schedule on a real ContextSwitchEngine
# ---------------------------------------------------------------------------

def run_schedule_live(engine, schedule: Sequence[Run], inputs: dict,
                      dynamic: bool = True, lookahead: int | None = 1,
                      settle: bool = False) -> dict:
    """Drive the real engine; returns measured wall/clock decomposition.

    dynamic=True  — preload upcoming contexts while the current one runs;
                    which ones (and which resident gets evicted) comes from
                    ``engine.policy`` — the same ``ReconfigPolicy`` object
                    ``simulate_dynamic`` runs, so the model and the
                    measurement execute literally the same decision code.
    dynamic=False — conventional: evict + blocking load on every change.

    ``lookahead`` bounds the prefetch window (None = policy default);
    ``settle`` waits for each preload before proceeding — decision points
    then happen in the same order as the simulator's, making the policy
    trace deterministic (used by the sim/live agreement tests; leave False
    for real overlap).
    """
    import time as _time
    t0 = _time.perf_counter()
    stalls = 0.0
    for i, r in enumerate(schedule):
        if not dynamic:
            # single-configuration FPGA: a net change always reloads.
            prev = engine.active.name if engine.active else None
            if prev != r.net:
                for name in list(engine.resident()):
                    if name != prev:
                        engine.evict(name)          # only the active stays
                ts = _time.perf_counter()
                engine.preload(r.net, block=True)
                stalls += _time.perf_counter() - ts
                engine.switch(r.net)
                if prev is not None:
                    engine.evict(prev)              # old config overwritten
        else:
            ts = _time.perf_counter()
            # quiescent point (previous run finished): the policy may
            # overwrite any slot, including the previously active one
            fut = engine.preload(r.net, allow_evict_active=True)
            if settle:
                fut.result()
            engine.switch(r.net, wait=True)  # stall only if load incomplete
            stalls += _time.perf_counter() - ts
            upcoming = [nxt.net for nxt in schedule[i + 1:]]
            for f in engine.prefetch(upcoming, limit=lookahead):
                if settle:                   # hidden behind run() otherwise
                    f.result()
        for _ in range(r.repeat):
            engine.run(*inputs[r.net])
    return {"total": _time.perf_counter() - t0, "visible_stalls": stalls}
