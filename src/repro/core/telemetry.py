"""Unified serving telemetry: metrics, lifecycle tracing, Perfetto export.

The paper's headline claim — reconfiguration time *hidden* behind
execution — is observational: it is only provable with a per-event
timeline of context loads overlapping decode.  Before this module every
serving layer kept its own ad-hoc accounting (``SlotPool.stats`` dicts,
``ServeStats`` dataclass, scheduler dicts, ``ContextSwitchEngine.stats``,
``time.perf_counter`` deltas in benches); this is the one measurement
layer they all share:

  * ``MetricRegistry`` — counters, gauges, and fixed-bucket histograms
    under one namespace.  The clock is injected (``clock=``), so the
    discrete-event simulator (virtual time) and the live engine (wall
    time) emit the SAME metric stream — ``simulate_dynamic(telemetry=)``
    writes the very counters (``ctx.loads``, ``ctx.load_seconds``,
    ``ctx.hidden_load_seconds``) the live ``ContextSwitchEngine`` writes.
  * ``MetricView`` — a dict-shaped window onto one registry namespace.
    Existing ``stats`` dict call-sites (engines, benches, tests) keep
    working verbatim while the registry is the single store.
  * ``Tracer`` — per-request lifecycle spans/events (submit → queued →
    admitted → prefill-chunk[i] → first-token → decode ticks → retire,
    plus context load/switch, prefix hit/CoW, page reclaim, spec rounds)
    in a bounded ring buffer.  Disabled (the default), every record call
    returns before allocating anything — near-zero overhead, gated by a
    test.
  * Chrome trace-event JSON export (``Tracer.chrome_trace`` /
    ``export``), viewable in Perfetto (https://ui.perfetto.dev): one
    track per context slot / pool slot, so a ``load:`` span on one track
    overlapping a ``run:`` span on another is the paper's hidden load,
    visually.  Spans carry the *exact* timestamps the engine's
    hidden-load accounting used, so the fraction recomputed from trace
    spans matches ``ContextSwitchEngine.hidden_load_fraction`` (tested
    to < 1%).

``Telemetry`` bundles one registry + one tracer + one clock and is what
components accept (``telemetry=``); ``scoped(prefix)`` hands a component
its own key namespace over the same store.  See docs/observability.md
for the metric glossary and span taxonomy — CI fails if a key is emitted
that the glossary does not document.
"""
from __future__ import annotations

import json
import time
from bisect import bisect_right
from collections import deque
from collections.abc import MutableMapping
from typing import Any, Callable, Optional

__all__ = ["LATENCY_BUCKETS_S", "Histogram", "ManualClock", "MetricRegistry",
           "MetricView", "Telemetry", "Tracer", "safe_ratio"]


def safe_ratio(num: float, den: float, default: float = 0.0) -> float:
    """``num / den`` with an explicit zero-denominator answer.  Every
    serving ratio (hidden-load fraction, steps/tick, acceptance rate,
    tok/s) routes through here so an early snapshot — taken before any
    load/tick/round happened — reports ``default`` instead of raising or
    propagating NaN into BENCH json."""
    return num / den if den else default


# Fixed buckets shared by every latency histogram (seconds).  Fixed — not
# adaptive — so histograms from different runs/machines/simulations merge
# bucket-for-bucket and BENCH diffs stay meaningful.
LATENCY_BUCKETS_S = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` is observations <=
    ``buckets[i]`` (last slot is the overflow).  Percentiles are the
    upper edge of the covering bucket — an upper bound, resolution
    bounded by the bucket grid (documented in docs/observability.md)."""

    __slots__ = ("buckets", "counts", "count", "total", "vmax")

    def __init__(self, buckets=LATENCY_BUCKETS_S):
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.total = 0.0
        self.vmax = 0.0

    def observe(self, v: float):
        self.counts[bisect_right(self.buckets, v)] += 1
        self.count += 1
        self.total += v
        if v > self.vmax:
            self.vmax = v

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket holding quantile ``q`` in [0, 1]
        (``vmax`` for the overflow bucket); 0.0 when empty."""
        if not self.count:
            return 0.0
        need = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= need and c:
                return self.buckets[i] if i < len(self.buckets) else self.vmax
        return self.vmax

    def summary(self) -> dict:
        return {"count": self.count,
                "sum": round(self.total, 6),
                "mean": round(safe_ratio(self.total, self.count), 6),
                "p50": self.percentile(0.50),
                "p99": self.percentile(0.99),
                "max": round(self.vmax, 6)}


class MetricRegistry:
    """Counters + gauges + histograms under one flat namespace.

    Values auto-register on first touch; ``doc`` strings ride along for
    the glossary check (every emitted key must appear in
    docs/observability.md — ``tools/check_metric_docs.py``).  The clock
    is injected so a simulator can drive the registry on virtual time.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._values: dict[str, float] = {}       # counters + gauges
        self._gauges: set[str] = set()
        self._hists: dict[str, Histogram] = {}
        self._docs: dict[str, str] = {}

    # ------------------------------------------------------------ scalars
    def inc(self, name: str, n=1, doc: str = ""):
        self._values[name] = self._values.get(name, 0) + n
        if doc and name not in self._docs:
            self._docs[name] = doc

    def set(self, name: str, v, doc: str = ""):
        self._values[name] = v
        if doc and name not in self._docs:
            self._docs[name] = doc

    def gauge(self, name: str, v, doc: str = ""):
        self._values[name] = v
        self._gauges.add(name)
        if doc and name not in self._docs:
            self._docs[name] = doc

    def value(self, name: str):
        return self._values[name]

    def __contains__(self, name: str) -> bool:
        return name in self._values or name in self._hists

    # --------------------------------------------------------- histograms
    def observe(self, name: str, v: float, buckets=LATENCY_BUCKETS_S,
                doc: str = ""):
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(buckets)
            if doc:
                self._docs[name] = doc
        h.observe(v)

    def histogram(self, name: str) -> Optional[Histogram]:
        return self._hists.get(name)

    # ------------------------------------------------------------ reports
    def keys(self) -> list[str]:
        """Every metric key this registry has emitted (scalar names +
        histogram names) — the set the docs glossary must cover."""
        return sorted(set(self._values) | set(self._hists))

    def snapshot(self) -> dict:
        """Flat scalars + per-histogram summaries, one dict."""
        out: dict[str, Any] = dict(self._values)
        for name, h in self._hists.items():
            out[name] = h.summary()
        return out

    def view(self, prefix: str = "") -> "MetricView":
        return MetricView(self, prefix)


class MetricView(MutableMapping):
    """Dict-shaped window onto one ``MetricRegistry`` namespace.

    ``engine.stats["host_ticks"] += 1`` and ``dict(engine.stats)`` keep
    working exactly as with the old per-engine dicts — but the values
    live in the shared registry under ``prefix + key``, so one snapshot
    call sees every layer.  Iteration covers the keys touched *through
    this view* (its local namespace), not the whole registry."""

    def __init__(self, registry: MetricRegistry, prefix: str = ""):
        self._reg = registry
        self._prefix = prefix
        self._names: dict[str, None] = {}         # insertion-ordered set

    def __getitem__(self, k: str):
        try:
            return self._reg.value(self._prefix + k)
        except KeyError:
            raise KeyError(k) from None

    def __setitem__(self, k: str, v):
        self._reg.set(self._prefix + k, v)
        self._names.setdefault(k)

    def __delitem__(self, k: str):
        del self._reg._values[self._prefix + k]
        self._names.pop(k, None)

    def __iter__(self):
        return iter(self._names)

    def __len__(self):
        return len(self._names)

    def __contains__(self, k) -> bool:
        return k in self._names


class ManualClock:
    """Settable clock for simulators and tests: ``clock()`` returns the
    last value given to ``advance``/``set`` — registry and tracer behave
    identically on virtual and wall time."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def set(self, t: float):
        self.t = t

    def advance(self, dt: float):
        self.t += dt


class Tracer:
    """Bounded ring buffer of lifecycle events, exportable as Chrome
    trace-event JSON (open at https://ui.perfetto.dev).

    Events are ``(track, name, ph, t0, dur, args)`` tuples with raw
    *clock-seconds* timestamps; tracks are free-form strings that become
    one Perfetto row each (``ctxslot0``, ``pool3``, ``sched``, ...).
    ``span`` takes explicit ``t0``/``t1`` so instrumentation can hand
    over the very timestamps its own accounting used (that is what makes
    the trace-derived hidden-load fraction match the engine's to < 1%).

    Disabled, ``span``/``instant`` return before touching anything —
    call sites in hot loops additionally guard ``if tracer.enabled:``
    before building f-string names or args dicts, so a disabled tracer
    costs one attribute test per record point (allocation-gated by
    ``tests/test_telemetry.py::test_disabled_tracer_allocates_nothing``).
    """

    __slots__ = ("enabled", "clock", "capacity", "_buf", "dropped")

    def __init__(self, capacity: int = 1 << 16,
                 clock: Callable[[], float] = time.perf_counter,
                 enabled: bool = False):
        self.enabled = enabled
        self.clock = clock
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)
        self.dropped = 0      # ring overwrites (capacity exceeded)

    def __len__(self) -> int:
        return len(self._buf)

    def clear(self):
        self._buf.clear()
        self.dropped = 0

    # ------------------------------------------------------------- record
    def instant(self, name: str, track: str, ts: Optional[float] = None,
                args: Optional[dict] = None):
        if not self.enabled:
            return
        if len(self._buf) == self.capacity:
            self.dropped += 1
        self._buf.append((track, name, "i",
                          self.clock() if ts is None else ts, 0.0, args))

    def span(self, name: str, track: str, t0: float, t1: float,
             args: Optional[dict] = None):
        if not self.enabled:
            return
        if len(self._buf) == self.capacity:
            self.dropped += 1
        self._buf.append((track, name, "X", t0, t1 - t0, args))

    # ------------------------------------------------------------- export
    def events(self) -> list[dict]:
        """Normalized copies (raw seconds) for programmatic checks."""
        return [{"track": tr, "name": nm, "ph": ph, "t0": t0, "dur": dur,
                 "args": args} for tr, nm, ph, t0, dur, args in self._buf]

    def chrome_trace(self, process_name: str = "repro-serve") -> dict:
        """Chrome trace-event JSON object.  ``ts``/``dur`` are
        microseconds relative to the earliest event (Perfetto renders
        absolute perf_counter epochs poorly); timestamps are NOT rounded
        so span arithmetic on the export reproduces the engine's float
        accounting."""
        evs = list(self._buf)
        base = min((e[3] for e in evs), default=0.0)
        tids = {tr: i + 1 for i, tr in
                enumerate(sorted({e[0] for e in evs}))}
        out: list[dict] = [{"name": "process_name", "ph": "M", "pid": 1,
                            "tid": 0, "args": {"name": process_name}}]
        for tr, tid in tids.items():
            out.append({"name": "thread_name", "ph": "M", "pid": 1,
                        "tid": tid, "args": {"name": tr}})
        for tr, nm, ph, t0, dur, args in evs:
            ev: dict[str, Any] = {"name": nm, "ph": ph, "cat": "serve",
                                  "pid": 1, "tid": tids[tr],
                                  "ts": (t0 - base) * 1e6}
            if ph == "X":
                ev["dur"] = dur * 1e6
            else:
                ev["s"] = "t"                 # instant scope: thread
            if args:
                ev["args"] = args
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str, process_name: str = "repro-serve") -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(process_name), f)
            f.write("\n")
        return path


class Telemetry:
    """One registry + one tracer + one clock, shared by every serving
    layer of a server.  ``scoped(prefix)`` returns a handle over the
    SAME store whose ``view()`` keys are namespaced — engines get
    ``eng.<i>.``, the context engine ``ctx.``, schedulers ``sched.`` —
    while histograms and root counters stay global (``observe``/``inc``
    ignore the prefix: a latency distribution spans engines by design).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 trace: bool = False, trace_capacity: int = 1 << 16,
                 registry: Optional[MetricRegistry] = None,
                 tracer: Optional[Tracer] = None, prefix: str = ""):
        self.clock = clock
        self.registry = (MetricRegistry(clock=clock) if registry is None
                         else registry)
        self.tracer = (Tracer(capacity=trace_capacity, clock=clock,
                              enabled=trace) if tracer is None else tracer)
        self.prefix = prefix

    def scoped(self, prefix: str) -> "Telemetry":
        return Telemetry(clock=self.clock, registry=self.registry,
                         tracer=self.tracer,
                         prefix=self.prefix + prefix)

    def view(self, sub: str = "") -> MetricView:
        """A stats view over this component's namespace."""
        return self.registry.view(self.prefix + sub)

    # Root-namespace conveniences: request-level histograms and counters
    # are deliberately unprefixed so every engine of a server feeds the
    # same distribution.
    def observe(self, name: str, v: float, doc: str = ""):
        self.registry.observe(name, v, doc=doc)

    def inc(self, name: str, n=1, doc: str = ""):
        self.registry.inc(name, n, doc=doc)
