"""Three-term roofline model from compiled dry-run artifacts.

Hardware constants (assignment-fixed, TPU v5e-class):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI

Terms (seconds for one lowered step, per device = per chip):
  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_moved_bytes_per_device / link_bw

``cost_analysis()`` and ``memory_analysis()`` on a partitioned executable
report per-device numbers; the collective bytes come from the HLO parse
(see analysis/hlo.py).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12         # bf16 / chip
    hbm_bw: float = 819e9              # B/s
    link_bw: float = 50e9              # B/s per ICI link
    hbm_bytes: float = 16e9            # v5e HBM capacity


V5E = HW()


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: HW = V5E) -> dict:
    compute = flops_per_dev / hw.peak_flops
    memory = bytes_per_dev / hw.hbm_bw
    collective = coll_bytes_per_dev / hw.link_bw
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms.update({
        "dominant": dom.replace("_s", ""),
        "bound_s": bound,
        # fraction of the bound that is useful compute — the score axis
        "roofline_fraction": compute / bound if bound > 0 else 0.0,
    })
    return terms


def model_flops(n_params_active: int, tokens: float,
                training: bool) -> float:
    """6ND for training, 2ND forward-only (prefill/decode)."""
    return (6.0 if training else 2.0) * n_params_active * tokens


def utilization(model_fl: float, hlo_fl_per_dev: float, n_dev: int) -> float:
    """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is 'useful'
    (catches remat recompute, dense-MoE waste, masked work)."""
    total_hlo = hlo_fl_per_dev * n_dev
    return model_fl / total_hlo if total_hlo else 0.0
