"""Analytic HBM-traffic / FLOP model of the Pallas flash-attention kernel.

The dry-run's jnp attention path materializes f32 score chains that the TPU
kernel keeps entirely in VMEM; the kernel-substituted roofline replaces the
measured attention-region HLO cost (isolated by compiling the model with
identity attention and diffing) with this model:

  forward  : read Q + K + V, write O;  grid skips tiles above the causal
             diagonal (or behind the window), so FLOPs ~= the masked half.
  backward : read Q,K,V,O,dO + write dQ,dK,dV; scores recomputed on-chip
             (flash backward), so HBM ~= 8/4 x forward tensors and FLOPs
             ~= 2.5x forward (dS via two extra matmuls).
  remat    : block remat recomputes the forward once more on the backward
             pass (+1x forward FLOPs and reads).
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig


def flash_attention_cost(cfg: ArchConfig, shape: ShapeConfig, n_devices: int,
                         training: bool, remat: bool = True) -> dict:
    """Per-device HBM bytes and FLOPs for all attention layers of one step."""
    n_attn = sum(1 for i in range(cfg.num_layers) if cfg.is_attention_layer(i))
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        # flash-decode: read the K/V cache once + q/o vectors
        S_kv = min(S, cfg.sliding_window) if cfg.sliding_window else S
        kv_bytes = 2 * B * cfg.num_kv_heads * S_kv * cfg.head_dim * 2
        qo_bytes = 2 * B * cfg.num_heads * cfg.head_dim * 2
        bytes_fwd = kv_bytes + qo_bytes
        flops = 2 * 2 * B * cfg.num_heads * S_kv * cfg.head_dim
        return {"bytes": n_attn * bytes_fwd / n_devices,
                "flops": n_attn * flops / n_devices}

    # train / prefill
    q_bytes = B * S * cfg.num_heads * cfg.head_dim * 2
    kv_bytes = 2 * B * S * cfg.num_kv_heads * cfg.head_dim * 2
    o_bytes = q_bytes
    fwd_bytes = q_bytes + kv_bytes + o_bytes
    # causal (or windowed) tile skipping halves the score work
    if cfg.sliding_window and cfg.sliding_window < S:
        frac = cfg.sliding_window / S
    else:
        frac = 0.5
    fwd_flops = 2 * 2 * B * cfg.num_heads * S * S * cfg.head_dim * frac

    if not training:
        return {"bytes": n_attn * fwd_bytes / n_devices,
                "flops": n_attn * fwd_flops / n_devices}
    bwd_bytes = 2 * fwd_bytes + o_bytes          # q,k,v,o,do + dq,dk,dv
    bwd_flops = 2.5 * fwd_flops
    remat_bytes = fwd_bytes if remat else 0
    remat_flops = fwd_flops if remat else 0
    return {"bytes": n_attn * (fwd_bytes + bwd_bytes + remat_bytes)
            / n_devices,
            "flops": n_attn * (fwd_flops + bwd_flops + remat_flops)
            / n_devices}
