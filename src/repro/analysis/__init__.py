from repro.analysis.hlo import collective_bytes, parse_collectives
from repro.analysis.roofline import roofline_terms, HW
