"""Post-SPMD HLO parsing: collective ops and their payload bytes.

``compiled.as_text()`` shapes are per-device (after partitioning), so summing
payloads gives per-device wire bytes — exactly the numerator of the
collective roofline term.

Moved-bytes model per op (ring algorithms, N peers):
  all-gather          ~ result_bytes            (each device receives it all)
  all-reduce          ~ 2 x payload             (reduce-scatter + all-gather)
  reduce-scatter      ~ max(operand) bytes
  all-to-all          ~ payload
  collective-permute  ~ payload
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# match op use like "= bf16[...] all-gather(" or "all-gather-start("
_OP_RE = re.compile(
    r"=\s+(?P<shape>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")

_MOVE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Returns {kind: {count, payload_bytes, moved_bytes}} per device."""
    out: dict = defaultdict(lambda: {"count": 0, "payload_bytes": 0,
                                     "moved_bytes": 0.0})
    seen_start: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        # async pairs: count the -start, skip the matching -done (done lines
        # don't match _OP_RE's open-paren-with-shape pattern for the same
        # op anyway, but guard by name)
        name = line.split("=")[0].strip()
        if name.endswith("-done") or ".done" in name:
            continue
        result_b = _bytes_of(m.group("shape"))
        # operand shapes: everything after the op's open paren
        operand_b = _bytes_of(line[m.end():])
        if kind == "all-gather":
            payload = result_b
        elif kind == "reduce-scatter":
            payload = max(operand_b, result_b)
        else:
            payload = max(result_b, operand_b if operand_b else result_b)
        rec = out[kind]
        rec["count"] += 1
        rec["payload_bytes"] += payload
        rec["moved_bytes"] += payload * _MOVE_FACTOR[kind]
    return dict(out)


def collective_bytes(hlo_text: str) -> tuple[float, dict]:
    """Total per-device moved bytes + the per-kind breakdown."""
    per = parse_collectives(hlo_text)
    return sum(r["moved_bytes"] for r in per.values()), per
