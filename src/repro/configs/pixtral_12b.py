"""pixtral-12b — pixtral-ViT + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409; unverified].

The pixtral ViT frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (dim 1024); the backbone projects and
prepends them to the text-token sequence.
"""
from repro.configs.base import ArchConfig, FrontendConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5_120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=131_072,
    head_dim=128,
    rope_theta=1_000_000.0,
    frontend=FrontendConfig(kind="vision_patches", embed_dim=1_024,
                            num_positions=256),
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)
