"""The paper's own application configs: Super-Sub cascade members (Fig 6a).

Small decoder/classifier-sized transformers: a generalist "super" network and
per-superclass "sub" specialists; sized to train on CPU in the examples while
exercising the full framework stack.
"""
from repro.configs.base import ArchConfig

_SUPER = ArchConfig(
    name="supersub-super",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=8,
    d_ff=1_024,
    vocab_size=512,
    tie_embeddings=True,
    source="paper Fig 6(a) generalist",
)

_SUB = ArchConfig(
    name="supersub-sub",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=8,
    d_ff=1_024,
    vocab_size=512,
    tie_embeddings=True,
    source="paper Fig 6(a) specialist",
)


def get(name: str) -> ArchConfig:
    return _SUPER if name.endswith("super") else _SUB
