"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                      # no separate FFN: mLSTM blocks carry gating
    vocab_size=50_304,
    head_dim=768 // 4,
    xlstm=XLSTMConfig(slstm_every=4, mlstm_expand=2, chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)
