"""mixtral-8x7b — 8 experts top-2, sliding-window attn [arXiv:2401.04088; hf]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=0,                      # all FFNs are MoE (d_ff_expert below)
    vocab_size=32_000,
    sliding_window=4_096,        # SWA => bounded KV => long_500k runnable
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14_336, every=1),
    source="arXiv:2401.04088; hf",
)
