"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4_096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=0,                      # all FFNs are MoE
    vocab_size=151_936,
    head_dim=128,                # qwen3 uses explicit head_dim (64*128 != d_model)
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1_536, every=1),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
