"""Config system: architecture + shape + run configs.

Plain dataclasses (constructed from dicts/JSON via the stdlib-only
``from_dict`` below so launchers can override any field from the CLI).  One
``ArchConfig`` per assigned architecture lives in ``repro/configs/<id>.py``;
the registry in ``repro/configs/__init__.py`` resolves ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import typing
from dataclasses import dataclass, field
from typing import Any, Optional

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0          # per-expert FFN hidden size
    every: int = 1                # MoE layer every `every` layers (jamba: 2)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM block parameters."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4          # one sLSTM block per `slstm_every` blocks
    mlstm_expand: int = 2         # mLSTM inner expansion
    chunk_size: int = 256         # chunkwise-parallel chunk length
    conv_width: int = 4


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB parameters (audio codec frames / vision patches).

    The frontend itself is not implemented (per assignment: ``input_specs()``
    provides precomputed frame/patch embeddings); this only sizes the stub
    inputs and the projection layer in the backbone.
    """
    kind: str = "none"            # none | audio_codec | vision_patches
    embed_dim: int = 0            # incoming precomputed-embedding dim
    num_positions: int = 0        # patches/frames prepended to the sequence


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # attention details
    rope_theta: float = 10_000.0
    sliding_window: int = 0       # 0 -> full attention
    attn_every: int = 1           # hybrid: attention layer every `attn_every`
                                  # layers (jamba: 8); others: 1
    # sub-family configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    mlp_gated: bool = True        # False -> 2-matrix GELU MLP (starcoder2)
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # citation per assignment
    source: str = ""

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    # ---- derived quantities ------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def is_attention_layer(self, i: int) -> bool:
        """Hybrid interleave: jamba puts attention at 1-of-`attn_every`."""
        if self.family != "hybrid":
            return True
        return i % self.attn_every == (self.attn_every // 2)

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.every) == (self.moe.every - 1)

    def is_slstm_layer(self, i: int) -> bool:
        if self.xlstm is None:
            return False
        return i % self.xlstm.slstm_every == (self.xlstm.slstm_every - 1)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: recurrent state or bounded (sliding) KV."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    # ---- parameter counting (for roofline MODEL_FLOPS) ---------------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; `active_only` counts top-k experts only."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        for i in range(L):
            n += 2 * d                                # norms
            if self.family == "ssm" and self.xlstm is not None:
                n += self._xlstm_block_params(i)
                continue
            if self.is_attention_layer(i):
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            elif self.ssm is not None:                # mamba block
                n += self._mamba_block_params()
            if self.is_moe_layer(i):
                m = self.moe
                experts = m.top_k if active_only else m.num_experts
                n += d * m.num_experts                # router (always live)
                n += experts * (3 * d * m.d_ff_expert)
            elif self.d_ff > 0:
                n += (3 if self.mlp_gated else 2) * d * self.d_ff
        return n

    def _mamba_block_params(self) -> int:
        s = self.ssm
        d_in = s.expand * self.d_model
        dt_rank = s.dt_rank or -(-self.d_model // 16)
        n = self.d_model * 2 * d_in                  # in_proj (x, z)
        n += d_in * s.d_conv                          # conv
        n += d_in * (dt_rank + 2 * s.d_state)         # x -> dt, B, C
        n += dt_rank * d_in                           # dt proj
        n += d_in * s.d_state + d_in                  # A_log, D
        n += d_in * self.d_model                      # out proj
        return n

    def _xlstm_block_params(self, i: int) -> int:
        x = self.xlstm
        d = self.d_model
        if self.is_slstm_layer(i):
            # sLSTM: 4 gates (i,f,z,o) from input + recurrent, + gated FFN 4/3
            h = d
            n = 8 * d * h
            dff = int(4 * d * 2 / 3)
            n += 3 * d * dff
            return n
        d_in = x.mlstm_expand * d
        n = d * 2 * d_in                              # up proj (x, z)
        n += 3 * d_in * d_in // 1                     # q,k,v projections
        n += d_in * x.conv_width                      # causal conv
        n += 3 * d_in                                 # i,f,o gate biases/proj
        n += d_in * d                                 # down proj
        return n


# ---------------------------------------------------------------------------
# Input-shape cells (assignment-fixed)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(arch: "ArchConfig", shape: ShapeConfig) -> tuple[bool, str]:
    """Applicability of a (arch x shape) cell, per DESIGN.md skip rules."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, ("pure full-attention arch: 500k dense-KV decode skipped "
                       "(sub-quadratic attention required; see DESIGN.md)")
    return True, ""


# ---------------------------------------------------------------------------
# Run config (training/serving hyperparams; not part of the arch identity)
# ---------------------------------------------------------------------------

@dataclass
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"      # cosine | linear | constant


@dataclass
class ParallelConfig:
    dp: int = 1                   # data axis
    tp: int = 1                   # model axis
    pods: int = 1                 # pod axis (pure DP over DCN)
    fsdp: bool = True             # shard params over the data axis
    seq_shard_kv: bool = False    # decode SP: shard KV seq over model axis
    grad_compression: str = "none"   # none | int8_ef
    microbatches: int = 1         # gradient accumulation
    remat: str = "none"           # none | full | dots
    cast_bf16: bool = False       # cast f32 master params to bf16 pre-gather


@dataclass
class RunConfig:
    arch: str = "tinyllama-1.1b"
    shape: str = "train_4k"
    seed: int = 0
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
    use_pallas: bool = False      # True on TPU; CPU paths use the jnp ref


def _unwrap_optional(tp):
    """Optional[X] -> (X, True); anything else -> (tp, False)."""
    if typing.get_origin(tp) is typing.Union:
        args = [a for a in typing.get_args(tp) if a is not type(None)]
        if len(args) == 1:
            return args[0], True
    return tp, False


def _build(tp, value, path: str):
    """Recursively construct `tp` from plain dicts/lists (stdlib only).

    Strict: unknown dataclass keys raise, like dacite's strict mode did
    (typos in CLI/JSON overrides must not pass silently)."""
    tp, is_opt = _unwrap_optional(tp)
    if value is None:
        if is_opt:
            return None
        raise ValueError(f"{path}: None not allowed for {tp!r}")
    if dataclasses.is_dataclass(tp):
        if dataclasses.is_dataclass(value):        # already constructed
            return value
        if not isinstance(value, dict):
            raise TypeError(f"{path}: expected dict for {tp.__name__}, "
                            f"got {type(value).__name__}")
        hints = typing.get_type_hints(tp)
        names = {f.name for f in dataclasses.fields(tp) if f.init}
        unknown = set(value) - names
        if unknown:
            raise ValueError(
                f"{path}: unknown key(s) {sorted(unknown)} for {tp.__name__}")
        kwargs = {k: _build(hints[k], v, f"{path}.{k}")
                  for k, v in value.items()}
        return tp(**kwargs)
    origin = typing.get_origin(tp)
    if origin in (list, tuple):
        if not isinstance(value, (list, tuple)):
            raise TypeError(f"{path}: expected a sequence for {tp!r}, "
                            f"got {type(value).__name__}")
        args = typing.get_args(tp) or (Any,)
        built = [_build(args[0], v, f"{path}[{i}]")
                 for i, v in enumerate(value)]
        return tuple(built) if origin is tuple else built
    if origin is dict:
        if not isinstance(value, dict):
            raise TypeError(f"{path}: expected a dict for {tp!r}, "
                            f"got {type(value).__name__}")
        _, vt = typing.get_args(tp) or (Any, Any)
        return {k: _build(vt, v, f"{path}[{k!r}]") for k, v in value.items()}
    if tp is float and isinstance(value, int) and not isinstance(value, bool):
        return float(value)                        # JSON has no int/float split
    if (origin is None and isinstance(tp, type) and tp is not Any
            and not isinstance(value, tp)):
        raise TypeError(f"{path}: expected {tp.__name__}, "
                        f"got {type(value).__name__}")
    return value


def from_dict(cls, d: dict[str, Any]):
    return _build(cls, d, cls.__name__)


def override(cfg, **kw):
    """Functional override for (frozen) dataclasses."""
    return dataclasses.replace(cfg, **kw)
