"""Architecture registry: ``--arch <id>`` resolution.

Each assigned architecture has its own module with the exact assignment
config; ``get_arch`` / ``list_archs`` are the public API.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, XLSTMConfig, FrontendConfig,
    ShapeConfig, SHAPES, RunConfig, OptimizerConfig, ParallelConfig,
    cell_is_runnable, from_dict, override,
)

_ARCH_MODULES = {
    "xlstm-125m": "xlstm_125m",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "tinyllama-1.1b": "tinyllama_11b",
    "starcoder2-7b": "starcoder2_7b",
    "deepseek-7b": "deepseek_7b",
    "musicgen-medium": "musicgen_medium",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "mixtral-8x7b": "mixtral_8x7b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "pixtral-12b": "pixtral_12b",
    # the paper's own application config (Super-Sub cascade members)
    "supersub-super": "supersub",
    "supersub-sub": "supersub",
}

ASSIGNED_ARCHS = [k for k in _ARCH_MODULES if not k.startswith("supersub")]


def get_arch(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.get(name) if hasattr(mod, "get") else mod.CONFIG


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def reduced(cfg: ArchConfig, **extra) -> ArchConfig:
    """A smoke-test-sized config of the same family (CPU-runnable)."""
    period = 1
    if cfg.xlstm is not None:
        period = cfg.xlstm.slstm_every
    elif cfg.family == "hybrid":
        import math
        period = math.lcm(cfg.attn_every,
                          cfg.moe.every if cfg.moe else 1)
    kw = dict(
        num_layers=min(cfg.num_layers, max(2, period)),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=256,
    )
    if cfg.moe is not None:
        kw["moe"] = override(cfg.moe, num_experts=4,
                             top_k=min(cfg.moe.top_k, 2), d_ff_expert=64)
    if cfg.ssm is not None:
        kw["ssm"] = override(cfg.ssm, d_state=8)
    if cfg.xlstm is not None:
        kw["xlstm"] = override(cfg.xlstm, chunk_size=16)
    if cfg.frontend.kind != "none":
        kw["frontend"] = override(cfg.frontend, embed_dim=64, num_positions=4)
    kw.update(extra)
    return override(cfg, name=cfg.name + "-reduced", **kw)
