"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

The EnCodec audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed codec-frame token ids / embeddings; only the transformer
backbone is modeled.
"""
from repro.configs.base import ArchConfig, FrontendConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1_536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6_144,
    vocab_size=2_048,
    frontend=FrontendConfig(kind="audio_codec", embed_dim=0, num_positions=0),
    source="arXiv:2306.05284; hf",
)
