"""jamba-v0.1-52b — Mamba+attn 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887; hf].

Jamba period-8 block: attention at 1 of 8 layers (the rest Mamba);
MoE MLP every other layer (period 2).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,                 # dense-MLP layers (non-MoE positions)
    vocab_size=65_536,
    attn_every=8,                # 1 attention layer per 8 (1:7 with mamba)
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14_336, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887; hf",
)
