"""starcoder2-7b — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4_608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18_432,
    vocab_size=49_152,
    rope_theta=1_000_000.0,
    mlp_gated=False,             # starcoder2 uses a 2-matrix GELU MLP
    source="arXiv:2402.19173; hf",
)
