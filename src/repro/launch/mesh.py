"""Production mesh factory (assignment-fixed shapes).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_mesh_auto(shape, axes):
    """jax.make_mesh with Auto axis types where the API exists (the
    ``axis_types`` kwarg and ``AxisType`` arrived after 0.4; older
    releases are Auto-only, so omitting it is equivalent)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)
