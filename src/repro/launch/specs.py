"""``input_specs()`` — ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero device allocation: the dry-run lowers
train/prefill/serve steps directly from these.  Modality frontends are STUBS
per the assignment: the VLM gets precomputed patch embeddings, the audio arch
gets codec-token ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, ParallelConfig
from repro.distributed.sharding import (
    DEFAULT_RULES, ShardingRules, shard_params_tree)
from repro.models.model import LM


def decode_rules(cfg: ArchConfig, mesh: Mesh,
                 base: ShardingRules = DEFAULT_RULES) -> ShardingRules:
    """KV-cache sharding: heads->model when they divide the axis, else
    sequence->model (SP decode; required for kv=4 archs on a 16-wide axis —
    qwen3's 32k cache would not fit HBM otherwise)."""
    tp = mesh.shape.get("model", 1)
    if cfg.num_kv_heads % tp == 0:
        return base.with_(kv_heads="model", kv_seq=None, kv_pages=None)
    return base.with_(kv_heads=None, kv_seq="model", kv_pages="model")


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def fit_batch_axes(mesh: Mesh, batch: int) -> tuple[str, ...]:
    """Batch mesh axes whose product divides `batch` (long_500k has B=1)."""
    axes = []
    size = 1
    for a in ("pod", "data"):
        if a in mesh.shape and batch % (size * mesh.shape[a]) == 0:
            axes.append(a)
            size *= mesh.shape[a]
    return tuple(axes)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                rules: ShardingRules = DEFAULT_RULES) -> dict:
    """Abstract inputs for the given (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    baxes = fit_batch_axes(mesh, B)
    bspec = (baxes,) if baxes else (None,)
    out: dict = {}
    n_patch = (cfg.frontend.num_positions
               if cfg.frontend.kind == "vision_patches" else 0)
    if shape.kind in ("train", "prefill"):
        s_text = S - n_patch
        out["tokens"] = _sds((B, s_text), jnp.int32, mesh,
                             P(*bspec, None))
        if n_patch:
            out["patch_embeds"] = _sds(
                (B, n_patch, cfg.frontend.embed_dim), jnp.bfloat16, mesh,
                P(*bspec, None, None))
    else:                                     # decode: one new token
        out["tokens"] = _sds((B, 1), jnp.int32, mesh, P(*bspec, None))
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


def abstract_sharded_params(model: LM, mesh: Mesh, rules: ShardingRules,
                            dtype) -> dict:
    specs = model.abstract(dtype)
    sh = shard_params_tree(mesh, specs, model.logical(), rules)
    return jax.tree.map(
        lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
        specs, sh)


def abstract_sharded_cache(model: LM, mesh: Mesh, rules: ShardingRules,
                           batch: int, max_len: int):
    cache = model.init_cache(batch, max_len, abstract=True)
    logical = model.cache_logical()
    sh = shard_params_tree(mesh, cache, logical, rules)
    return jax.tree.map(
        lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
        cache, sh)


def abstract_sharded_paged_cache(model: LM, mesh: Mesh, rules: ShardingRules,
                                 batch: int, max_len: int, page: int):
    bigs, acts = model.init_paged_cache(batch, max_len, page, abstract=True)
    lb, la = model.paged_cache_logical()

    def place(tree, logical):
        sh = shard_params_tree(mesh, tree, logical, rules)
        return jax.tree.map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
            tree, sh)

    return place(bigs, lb), place(acts, la)


def default_parallel(cfg: ArchConfig, shape: ShapeConfig) -> ParallelConfig:
    """Baseline per-cell parallel knobs (the paper-faithful starting point)."""
    n = cfg.param_count_cached if hasattr(cfg, "param_count_cached") else None
    big = cfg.num_layers * cfg.d_model * cfg.d_model
    p = ParallelConfig()
    if shape.kind == "train":
        big = cfg.moe is not None or cfg.d_model >= 4_000
        p.microbatches = 8 if big else 4
        # Block remat is the production default at this scale: without it
        # the backward pass stores every attention-score residual
        # (O(S^2) per layer) and no 4k-seq cell fits 16 GB HBM.
        p.remat = "full"
    return p
