import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
# The dry-run — and ONLY the dry-run — runs with 512 placeholder host
# devices so the production meshes (16x16 and 2x16x16) can be built.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (single-pod 16x16 = 256 chips, or
     multi-pod 2x16x16 = 512 chips),
  2. lowers the cell's step function (train_step / prefill / serve_step)
     from ShapeDtypeStruct stand-ins (zero device allocation),
  3. compiles it (SPMD partitioning succeeds == the distribution config is
     coherent: no sharding mismatch, no unsupported collective),
  4. records memory_analysis() (proves it fits), cost_analysis() FLOPs/bytes,
     and the collective-byte breakdown parsed from the optimized HLO,
  5. extrapolates full-depth FLOPs/collective bytes from two reduced-depth
     *unrolled* compiles (XLA's cost model visits a while-loop body once, so
     the scanned full-depth program under-counts by ~num_layers; the
     two-point fit recovers the true totals including the embed/head
     intercept),
  6. writes one JSON per cell into --out (benchmarks/roofline reads these).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
  python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b --shape decode_32k \
      --rule kv_seq=model --tag sp_decode      # hillclimb variant
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import model_flops, roofline_terms, utilization
from repro.configs import (
    ASSIGNED_ARCHS, SHAPES, cell_is_runnable, get_arch, override)
from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.distributed.mesh import AXIS_MODEL as AXIS_MODEL_NAME
from repro.distributed.sharding import DEFAULT_RULES, ShardingRules
from repro.launch.mesh import make_mesh_auto, make_production_mesh
from repro.launch.specs import (
    abstract_sharded_cache, abstract_sharded_params, decode_rules,
    default_parallel, input_specs)
from repro.models.model import build_model
from repro.train.trainer import make_train_step


# ---------------------------------------------------------------------------
# step-function construction per shape kind
# ---------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, rules: ShardingRules,
               *, metrics_depth: int | None = None, run_cfg: RunConfig | None
               = None, moe_strategy: str = "auto",
               embed_onehot: bool = False, paged: int = 0,
               attn_identity: bool = False):
    """Returns (jitted_fn, example_args: tuple) ready to .lower()."""
    if metrics_depth is not None:
        period = len(build_model(cfg).pattern)
        cfg = override(cfg, num_layers=period * metrics_depth)
    import copy
    if run_cfg is None:
        run_cfg = RunConfig(arch=cfg.name, shape=shape.name,
                            parallel=default_parallel(cfg, shape))
    if metrics_depth is not None:
        run_cfg = copy.deepcopy(run_cfg)
        run_cfg.parallel.microbatches = 1   # see module docstring step 5

    if shape.kind == "decode":
        rules = decode_rules(cfg, mesh, rules) if rules is DEFAULT_RULES \
            else rules
    if cfg.moe is not None and \
            cfg.moe.num_experts % mesh.shape.get("model", 1) != 0:
        # mixtral (8e) on a 16-wide model axis: experts cannot shard the
        # axis; replicate experts and TP-shard the expert FFN dim instead
        # (dense dispatch; the top-k waste shows up in useful_fraction).
        rules = rules.with_(experts=None, expert_ffn=AXIS_MODEL_NAME)
    model = build_model(cfg, mesh=mesh, rules=rules,
                        moe_strategy=moe_strategy,
                        embed_onehot=embed_onehot,
                        attn_identity=attn_identity,
                        scan_unroll=metrics_depth is not None)

    inputs = input_specs(cfg, shape, mesh, rules)

    if shape.kind == "train":
        step = make_train_step(model, run_cfg, mesh)
        params = abstract_sharded_params(model, mesh, rules,
                                         jnp.dtype(cfg.param_dtype))
        opt_leaf = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                                  sharding=p.sharding)
        state = {"params": params,
                 "opt": {"m": jax.tree.map(opt_leaf, params),
                         "v": jax.tree.map(opt_leaf, params),
                         "count": jax.ShapeDtypeStruct((), jnp.int32)},
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
        batch = {"tokens": inputs["tokens"]}
        if "patch_embeds" in inputs:
            batch["patch_embeds"] = inputs["patch_embeds"]
        return jax.jit(step, donate_argnums=(0,)), (state, batch)

    # serving: bf16 params (inference residency, paper's context = weights)
    params = abstract_sharded_params(model, mesh, rules, jnp.bfloat16)

    if shape.kind == "prefill":
        max_len = shape.seq_len

        def prefill_fn(params, tokens, patch_embeds=None):
            if patch_embeds is not None:
                return model.prefill(params, tokens, max_len,
                                     patch_embeds=patch_embeds)
            return model.prefill(params, tokens, max_len)

        args = (params, inputs["tokens"])
        if "patch_embeds" in inputs:
            args = args + (inputs["patch_embeds"],)
        return jax.jit(prefill_fn), args

    # decode: serve_step — one new token against a seq_len cache
    if paged:
        from repro.launch.specs import abstract_sharded_paged_cache
        bigs, acts = abstract_sharded_paged_cache(
            model, mesh, rules, shape.global_batch, shape.seq_len, paged)

        def serve_step_paged(params, bigs, acts, tokens, pos):
            return model.decode_step_paged(params, bigs, acts, tokens, pos)

        # only the active pages are donated; `bigs` is read-only residency
        return (jax.jit(serve_step_paged, donate_argnums=(2,)),
                (params, bigs, acts, inputs["tokens"], inputs["pos"]))

    caches = abstract_sharded_cache(model, mesh, rules,
                                    shape.global_batch, shape.seq_len)

    def serve_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)

    return (jax.jit(serve_step, donate_argnums=(1,)),
            (params, caches, inputs["tokens"], inputs["pos"]))


# ---------------------------------------------------------------------------
# metrics extraction
# ---------------------------------------------------------------------------

def _cost(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
        if isinstance(c, list):
            c = c[0]
        return dict(c)
    except Exception as e:            # pragma: no cover
        return {"error": repr(e)}


def _memory(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")
        return {k: int(getattr(m, k)) for k in keys if hasattr(m, k)}
    except Exception as e:            # pragma: no cover
        return {"error": repr(e)}


def _arg_bytes_per_device(args, mesh) -> int:
    """Analytic per-device residency of the step's inputs (params+cache+data).

    CPU memory_analysis does not model the 512-device partition; shard sizes
    from the NamedShardings are exact."""
    ndev = mesh.size
    total = 0
    for leaf in jax.tree.leaves(args):
        if not hasattr(leaf, "shape"):
            continue
        n = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        sh = getattr(leaf, "sharding", None)
        if isinstance(sh, NamedSharding):
            spec = sh.spec
            denom = 1
            for dim_ax in spec:
                if dim_ax is None:
                    continue
                axes = (dim_ax,) if isinstance(dim_ax, str) else dim_ax
                for a in axes:
                    denom *= mesh.shape[a]
            n //= denom
        total += n
    return total


def compile_cell(fn, args) -> tuple:
    t0 = time.perf_counter()
    lowered = fn.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return lowered, compiled, t1 - t0, t2 - t1


def measure_cell(cfg: ArchConfig, shape: ShapeConfig, mesh_kind: str,
                 rules: ShardingRules, *, metrics_depths=(1, 2),
                 moe_strategy: str = "auto", skip_metrics: bool = False,
                 run_cfg: RunConfig | None = None,
                 embed_onehot: bool = False, paged: int = 0,
                 mesh_shape: tuple | None = None,
                 kernel_subst: bool = False) -> dict:
    if mesh_shape is not None:
        # same 256 chips, different logical split (hillclimb variant):
        # e.g. (32, 8) gives an 8-wide model axis = mixtral's expert count.
        mesh = make_mesh_auto(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: dict = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_kind,
                 "chips": int(mesh.size)}

    with mesh:
        # -- full-depth compile: the runnability/memory proof ---------------
        fn, args = build_cell(cfg, shape, mesh, rules, run_cfg=run_cfg,
                              moe_strategy=moe_strategy,
                              embed_onehot=embed_onehot, paged=paged)
        lowered, compiled, t_low, t_comp = compile_cell(fn, args)
        rec["lower_s"], rec["compile_s"] = round(t_low, 2), round(t_comp, 2)
        rec["memory_analysis"] = _memory(compiled)
        rec["arg_bytes_per_device"] = _arg_bytes_per_device(args, mesh)
        rec["cost_scanned"] = {k: v for k, v in _cost(compiled).items()
                               if k in ("flops", "bytes accessed")}
        coll_full, per_kind_full = collective_bytes(compiled.as_text())
        rec["collectives_scanned"] = {
            "moved_bytes": coll_full,
            "per_kind": {k: v["count"] for k, v in per_kind_full.items()}}
        del compiled, lowered

        if skip_metrics:
            return rec

        # -- two-point depth extrapolation (unrolled reduced-depth) ---------
        period = len(build_model(cfg).pattern)
        repeats_full = cfg.num_layers // period
        pts = []
        pts_id = []
        for r in metrics_depths:
            r = min(r, repeats_full)
            variants = [(False, pts)] + ([(True, pts_id)] if kernel_subst
                                         else [])
            for ident, sink in variants:
                fn_r, args_r = build_cell(cfg, shape, mesh, rules,
                                          metrics_depth=r,
                                          run_cfg=run_cfg,
                                          moe_strategy=moe_strategy,
                                          embed_onehot=embed_onehot,
                                          paged=paged, attn_identity=ident)
                lo, co, _, _ = compile_cell(fn_r, args_r)
                cost = _cost(co)
                coll, per_kind = collective_bytes(co.as_text())
                sink.append({"repeats": r, "flops": cost.get("flops", 0.0),
                             "bytes": cost.get("bytes accessed", 0.0),
                             "coll": coll, "per_kind": per_kind})
                del co, lo
            if r == repeats_full:
                break

        def fit(key):
            if len(pts) == 1 or pts[0]["repeats"] == pts[-1]["repeats"]:
                return float(pts[-1][key])
            (p1, p2) = pts[0], pts[-1]
            slope = (p2[key] - p1[key]) / (p2["repeats"] - p1["repeats"])
            c0 = p1[key] - slope * p1["repeats"]
            return float(c0 + slope * repeats_full)

        flops = fit("flops")
        byts = fit("bytes")
        coll = fit("coll")
        rec["extrapolated"] = {
            "repeats_points": [p["repeats"] for p in pts],
            "flops_per_device": flops, "bytes_per_device": byts,
            "collective_moved_bytes_per_device": coll,
            "collective_per_kind_at_depth": {
                k: {"count": v["count"],
                    "moved_bytes": v["moved_bytes"]}
                for k, v in pts[-1]["per_kind"].items()},
        }

        # -- kernel-substituted terms (Pallas flash attention on TPU) -------
        if kernel_subst and pts_id:
            from repro.analysis.kernelcost import flash_attention_cost

            def fit_from(pp, key):
                if len(pp) == 1 or pp[0]["repeats"] == pp[-1]["repeats"]:
                    return float(pp[-1][key])
                p1, p2 = pp[0], pp[-1]
                sl = (p2[key] - p1[key]) / (p2["repeats"] - p1["repeats"])
                return float(p1[key] - sl * p1["repeats"]
                             + sl * repeats_full)

            kc = flash_attention_cost(
                cfg, shape, mesh.size, training=(shape.kind == "train"),
                remat=(run_cfg is None or
                       run_cfg.parallel.remat != "none"))
            f_id = fit_from(pts_id, "flops")
            b_id = fit_from(pts_id, "bytes")
            adj_f = f_id + kc["flops"]
            adj_b = b_id + kc["bytes"]
            t_adj = roofline_terms(adj_f, adj_b, fit_from(pts, "coll"))
            rec["kernel_substituted"] = {
                "flops_per_device": adj_f, "bytes_per_device": adj_b,
                "attn_region_bytes_measured":
                    fit_from(pts, "bytes") - b_id,
                "flash_kernel_bytes": kc["bytes"],
                **t_adj}

        # -- roofline ---------------------------------------------------------
        terms = roofline_terms(flops, byts, coll)
        n_active = cfg.param_count(active_only=True)
        tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                       else (shape.seq_len if shape.kind ==
                                             "prefill" else 1))
        mf = model_flops(n_active, tokens, training=(shape.kind == "train"))
        terms["model_flops"] = mf
        terms["useful_fraction"] = utilization(mf, flops, mesh.size)
        rec["roofline"] = terms
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def parse_rules(pairs: list[str]) -> ShardingRules:
    rules = DEFAULT_RULES
    for p in pairs:
        k, _, v = p.partition("=")
        axis = None if v in ("", "none", "None") else \
            (tuple(v.split("+")) if "+" in v else v)
        rules = rules.with_(**{k: axis})
    return rules


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="every runnable (arch x shape) cell")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="", help="suffix for variant runs")
    ap.add_argument("--rule", action="append", default=[],
                    metavar="logical=mesh_axis",
                    help="sharding-rule override (hillclimb knob)")
    ap.add_argument("--moe-strategy", default="auto",
                    choices=("auto", "ep", "tp", "ref"))
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--cast-bf16", action="store_true",
                    help="bf16-cast master params before the FSDP gather")
    ap.add_argument("--remat", default=None, choices=("none", "full", "dots"))
    ap.add_argument("--embed-onehot", action="store_true",
                    help="one-hot matmul embedding (vs gather)")
    ap.add_argument("--paged", type=int, default=0, metavar="PAGE",
                    help="paged decode cache with this page size")
    ap.add_argument("--mesh-shape", default=None, metavar="DxM",
                    help="alternate (data, model) split of the 256 chips")
    ap.add_argument("--kernel-subst", action="store_true",
                    help="also report the Pallas-flash-substituted roofline")
    ap.add_argument("--skip-metrics", action="store_true",
                    help="compile proof only (no roofline extrapolation)")
    args = ap.parse_args(argv)

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                if cell_is_runnable(get_arch(a), SHAPES[s])[0]:
                    cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    rules = parse_rules(args.rule)
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch_name, shape_name in cells:
        cfg = get_arch(arch_name)
        shape = SHAPES[shape_name]
        ok, why = cell_is_runnable(cfg, shape)
        if not ok:
            print(f"SKIP {arch_name} x {shape_name}: {why}")
            continue
        run_cfg = RunConfig(arch=cfg.name, shape=shape.name,
                            parallel=default_parallel(cfg, shape))
        if args.microbatches is not None:
            run_cfg.parallel.microbatches = args.microbatches
        if args.remat is not None:
            run_cfg.parallel.remat = args.remat
        if args.cast_bf16:
            run_cfg.parallel.cast_bf16 = True
        for mesh_kind in meshes:
            key = f"{arch_name}_{shape_name}_{mesh_kind}"
            if args.tag:
                key += f"_{args.tag}"
            t0 = time.perf_counter()
            try:
                rec = measure_cell(cfg, shape, mesh_kind, rules,
                                   moe_strategy=args.moe_strategy,
                                   skip_metrics=(args.skip_metrics or
                                                 mesh_kind == "multi"),
                                   run_cfg=run_cfg,
                                   embed_onehot=args.embed_onehot,
                                   paged=args.paged,
                                   mesh_shape=(tuple(
                                       int(v) for v in
                                       args.mesh_shape.split("x"))
                                       if args.mesh_shape else None),
                                   kernel_subst=args.kernel_subst)
                rec["variant"] = {"tag": args.tag, "rules": args.rule,
                                  "embed_onehot": args.embed_onehot,
                                  "paged": args.paged,
                                  "mesh_shape": args.mesh_shape,
                                  "moe_strategy": args.moe_strategy,
                                  "microbatches": run_cfg.parallel.microbatches,
                                  "remat": run_cfg.parallel.remat}
                path = os.path.join(args.out, key + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                dom = rec.get("roofline", {}).get("dominant", "-")
                print(f"OK   {key}  compile={rec['compile_s']}s "
                      f"dominant={dom}  "
                      f"[{time.perf_counter() - t0:.1f}s]")
            except Exception:
                failures += 1
                print(f"FAIL {key}")
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
