"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs on whatever devices exist (1 CPU device in dev; the production pods via
the same code path — the mesh shape is the only difference).  ``--reduced``
trains the smoke-scale variant of the arch; the full configs are
dry-run-only on CPU.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax

from repro.configs import get_arch, reduced as make_reduced
from repro.configs.base import RunConfig, OptimizerConfig, ParallelConfig
from repro.distributed.mesh import make_mesh
from repro.models.model import build_model
from repro.train.data import SyntheticTokens
from repro.train.trainer import Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--dp", type=int, default=0, help="0 = all devices")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=("none", "full"))
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    dp = args.dp or max(len(jax.devices()) // args.tp, 1)
    mesh = make_mesh((dp, args.tp), ("data", "model"))

    run_cfg = RunConfig(
        arch=cfg.name, shape="custom", seed=args.seed,
        optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps,
                                  warmup_steps=max(args.steps // 10, 1)),
        parallel=ParallelConfig(dp=dp, tp=args.tp,
                                microbatches=args.microbatches,
                                remat=args.remat),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        log_every=args.log_every)

    model = build_model(cfg, mesh=mesh)
    patch = ((cfg.frontend.num_positions, cfg.frontend.embed_dim)
             if cfg.frontend.kind == "vision_patches" else None)
    data = SyntheticTokens(cfg.vocab_size, args.seq, args.batch,
                           seed=args.seed, patch_spec=patch)
    trainer = Trainer(model, run_cfg, data, mesh=mesh)

    state = trainer.init_or_restore(jax.random.key(args.seed))
    n = model.n_params()
    print(f"arch={cfg.name} params={n/1e6:.1f}M devices={dp}x{args.tp} "
          f"start_step={trainer.start_step}")
    t0 = time.perf_counter()
    state = trainer.train(state, args.steps,
                          log_cb=lambda m: print(json.dumps(m)))
    dt = time.perf_counter() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s, "
          f"final loss {trainer.metrics_log[-1]['loss']:.4f}"
          if trainer.metrics_log else f"done in {dt:.1f}s")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(trainer.metrics_log, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
