"""Serving launcher: context-switching inference over N registered models.

``python -m repro.launch.serve --archs supersub-super,supersub-sub --steps 4``

Four modes:

  * ``--mode queue`` (default) — the async ``SwitchScheduler``: requests
    for all models are submitted up front; the scheduler coalesces
    same-model requests into streaks, ranks the next model by queue
    pressure + load cost, and streams it into the shadow slot while the
    active streak executes.  Reports throughput, p50/p99 latency, and the
    hidden-load fraction.
  * ``--mode continuous`` — the token-granular ``ContinuousScheduler``:
    requests join/leave a persistent slot-pooled step engine at every
    decode step; context choice is re-decided at step boundaries and the
    next context streams into the shadow slot behind the remaining steps
    (``--pool`` sets the slot-pool width).  ``--paged --page-size N``
    swaps each context's row-granular KV pool for the paged slot pool:
    per-slot page tables over one shared page bank, so a request only
    holds the pages its own length needs.  ``--multi-step T`` fuses up
    to T decode steps per tick (host bookkeeping amortizes over T
    tokens); ``--quantize-kv int8`` stores the page bank in int8 for
    ~2x pages per HBM budget; ``--prefix-cache`` shares already-written
    prompt pages across admissions (refcounted, copy-on-write), so a
    cache-hit prompt prefills only its divergent suffix.
  * ``--mode speculative`` — continuous batching with speculative cascade
    decode: ``--draft NAME`` names the draft context; every other
    registered context becomes a verify target whose requests run on a
    ``SpecEngine`` (draft proposes ``--spec-k`` tokens per round, the
    target scores them in one multi-token verify pass).  Draft/target
    hand-offs are O(1) select flips with the other side prefetched into
    the shadow slot — the paper's Super-Sub cascade as a serving mode.
  * ``--mode sync``  — the old synchronous round-robin driver (worst case
    for switching; kept as the baseline the paper compares against).

Both route every slot/eviction/prefetch decision through the shared
``ReconfigPolicy`` — there is no scheduling logic in this file.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced as make_reduced
from repro.models.model import build_model
from repro.serve.scheduler import ContinuousScheduler, SwitchScheduler
from repro.serve.switching import ServedModel, SwitchableServer
from repro.serve.telemetry import Telemetry


def build_server(names: list[str], slots: int, max_len: int,
                 temperature: float = 0.0,
                 load_delay_s: float = 0.0,
                 arch_overrides: dict | None = None,
                 telemetry: Telemetry | None = None
                 ) -> tuple[SwitchableServer, dict]:
    """Register reduced versions of `names` behind one SwitchableServer.

    ``load_delay_s`` sleeps in each ``weights_fn`` to emulate streaming a
    full-size context over the host->device link (benchmarks use it: the
    reduced CPU test models are in-memory, real contexts are not).
    ``arch_overrides`` are extra reduced-config fields (e.g. float32
    dtypes for tests that compare two numerically different execution
    paths bitwise)."""
    import jax.numpy as jnp
    server = SwitchableServer(num_slots=slots, telemetry=telemetry)
    cfgs = {}
    over = arch_overrides or {}
    for i, name in enumerate(names):
        cfg = make_reduced(get_arch(name), **over)
        cfgs[name] = cfg
        model = build_model(cfg, cache_dtype=jnp.float32
                            if over.get("dtype") == "float32"
                            else jnp.bfloat16)
        params = model.init(jax.random.key(i))

        def weights_fn(p=params):
            if load_delay_s:
                time.sleep(load_delay_s)
            return p
        server.register(ServedModel(name=name, model=model,
                                    weights_fn=weights_fn,
                                    max_len=max_len,
                                    temperature=temperature))
    return server, cfgs


def request_stream(names, cfgs, n_requests, batch, seq, seed):
    """Round-robin mixed-model traffic (worst case for switching)."""
    rng = np.random.default_rng(seed)
    for r in range(n_requests):
        name = names[r % len(names)]
        toks = rng.integers(0, cfgs[name].vocab_size, (batch, seq))
        yield name, toks


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--archs", default="supersub-super,supersub-sub")
    ap.add_argument("--mode",
                    choices=("queue", "continuous", "speculative", "sync"),
                    default="queue")
    ap.add_argument("--pool", type=int, default=8,
                    help="continuous/speculative mode: slot-pool width")
    ap.add_argument("--draft", default=None,
                    help="speculative mode: draft context name (must be "
                         "one of --archs; the remaining archs become "
                         "verify targets)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculative mode: draft tokens per round (the "
                         "adaptive ceiling when --spec-adaptive is set)")
    ap.add_argument("--spec-tree", type=int, default=1,
                    help="speculative mode: sibling candidates per draft "
                         "depth — 1 is the flat chain; W>1 verifies a "
                         "token tree so a rejected chain can still "
                         "commit an accepted sibling (needs "
                         "1 + K*W <= 31 tree nodes)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="speculative mode: let the scheduler walk each "
                         "engine's K inside [1, --spec-k] from the "
                         "measured acceptance rate (EWMA, hysteresis)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous mode: admit prompts in fixed-size "
                         "chunks of this many tokens, one chunk per step "
                         "(bounded admission latency; one jitted chunk "
                         "program instead of one per prompt length)")
    ap.add_argument("--paged", action="store_true",
                    help="continuous mode: paged slot pool — per-slot "
                         "page tables over one shared KV page bank; each "
                         "request holds only the pages its own length "
                         "needs, so the same memory serves more "
                         "concurrent requests")
    ap.add_argument("--page-size", type=int, default=256,
                    help="paged mode: tokens per KV page (must divide "
                         "the serving max_len)")
    ap.add_argument("--multi-step", type=int, default=1,
                    help="continuous mode: fuse up to T decode steps "
                         "into one device program per scheduler tick "
                         "(the host's rank/drain/admit bookkeeping "
                         "amortizes over up to T tokens; streams stay "
                         "bitwise-identical to T single steps)")
    ap.add_argument("--quantize-kv", choices=("none", "int8"),
                    default="none",
                    help="paged mode: store the shared KV page bank in "
                         "int8 with per-token-per-head scales — about "
                         "half the bytes per page, ~2x admitted "
                         "concurrency per HBM budget (outputs are "
                         "tolerance-close, not bitwise)")
    ap.add_argument("--shards", type=int, default=None,
                    help="paged mode: partition each engine's KV page "
                         "bank into this many shards with one free-list "
                         "each; admission routes a request's pages to "
                         "one shard (prefix hits to the shard holding "
                         "the cached pages, cold admissions to the "
                         "least-loaded shard).  When at least this many "
                         "devices are visible the bank is also placed "
                         "over a device mesh")
    ap.add_argument("--platform", default=None,
                    choices=("cpu", "gpu", "tpu"),
                    help="pin jax to one platform (default: jax's own "
                         "detection order)")
    ap.add_argument("--x64", action="store_true",
                    help="enable 64-bit mode (f64/i64 default types)")
    ap.add_argument("--host-devices", type=int, default=None,
                    metavar="N",
                    help="force the host (CPU) platform to expose N "
                         "devices — a fake multi-device topology for "
                         "--shards mesh placement without hardware "
                         "(must be set before jax initializes; the CI "
                         "multi-device job exports XLA_FLAGS instead)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged mode: share already-written prompt pages "
                         "across admissions — a request whose prompt "
                         "starts with a cached whole-page run maps those "
                         "pages read-only and prefills only the "
                         "divergent suffix (copy-on-write on the "
                         "boundary page; streams stay bitwise-identical "
                         "to cold admission); cached pages are evicted "
                         "LRU-first under page pressure")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--steps", type=int, default=1)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-request lifecycle spans and export "
                         "Chrome trace-event JSON here on exit (open at "
                         "https://ui.perfetto.dev; one track per context "
                         "slot / pool slot, so hidden context loads show "
                         "as load: spans under run:/tick spans)")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    metavar="SECONDS",
                    help="while requests are in flight, print a metric "
                         "registry snapshot (one JSON line to stderr) "
                         "every SECONDS; 0 disables")
    args = ap.parse_args(argv)
    if args.shards is not None and (args.shards < 1 or not args.paged):
        ap.error("--shards needs --paged and a positive shard count")
    from repro.core import env
    env.set_platform(args.platform)
    if args.x64:
        env.enable_x64(True)
    env.set_host_device_count(args.host_devices)
    if args.quantize_kv != "none" and not args.paged \
            and args.mode != "speculative":
        ap.error("--quantize-kv targets the shared page bank: it "
                 "requires --paged (or --mode speculative, whose cache "
                 "columns are always paged)")
    if args.prefix_cache and not args.paged \
            and args.mode != "speculative":
        ap.error("--prefix-cache shares pages of the pooled bank: it "
                 "requires --paged (or --mode speculative, whose target "
                 "column is always paged)")
    if args.multi_step < 1:
        ap.error("--multi-step must be >= 1")
    if args.spec_k < 1:
        ap.error("--spec-k must be >= 1 (one drafted token per round is "
                 "the minimum speculative step)")
    if args.spec_tree < 1:
        ap.error("--spec-tree must be >= 1 (1 is the flat chain)")
    if args.mode == "speculative":
        if args.draft is None:
            ap.error("--mode speculative requires --draft: name the "
                     "context that proposes tokens (the remaining "
                     "--archs become verify targets)")
        if 1 + args.spec_k * args.spec_tree > 31:
            ap.error(f"--spec-k {args.spec_k} with --spec-tree "
                     f"{args.spec_tree} needs 1 + K*W <= 31 tree nodes "
                     "(ancestor masks live in an int32 bitmask); lower "
                     "one of them")
    else:
        if args.draft is not None:
            ap.error("--draft only applies to --mode speculative")
        if args.spec_tree != 1:
            ap.error("--spec-tree only applies to --mode speculative")
        if args.spec_adaptive:
            ap.error("--spec-adaptive only applies to --mode speculative")

    names = args.archs.split(",")
    slack = args.spec_k if args.mode == "speculative" else 0
    max_len = args.seq + args.steps + slack + 8
    if args.paged:
        # a paged pool's row space is a whole number of pages
        ps = min(args.page_size, max_len)
        max_len = -(-max_len // ps) * ps
    telemetry = Telemetry(trace=args.trace_out is not None)
    server, cfgs = build_server(names, args.slots, max_len,
                                telemetry=telemetry)
    stats_stop = None
    if args.stats_interval > 0:
        import threading
        stats_stop = threading.Event()

        def _stats_loop():
            while not stats_stop.wait(args.stats_interval):
                print(json.dumps(telemetry.registry.snapshot(),
                                 default=str), file=sys.stderr)
        threading.Thread(target=_stats_loop, daemon=True,
                         name="stats-reporter").start()
    draft_map = {}
    if args.mode == "speculative":
        if args.draft not in names:
            ap.error(f"--draft {args.draft!r} must be one of "
                     f"--archs {names}")
        targets = [n for n in names if n != args.draft]
        draft_map = {t: args.draft for t in targets}
        reqs = list(request_stream(targets, cfgs, args.requests,
                                   args.batch, args.seq, args.seed))
    else:
        reqs = list(request_stream(names, cfgs, args.requests,
                                   args.batch, args.seq, args.seed))

    mesh = None
    if args.shards is not None and args.shards > 1 \
            and jax.device_count() >= args.shards:
        # enough devices: place the sharded bank over a real mesh (the
        # host allocator shards regardless; this adds device placement)
        from repro.distributed.mesh import make_mesh
        mesh = make_mesh((args.shards,), ("model",))

    t0 = time.perf_counter()
    if args.mode in ("queue", "continuous", "speculative"):
        sched_cls = (SwitchScheduler if args.mode == "queue" else
                     lambda s: ContinuousScheduler(
                         s, batch_size=args.pool, draft=draft_map,
                         spec_k=args.spec_k, spec_tree=args.spec_tree,
                         spec_adaptive=args.spec_adaptive,
                         prefill_chunk=args.prefill_chunk,
                         paged=args.paged, page_size=args.page_size,
                         multi_step=args.multi_step,
                         quantize_kv=(None if args.quantize_kv == "none"
                                      else args.quantize_kv),
                         prefix_cache=args.prefix_cache,
                         shards=args.shards, mesh=mesh))
        with sched_cls(server) as sched:
            futs = [(sched.submit(n, t, steps=args.steps),
                     time.perf_counter()) for n, t in reqs]
            lat = []
            for f, t_in in futs:
                f.result()
                lat.append(time.perf_counter() - t_in)
        extra = {**sched.snapshot()}
        if lat:
            extra["latency_p50_s"] = round(float(np.percentile(lat, 50)), 4)
            extra["latency_p99_s"] = round(float(np.percentile(lat, 99)), 4)
    else:
        for i, (name, toks) in enumerate(reqs):
            server.engine.preload(name)
            server.engine.switch(name, wait=True)
            server.engine.prefetch([n for n, _ in reqs[i + 1:]], limit=1)
            server.serve_batch(name, toks, steps=args.steps)
        extra = {}
    wall = time.perf_counter() - t0

    stats = server.engine.stats
    report = {
        "mode": args.mode,
        "wall_s": round(wall, 3),
        "requests_per_s": round(args.requests / wall, 2) if wall else 0.0,
        "switches": stats["switches"],
        "context_changes": stats["context_changes"],
        "mean_switch_us": round(1e6 * stats["switch_seconds"]
                                / max(stats["switches"], 1), 1),
        "loads": stats["loads"],
        "mean_load_ms": round(1e3 * stats["load_seconds"]
                              / max(stats["loads"], 1), 2),
        "bytes_loaded": stats["bytes_loaded"],
        "hidden_load_fraction": round(
            server.engine.hidden_load_fraction(), 3),
        **extra,
        "env": env.describe(),
        "log_tail": server.log[-3:],
    }
    if stats_stop is not None:
        stats_stop.set()
    if args.trace_out:
        report["trace_out"] = telemetry.tracer.export(args.trace_out)
        report["trace_events"] = len(telemetry.tracer)
    print(json.dumps(report, indent=1, default=str))
    server.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
