"""Serving launcher: context-switching inference over N registered models.

``python -m repro.launch.serve --archs supersub-super,supersub-sub --steps 8``

Demonstrates the paper's architecture live: the active model serves batched
requests while the next model's weights stream into the shadow slot; the
switch itself is an O(1) activation flip.  Prints the measured
switch/load/execution decomposition (EXPERIMENTS.md §Serving reads this).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced as make_reduced
from repro.models.model import build_model
from repro.serve.switching import ServedModel, SwitchableServer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--archs", default="supersub-super,supersub-sub")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    names = args.archs.split(",")
    server = SwitchableServer(num_slots=args.slots)
    rng = np.random.default_rng(args.seed)

    for i, name in enumerate(names):
        cfg = make_reduced(get_arch(name))
        model = build_model(cfg)
        params = model.init(jax.random.key(i))

        def weights_fn(p=params):
            return p
        server.register(ServedModel(name=name, model=model,
                                    weights_fn=weights_fn,
                                    max_len=args.seq + 8))

    # round-robin request stream across models (worst case for switching)
    t0 = time.perf_counter()
    for r in range(args.requests):
        name = names[r % len(names)]
        cfg = make_reduced(get_arch(name))
        toks = rng.integers(0, cfg.vocab_size, (args.batch, args.seq))
        out = server.serve_batch(name, toks)
        nxt = names[(r + 1) % len(names)]
        if nxt != name:
            server.preload(nxt)           # hidden behind this batch
    wall = time.perf_counter() - t0

    stats = server.engine.stats
    print(json.dumps({
        "wall_s": round(wall, 3),
        "switches": stats["switches"],
        "mean_switch_us": round(1e6 * stats["switch_seconds"]
                                / max(stats["switches"], 1), 1),
        "loads": stats["loads"],
        "mean_load_ms": round(1e3 * stats["load_seconds"]
                              / max(stats["loads"], 1), 2),
        "bytes_loaded": stats["bytes_loaded"],
        "log_tail": server.log[-3:],
    }, indent=1, default=str))
    server.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
