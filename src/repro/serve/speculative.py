"""Speculative cascade decode on the paged slot pool.

The paper's Super-Sub cascade (Fig 6a, S1a) runs the small network while
the big network's context streams into the shadow slot — load hidden
behind execution.  ``SpecEngine`` is the LLM-serving analogue at token
granularity: a cheap *draft* context proposes tokens, the *target*
context scores them all in ONE multi-token verify pass
(``LM.verify_step_pages`` over the ``verify_attention`` kernel), and
exact speculative sampling accepts a prefix + draws one continuation —
so the committed stream is distributed exactly as target-only sampling,
and greedy output is token-identical to ``StepEngine.generate``
(tested).

The engine keeps TWO cache columns over paged pools (one per model),
not per-slot rows: each admitted request owns only the pages its own
lifetime needs in each column, addressed through per-slot page tables
(``SpecState.d_table``/``t_table``) that the paged attention kernels
scalar-prefetch.  Admission gates on free slots AND free pages in both
pools (``can_admit``), retirement releases pages instead of a whole
row, and the target column can share one ``SharedBank`` — allocator,
prefix index, and device pages — with the plain paged engines serving
the same context, so a prompt one engine indexed is a prefix hit for
the speculative target too.

Proposal shapes:

  * ``tree_width=1`` (default) — the classic flat strip: K draft tokens
    verified with the intra-block causal mask (``speculative_accept``).
  * ``tree_width=W>1`` — a *sausage tree*: every depth carries W
    sibling candidates (the chain = sibling 0), all ``1 + K*W`` nodes
    verified in ONE pass with per-node depth offsets and an ancestor
    bitmask folded into the kernel's intra-block mask
    (``tree_speculative_accept``).  When the chain token dies at depth
    i but a sibling survives, the round still commits i+1 tokens where
    the flat strip would stop at i — wider trees buy acceptance length
    for draft compute, not extra target passes.

``k`` is *adaptive*: ``set_k`` moves the current depth within
``[1, k_max]`` (one compiled roll/verify pair per depth, cached), and
the continuous scheduler drives it from a measured-acceptance EWMA —
an aligned draft climbs to ``k_max``, a mismatched one falls back to
short cheap blocks.

Rollback stays positional: a rejected proposal's stale page writes are
masked by the row's committed position and overwritten later.  That
works for full attention caches only, so both models must be
all-attention with no sliding window — the same paged-support gate the
paged ``StepEngine`` applies.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.serve.engine import StepEngine
from repro.serve.pool import (Generation, PagePool, SharedBank, SlotPool,
                              PrefixIndex)
from repro.serve.telemetry import Telemetry, safe_ratio

# committed tokens per row per round lands in [1, K+1]; buckets cover
# the practical K range (the histogram is cumulative-bucket style)
SPEC_ACCEPT_BUCKETS = (1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0)


def speculative_accept(key, proposals, draft_logits, target_logits,
                       temperature: float):
    """Exact speculative sampling: accept/reject K proposals, draw the
    continuation.

    proposals: (B, K) int32 — draft tokens d_1..d_K; draft_logits:
    (B, K, V) — the distributions each d_i was sampled from;
    target_logits: (B, K+1, V) — target distributions for block-relative
    positions 1..K+1.  Returns (tokens (B, K+1), n_accepted (B,)):
    ``tokens[:, :n]`` are the accepted proposals, entry n is the residual
    draw (n < K) or the bonus token from the target's last distribution
    (n == K); entries past n are undefined.  The committed prefix is
    distributed exactly as target-only sampling for ANY draft
    distribution (tested statistically).

    Greedy (temperature == 0): accept while d_i equals the target argmax;
    the continuation is the target argmax — the committed stream is
    token-identical to plain greedy target decode.
    """
    B, K = proposals.shape
    cols = jnp.arange(K + 1, dtype=jnp.int32)[None, :]
    if temperature <= 0.0:
        tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)
        acc = proposals == tgt[:, :K]
        n = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
        nxt = jnp.take_along_axis(tgt, n[:, None], axis=1)[:, 0]
    else:
        p_all = jax.nn.softmax(target_logits.astype(jnp.float32)
                               / temperature, axis=-1)       # (B, K+1, V)
        q_all = jax.nn.softmax(draft_logits.astype(jnp.float32)
                               / temperature, axis=-1)       # (B, K, V)
        pd = jnp.take_along_axis(p_all[:, :K], proposals[..., None],
                                 axis=-1)[..., 0]            # (B, K)
        qd = jnp.take_along_axis(q_all, proposals[..., None],
                                 axis=-1)[..., 0]
        u = jax.random.uniform(key, (B, K), jnp.float32)
        acc = u * qd <= pd            # accept w.p. min(1, p/q); p==q -> 1
        n = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
        # residual at the rejection point: r ∝ max(p - q, 0); all-accepted
        # rows pad q with zeros so the "residual" is the bonus draw from p
        q_pad = jnp.concatenate(
            [q_all, jnp.zeros_like(q_all[:, :1])], axis=1)
        pn = jnp.take_along_axis(p_all, n[:, None, None], axis=1)[:, 0]
        qn = jnp.take_along_axis(q_pad, n[:, None, None], axis=1)[:, 0]
        r = jnp.clip(pn - qn, 0.0, None)
        rs = jnp.sum(r, axis=-1, keepdims=True)
        r = jnp.where(rs > 0, r / jnp.maximum(rs, 1e-30), pn)
        g = jax.random.gumbel(jax.random.fold_in(key, 1),
                              r.shape, jnp.float32)
        nxt = jnp.argmax(jnp.log(r + 1e-30) + g, axis=-1).astype(jnp.int32)
    props_pad = jnp.concatenate([proposals, proposals[:, :1]], axis=1)
    tokens = jnp.where(cols < n[:, None], props_pad,
                       jnp.where(cols == n[:, None], nxt[:, None], 0))
    return tokens.astype(jnp.int32), n.astype(jnp.int32)


def tree_speculative_accept(key, cand, draft_logits, target_logits,
                            temperature: float):
    """Recursive-rejection acceptance over a sausage token tree.

    Node layout (depths i in 1..K, siblings w in 0..W-1): node 0 is the
    last committed token; node ``1 + (i-1)*W + w`` is candidate w at
    depth i; sibling 0 is the *chain* (the path the draft rolled its own
    cache along).  ``cand``: (B, K, W) int32 candidates — the W draws at
    each depth were sampled i.i.d. from the SAME chain draft
    distribution ``draft_logits[:, i-1]`` ((B, K, V)).
    ``target_logits``: (B, 1+K*W, V), one distribution per tree node
    from the tree-verify pass.

    Per depth the W siblings run SpecInfer-style recursive rejection
    against the parent-node target distribution: candidate w is accepted
    with probability ``min(1, r/q)`` where r starts at p and renormalizes
    to ``max(r - q, 0)`` after each rejection; the first accepted sibling
    wins.  Sibling 0 accepted -> descend the chain.  A later sibling
    accepted -> commit the chain prefix, the sibling, AND a bonus token
    from the sibling's own verified distribution (the round ends there —
    the tree has no grandchildren off-chain).  All W rejected -> commit
    the residual draw.  Marginally the committed stream is exactly
    target-distributed (tested statistically), and at temperature 0 it
    is token-identical to greedy target decode: the committed token at
    depth i is ALWAYS the parent node's target argmax.

    Returns ``(tokens (B, K+1), n (B,), alt_depth (B,), alt_tok (B,))``:
    ``tokens[:, :n+1]`` is the committed block (same contract as
    ``speculative_accept``); rows with ``alt_depth > 0`` committed a
    non-chain sibling ``alt_tok`` at that depth, whose k/v the caches
    hold for the *chain* candidate — the engine repairs that one
    position with a masked decode step.
    """
    B, K, W = cand.shape
    chain = lambda i: 1 + (i - 1) * W           # chain node at depth i

    alive = jnp.ones((B,), bool)
    n = jnp.zeros((B,), jnp.int32)
    alt_depth = jnp.zeros((B,), jnp.int32)
    alt_tok = jnp.zeros((B,), jnp.int32)
    toks = jnp.zeros((B, K + 1), jnp.int32)

    if temperature <= 0.0:
        tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)
        for i in range(1, K + 1):
            parent = 0 if i == 1 else chain(i - 1)
            t_i = tgt[:, parent]
            # chain hit, alt hit (first matching sibling), or residual —
            # the committed token at depth i is t_i in every case
            toks = toks.at[:, i - 1].set(
                jnp.where(alive, t_i, toks[:, i - 1]))
            chain_hit = cand[:, i - 1, 0] == t_i
            alt_hit = jnp.zeros((B,), bool)
            alt_node = jnp.zeros((B,), jnp.int32)
            for w in range(1, W):
                hw = (~alt_hit) & (cand[:, i - 1, w] == t_i)
                alt_node = jnp.where(hw, chain(i) + w, alt_node)
                alt_hit = alt_hit | hw
            alt_hit = alt_hit & ~chain_hit
            n = jnp.where(alive & (chain_hit | alt_hit), i, n)
            bonus = jnp.take_along_axis(tgt, alt_node[:, None],
                                        axis=1)[:, 0]
            sel = alive & alt_hit
            toks = toks.at[:, i].set(jnp.where(sel, bonus, toks[:, i]))
            alt_depth = jnp.where(sel, i, alt_depth)
            alt_tok = jnp.where(sel, t_i, alt_tok)
            alive = alive & chain_hit
        toks = toks.at[:, K].set(
            jnp.where(alive, tgt[:, chain(K)], toks[:, K]))
        return toks, n, alt_depth, alt_tok

    p_all = jax.nn.softmax(target_logits.astype(jnp.float32)
                           / temperature, axis=-1)       # (B, Kt, V)
    q_all = jax.nn.softmax(draft_logits.astype(jnp.float32)
                           / temperature, axis=-1)       # (B, K, V)
    V = p_all.shape[-1]
    u = jax.random.uniform(key, (B, K, W), jnp.float32)
    # one residual + one bonus gumbel field: each row realizes each at
    # most once (the depth it dies rejecting / the node it bonuses from),
    # so sharing the field across depths keeps the draws independent
    gres = jax.random.gumbel(jax.random.fold_in(key, 1), (B, V),
                             jnp.float32)
    gbon = jax.random.gumbel(jax.random.fold_in(key, 2), (B, V),
                             jnp.float32)
    for i in range(1, K + 1):
        parent = 0 if i == 1 else chain(i - 1)
        p = p_all[:, parent]                             # (B, V)
        q = q_all[:, i - 1]
        r = p
        acc = jnp.zeros((B,), bool)
        acc_alt = jnp.zeros((B,), bool)
        acc_tok = jnp.zeros((B,), jnp.int32)
        acc_node = jnp.zeros((B,), jnp.int32)
        for w in range(W):
            tw = cand[:, i - 1, w]
            qt = jnp.take_along_axis(q, tw[:, None], axis=1)[:, 0]
            rt = jnp.take_along_axis(r, tw[:, None], axis=1)[:, 0]
            aw = (~acc) & (u[:, i - 1, w] * qt <= rt)
            acc_tok = jnp.where(aw, tw, acc_tok)
            acc_node = jnp.where(aw, chain(i) + w, acc_node)
            acc_alt = acc_alt | (aw & (w > 0))
            acc = acc | aw
            if w < W - 1:
                # rejected w: renormalized leftover target mass (fall
                # back to p when nothing is left, like the flat rule)
                rm = jnp.clip(r - q, 0.0, None)
                rs = jnp.sum(rm, axis=-1, keepdims=True)
                rn = jnp.where(rs > 0, rm / jnp.maximum(rs, 1e-30), p)
                r = jnp.where(acc[:, None], r, rn)
        # all W rejected: residual draw from the final leftover mass
        rm = jnp.clip(r - q, 0.0, None)
        rs = jnp.sum(rm, axis=-1, keepdims=True)
        r = jnp.where(rs > 0, rm / jnp.maximum(rs, 1e-30), p)
        residual = jnp.argmax(jnp.log(r + 1e-30) + gres,
                              axis=-1).astype(jnp.int32)
        tok_i = jnp.where(acc, acc_tok, residual)
        toks = toks.at[:, i - 1].set(
            jnp.where(alive, tok_i, toks[:, i - 1]))
        n = jnp.where(alive & acc, i, n)
        bl = jnp.take_along_axis(p_all, acc_node[:, None, None],
                                 axis=1)[:, 0]           # (B, V)
        bonus = jnp.argmax(jnp.log(bl + 1e-30) + gbon,
                           axis=-1).astype(jnp.int32)
        sel = alive & acc_alt
        toks = toks.at[:, i].set(jnp.where(sel, bonus, toks[:, i]))
        alt_depth = jnp.where(sel, i, alt_depth)
        alt_tok = jnp.where(sel, acc_tok, alt_tok)
        alive = alive & (acc & ~acc_alt)
    blK = p_all[:, chain(K)]
    bonusK = jnp.argmax(jnp.log(blK + 1e-30) + gbon,
                        axis=-1).astype(jnp.int32)
    toks = toks.at[:, K].set(jnp.where(alive, bonusK, toks[:, K]))
    return toks, n, alt_depth, alt_tok


class SpecKey(NamedTuple):
    """Frozen cache key for ONE speculative-engine configuration — the
    SpecEngine counterpart of ``EngineKey``: every knob that changes a
    compiled program or a cache layout is a named field, so two
    configurations can never silently alias one pool.  ``k`` is the
    engine's K_MAX — adaptive K moves ``eng.k`` underneath it without
    changing which engine serves the context."""
    name: Optional[str] = None          # target context
    draft: Optional[str] = None         # draft context
    batch_size: int = 1
    k: int = 4                          # constructor k == adaptive ceiling
    tree_width: int = 1
    page_size: Optional[int] = None     # resolved (never None in practice)
    quantize_kv: Optional[str] = None
    prefix_cache: bool = False
    prefill_chunk: Optional[int] = None
    shared_bank: bool = False           # target column on a SharedBank


class SpecState(NamedTuple):
    """Device half of the speculative pool (a pytree; donated each call).

    One slot pool, two PAGED cache columns: at every round boundary both
    columns hold exactly the committed prefix (positions <= pos-1,
    addressed through the per-slot page tables) and ``tok`` is the last
    committed token at position ``pos`` — the same invariant
    ``decode_step_pages`` keeps, so draft and target stay
    interchangeable views of one sequence."""
    d_caches: Any         # draft page-bank pytree, leaves (R, NP, ...)
    t_caches: Any         # target page-bank pytree (bank-shared when set)
    tok: jax.Array        # (B, 1) int32 — last committed token per slot
    pos: jax.Array        # (B,) int32  — its cache position
    key: jax.Array        # PRNG key, folded once per round
    t: jax.Array          # () int32    — round counter
    d_table: jax.Array    # (B, P) int32 — draft-column page tables
    t_table: jax.Array    # (B, P) int32 — target-column page tables


@dataclass
class _SpecPending:
    """One admitted-but-still-prefilling request (chunked admission):
    its slot and pages (both columns) are reserved, its prompt streams
    into both cache columns one chunk per engine tick."""
    tokens: np.ndarray                    # (b, S) full prompt, int32
    gens: list                            # Generation handles (slots set)
    t_tables: np.ndarray                  # (b, P) target page tables
    d_tables: np.ndarray                  # (b, P) draft page tables
    done: int = 0                         # prompt tokens already chunked
    started: bool = False                 # first chunk has executed


class SpecEngine(SlotPool):
    """Speculative continuous-batching engine for one draft/target pair,
    on paged KV columns.

    Host surface is the shared ``SlotPool`` base ``StepEngine`` also
    builds on (slots, free-list, ``admit``, ``step``, ``drain``) so the
    continuous scheduler drives either interchangeably; one ``step()`` is
    a full speculative ROUND — a K+1 draft rollout plus one multi-token
    verify — committing between 1 and K+1 tokens per live row.

    Each column is a paged pool (``PagePool`` + per-slot page table):
    admission takes ``pages_needed`` pages per column (gated by
    ``can_admit`` on slots AND both pools), retirement releases them.
    The target column accepts a ``SharedBank`` so its allocator, prefix
    index, and device pages are the SAME objects a plain paged
    ``StepEngine`` over the same context uses — a prompt either engine
    admitted is a prefix hit for both.  ``prefix_cache=True`` maps a new
    prompt's indexed pages read-only into the target table and prefills
    only the un-cached suffix (one-shot single-row admissions; the draft
    column always prefills cold — its pages are private).

    ``prefill_chunk=C`` streams admission: each engine tick runs one
    (b, C) chunk into BOTH columns before the round, so admission
    latency for live rows is bounded by one chunk regardless of prompt
    length (greedy streams are token-identical across chunk sizes —
    tested).

    ``tree_width=W>1`` widens each draft depth to W sibling candidates
    verified in one tree pass (see ``tree_speculative_accept``); the
    committed distribution is unchanged.  ``k`` is the CURRENT depth,
    adjustable per round via ``set_k`` within [1, k_max] (k_max = the
    constructor ``k``); admission always reserves ``k_max`` slack so a
    depth change never overruns a row's pages.

    ``params`` per call is ``(draft_params, target_params)``, or ``None``
    when ``runner`` is set: the scheduler's runner receives
    ``(which, fn, *args)`` with ``which`` in {"draft", "target"} and runs
    the program against the right context slot (switching + hidden-load
    accounting included) — the engine never captures weights.
    """

    def __init__(self, draft: LM, target: LM, batch_size: int, max_len: int,
                 k: int = 4, temperature: float = 0.0, seed: int = 0,
                 eos_id: Optional[int] = None,
                 tree_width: int = 1,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 bank: Optional[SharedBank] = None,
                 quantize_kv: Optional[str] = None,
                 telemetry: Optional[Telemetry] = None):
        for m, role in ((draft, "draft"), (target, "target")):
            if any(mix != "attn" for mix, _ in m.pattern):
                raise ValueError(
                    f"speculative decode needs an all-attention {role} "
                    "(recurrent state cannot rewind a rejected proposal)")
            if m.cfg.sliding_window:
                raise ValueError(
                    f"speculative decode needs a full-cache {role} (ring "
                    "writes wrap onto slots a rollback must preserve)")
            m._require_paged_support()
        if draft.cfg.vocab_size != target.cfg.vocab_size:
            raise ValueError("draft and target must share a vocabulary")
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if tree_width < 1:
            raise ValueError(f"tree_width must be >= 1, got {tree_width}")
        if tree_width > 1 and 1 + k * tree_width > 31:
            raise ValueError(
                f"tree of depth {k} x width {tree_width} has "
                f"{1 + k * tree_width} nodes; the ancestor bitmask holds "
                "at most 31 (int32)")
        if quantize_kv not in (None, "int8"):
            raise ValueError(f"quantize_kv must be None or 'int8', got "
                             f"{quantize_kv!r}")
        self.draft_model = draft
        self.target_model = target
        self.batch_size = batch_size
        self.max_len = max_len
        self.k = k                  # CURRENT depth (set_k moves it)
        self.k_max = k              # admission slack + program-cache cap
        self.tree_width = tree_width
        self.temperature = temperature
        self.seed = seed
        self.eos_id = eos_id
        self.quantize_kv = quantize_kv
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got "
                             f"{prefill_chunk}")
        self.prefill_chunk = prefill_chunk

        telemetry = telemetry if telemetry is not None else Telemetry()

        # ---- paged columns: one pool per model (the target may share)
        if page_size is None:
            page_size = math.gcd(max_len, 256)
        page_size = min(page_size, max_len)
        if max_len % page_size:
            raise ValueError(
                f"page_size {page_size} must divide max_len {max_len}: a "
                "row's virtual space is a whole number of pages")
        self.page_size = page_size
        self.pages_per_row = max_len // page_size
        if num_pages is None:
            num_pages = batch_size * self.pages_per_row + 1
        if num_pages < self.pages_per_row + 1:
            raise ValueError(
                f"num_pages {num_pages} cannot hold one worst-case row "
                f"({self.pages_per_row} pages) plus the park page")
        self.num_pages = num_pages
        # scoped pool telemetry so the two free_pages gauges don't collide
        self._d_pages = PagePool(num_pages,
                                 telemetry=telemetry.scoped("draft."))
        self._bank = bank
        if bank is not None:
            if bank.pool.total_pages < self.pages_per_row + 1:
                raise ValueError(
                    f"shared bank of {bank.pool.total_pages} pages cannot "
                    f"hold one worst-case row ({self.pages_per_row} pages)")
            self._t_pages = bank.pool
        else:
            self._t_pages = PagePool(num_pages,
                                     telemetry=telemetry.scoped("target."))
        self.prefix_cache = prefix_cache
        if prefix_cache:
            if bank is not None:
                if bank.index is None:
                    bank.index = PrefixIndex(page_size,
                                             namespace=quantize_kv or "fp16")
                self._prefix = bank.index
            else:
                self._prefix = PrefixIndex(page_size,
                                           namespace=quantize_kv or "fp16")
        else:
            self._prefix = None
        # the prefix machinery reads/writes the TARGET column only
        self._pages = self._t_pages
        self.paged = True

        B, T = batch_size, temperature
        V = target.cfg.vocab_size
        max_len_ = max_len

        def _admit_draw(state: SpecState, last, slots):
            """First-token draw from prefill logits — the target's draw:
            the committed stream must be target-distributed from token
            one.  Past t=0 the draw key is salted (same hazard and same
            salt as ``StepEngine._admit``): the stored key equals round
            t-1's roll base, whose small-integer folds generated that
            round's draft fields — an unsalted admission at t <= K would
            reuse one of them."""
            if T > 0.0:
                salted = jax.random.fold_in(state.key,
                                            (1 << 30) ^ state.t)
                akey = jnp.where(state.t == 0, state.key, salted)
                g = jax.random.gumbel(akey, (B, V), jnp.float32)
                first = jnp.argmax(last / T + g[slots], axis=-1)
            else:
                first = jnp.argmax(last, axis=-1)
            return first.astype(jnp.int32)

        def _admit_target(tparams, state: SpecState, tokens, slots, tables):
            """Target prefill scattered into the rows' own pages + first
            token draw."""
            S = tokens.shape[1]
            logits, rows = target.prefill(tparams, tokens, max_len_)
            first = _admit_draw(state, logits[:, -1], slots)
            t_caches = target.insert_cache_pages(state.t_caches, rows,
                                                 tables)
            return first, state._replace(
                t_caches=t_caches,
                tok=state.tok.at[slots].set(first[:, None]),
                pos=state.pos.at[slots].set(jnp.int32(S)),
                t_table=state.t_table.at[slots].set(tables))

        def _admit_draft(dparams, state: SpecState, tokens, slots, tables):
            """Draft prefill into the draft column's pages (its last-token
            logits are unused — the draft only needs the prompt's k/v)."""
            _, rows = draft.prefill(dparams, tokens, max_len_)
            return state._replace(
                d_caches=draft.insert_cache_pages(state.d_caches, rows,
                                                  tables),
                d_table=state.d_table.at[slots].set(tables))

        def _admit_t_hit(tparams, state: SpecState, suffix, pos, slots,
                         tables, nvalid):
            """Prefix-hit target admission: only the prompt's un-cached
            suffix runs, as one verify-machinery chunk through the page
            tables (matched pages were mapped read-only by the host);
            the last real token's logits draw the first token under the
            same rules as a cold admit."""
            Wd = suffix.shape[1]
            wmask = (jnp.arange(Wd, dtype=jnp.int32)[None, :]
                     < nvalid[:, None])
            logits, t_caches = target.verify_step_pages(
                tparams, state.t_caches, suffix, pos, tables, wmask=wmask)
            last = jnp.take_along_axis(
                logits, (nvalid - 1)[:, None, None], axis=1)[:, 0]
            first = _admit_draw(state, last, slots)
            return first, state._replace(
                t_caches=t_caches,
                tok=state.tok.at[slots].set(first[:, None]),
                pos=state.pos.at[slots].set(pos + nvalid),
                t_table=state.t_table.at[slots].set(tables))

        def _chunk_d(dparams, state: SpecState, chunk, pos, tables, nvalid):
            """One streaming draft prefill chunk through the draft page
            tables (pad positions write-masked; no logits)."""
            Wd = chunk.shape[1]
            wmask = (jnp.arange(Wd, dtype=jnp.int32)[None, :]
                     < nvalid[:, None])
            _, d_caches = draft.prefill_chunk_pages(
                dparams, state.d_caches, chunk, pos, tables, wmask=wmask,
                need_logits=False)
            return state._replace(d_caches=d_caches)

        def _chunk_t(tparams, state: SpecState, chunk, pos, tables, nvalid):
            """One streaming target prefill chunk (non-final: no logits,
            no sampling)."""
            Wd = chunk.shape[1]
            wmask = (jnp.arange(Wd, dtype=jnp.int32)[None, :]
                     < nvalid[:, None])
            _, t_caches = target.prefill_chunk_pages(
                tparams, state.t_caches, chunk, pos, tables, wmask=wmask,
                need_logits=False)
            return state._replace(t_caches=t_caches)

        def _chunk_t_final(tparams, state: SpecState, chunk, pos, slots,
                           tables, nvalid):
            """Final target chunk: write the tail, sample the first token
            from the last real token's logits (same admission draw as
            one-shot), and arm the row's tok/pos."""
            Wd = chunk.shape[1]
            wmask = (jnp.arange(Wd, dtype=jnp.int32)[None, :]
                     < nvalid[:, None])
            logits, t_caches = target.prefill_chunk_pages(
                tparams, state.t_caches, chunk, pos, tables, wmask=wmask)
            last = jnp.take_along_axis(
                logits, (nvalid - 1)[:, None, None], axis=1)[:, 0]
            first = _admit_draw(state, last, slots)
            plen = pos + nvalid
            return first, state._replace(
                t_caches=t_caches,
                tok=state.tok.at[slots].set(first[:, None]),
                pos=state.pos.at[slots].set(plen))

        def _copy_t(params, state: SpecState, src, dst):
            """Copy-on-write a shared target page before the diverging
            row's first write.  ``params`` is unused but keeps the
            runner's uniform ``fn(params, *args)`` convention."""
            del params
            return state._replace(
                t_caches=target.copy_cache_pages(state.t_caches, src, dst))

        def _repair_d(dparams, state: SpecState, tok, rpos, alive):
            """Tree repair, draft column: the round committed a non-chain
            sibling, so the draft cache holds the CHAIN candidate's k/v
            at the sibling's position — one masked decode step feeding
            the committed sibling overwrites it with exactly what a
            sequential draft decode would have written (reads at rpos see
            only the committed prefix).  Logits are discarded."""
            _, d_caches = draft.decode_step_pages(
                dparams, state.d_caches, tok, rpos, state.d_table,
                live=alive)
            return state._replace(d_caches=d_caches)

        self._admit_target_fn = jax.jit(_admit_target, donate_argnums=(1,))
        self._admit_draft_fn = jax.jit(_admit_draft, donate_argnums=(1,))
        self._admit_t_hit_fn = jax.jit(_admit_t_hit, donate_argnums=(1,))
        self._chunk_d_fn = jax.jit(_chunk_d, donate_argnums=(1,))
        self._chunk_t_fn = jax.jit(_chunk_t, donate_argnums=(1,))
        self._chunk_t_final_fn = jax.jit(_chunk_t_final, donate_argnums=(1,))
        self._copy_t_fn = jax.jit(_copy_t, donate_argnums=(1,))
        self._repair_d_fn = jax.jit(_repair_d, donate_argnums=(1,))
        self._fns: dict = {}        # depth k -> {"roll", "verify"} jits

        # Execution hook: when set, every device program runs as
        # ``runner(which, fn, *args)`` with which in {"draft", "target"} —
        # the continuous scheduler activates the matching context slot and
        # prefetches the other into the shadow slot before each call.
        self.runner = None

        self.state: Optional[SpecState] = None
        self._pending: deque = deque()
        self._d_owned: dict = {}    # slot -> draft-column pages owned
        self._pool_init(B, telemetry=telemetry)
        # speculative accounting rides the shared pool counters; the tick
        # counters stay 0 — a round is not a decode round-trip and must
        # not skew the steps-per-tick aggregate.
        self.stats.update({"rounds": 0, "row_rounds": 0, "draft_steps": 0,
                           "committed_tokens": 0, "admitted_tokens": 0,
                           "prefix_hits": 0, "prefix_pages_mapped": 0,
                           "cow_copies": 0, "cache_evictions": 0})
        reg = self.telemetry.registry
        reg.gauge(self.telemetry.prefix + "k_current", self.k,
                  doc="current adaptive speculation depth")
        reg.gauge(self.telemetry.prefix + "tree_width", self.tree_width,
                  doc="draft candidates per speculation depth")
        self.reset()

    # -------------------------------------------------------- round programs
    def set_k(self, k: int):
        """Move the current speculation depth within [1, k_max] (adaptive
        K: the scheduler calls this from its acceptance EWMA).  Programs
        for each depth compile once and are cached; admission slack
        always reserves ``k_max`` so a later rise never overruns pages
        already granted."""
        k = max(1, min(int(k), self.k_max))
        if k != self.k:
            self.k = k
            self.telemetry.registry.gauge(
                self.telemetry.prefix + "k_current", k,
                doc="current adaptive speculation depth")

    def _programs(self, k: int):
        fns = self._fns.get(k)
        if fns is None:
            fns = self._build_round_programs(k)
            self._fns[k] = fns
        return fns

    def _build_round_programs(self, k: int):
        draft, target = self.draft_model, self.target_model
        B, T = self.batch_size, self.temperature
        V = target.cfg.vocab_size
        W = self.tree_width
        K = k
        max_len = self.max_len

        if W == 1:
            def _roll(dparams, state: SpecState, live):
                """K+1 draft decode steps from the committed token:
                iteration i feeds block token i at pos+i, sampling
                proposal d_{i+1}.  The extra iteration feeds d_K so its
                k/v lands in the draft pages (needed when the whole block
                is accepted); its sample is discarded.  Dead rows' writes
                park (their pages may already belong to a neighbor);
                sampling never sees the cache layout, so the stream is
                bitwise the dense-row engine's."""
                base = jax.random.fold_in(state.key, state.t)

                def body(carry, i):
                    caches, tok = carry
                    logits, caches = draft.decode_step_pages(
                        dparams, caches, tok, state.pos + i,
                        state.d_table, live=live)
                    last = logits[:, -1]
                    if T > 0.0:
                        g = jax.random.gumbel(jax.random.fold_in(base, i),
                                              (B, V), jnp.float32)
                        nxt = jnp.argmax(last / T + g, axis=-1)
                    else:
                        nxt = jnp.argmax(last, axis=-1)
                    nxt = nxt.astype(jnp.int32)
                    return (caches, nxt[:, None]), (nxt, last)

                (d_caches, _), (props, dlogits) = jax.lax.scan(
                    body, (state.d_caches, state.tok),
                    jnp.arange(K + 1, dtype=jnp.int32))
                return (props[:K].T, dlogits[:K].transpose(1, 0, 2),
                        state._replace(d_caches=d_caches))

            def _verify(tparams, state: SpecState, props, dlogits, live,
                        remaining):
                """One multi-token target pass over [t0, d_1..d_K] through
                the target page tables + exact accept/reject.  Commits
                m = min(n_accepted+1, remaining) tokens per live row;
                stale page writes past pos+m are masked by position and
                overwritten by later rounds.  Dead rows write-mask the
                whole block."""
                block = jnp.concatenate([state.tok, props], axis=1)
                wmask = jnp.broadcast_to(live[:, None], block.shape)
                logits, t_caches = target.verify_step_pages(
                    tparams, state.t_caches, block, state.pos,
                    state.t_table, wmask=wmask)
                vkey = jax.random.fold_in(
                    jax.random.fold_in(state.key, state.t), 1 << 20)
                toks, n = speculative_accept(vkey, props, dlogits, logits,
                                             T)
                m = jnp.where(live, jnp.minimum(n + 1, remaining), 0)
                tok_new = jnp.take_along_axis(
                    toks, jnp.clip(m - 1, 0, K)[:, None], axis=1)
                tok_new = jnp.where(m[:, None] > 0, tok_new, state.tok)
                pos_new = jnp.minimum(state.pos + m, max_len - 1)
                # advance the key once per round (like StepEngine._step):
                # a later admission must draw from a FRESH field, not the
                # one every earlier admission into that slot already used
                return toks, m, state._replace(
                    t_caches=t_caches, tok=tok_new, pos=pos_new,
                    key=jax.random.fold_in(state.key, state.t),
                    t=state.t + 1)

            return {"roll": jax.jit(_roll, donate_argnums=(1,)),
                    "verify": jax.jit(_verify, donate_argnums=(1,))}

        # ---- sausage tree: W candidates per depth, one verify pass
        Kt = 1 + K * W
        chain = lambda i: 1 + (i - 1) * W
        offsets_np = np.concatenate(
            [[0], np.repeat(np.arange(1, K + 1), W)]).astype(np.int32)
        mask_np = np.zeros((Kt,), np.int32)
        mask_np[0] = 1                               # node 0 sees itself
        for i in range(1, K + 1):
            anc = 1                                  # bit 0: committed tok
            for d in range(1, i):
                anc |= 1 << chain(d)
            for w in range(W):
                j = chain(i) + w
                mask_np[j] = anc | (1 << j)
        writer_np = np.zeros((Kt,), bool)
        writer_np[0] = True                          # committed tok at pos
        for i in range(1, K + 1):
            writer_np[chain(i)] = True               # chain k/v at pos+i

        def _roll_tree(dparams, state: SpecState, live):
            """K+1 draft steps along the CHAIN (sibling 0), sampling W
            i.i.d. candidates per depth from the chain distribution
            (greedy: top-W, so sibling 0 is the argmax chain).  Only the
            chain's k/v enters the draft pages — siblings are scored by
            the target's tree pass, never decoded by the draft."""
            base = jax.random.fold_in(state.key, state.t)

            def body(carry, i):
                caches, tok = carry
                logits, caches = draft.decode_step_pages(
                    dparams, caches, tok, state.pos + i, state.d_table,
                    live=live)
                last = logits[:, -1]                         # (B, V)
                if T > 0.0:
                    g = jax.random.gumbel(jax.random.fold_in(base, i),
                                          (B, W, V), jnp.float32)
                    cands = jnp.argmax(last[:, None, :] / T + g, axis=-1)
                else:
                    _, cands = jax.lax.top_k(last, W)
                cands = cands.astype(jnp.int32)              # (B, W)
                return (caches, cands[:, :1]), (cands, last)

            (d_caches, _), (cs, ls) = jax.lax.scan(
                body, (state.d_caches, state.tok),
                jnp.arange(K + 1, dtype=jnp.int32))
            return (cs[:K].transpose(1, 0, 2),
                    ls[:K].transpose(1, 0, 2),
                    state._replace(d_caches=d_caches))

        def _verify_tree(tparams, state: SpecState, cand, dlogits, live,
                         remaining):
            """ONE target pass over all 1+K*W tree nodes: per-node
            depth offsets place queries/writes at pos+depth, the
            scalar-prefetched ancestor bitmask replaces the
            intra-block causal mask, and only the chain nodes write
            k/v (siblings park — a dead branch must not dirty the
            pages).  Tree acceptance picks the committed block; when
            a non-chain sibling wins, the target cache's chain k/v at
            that depth is repaired in-place with one masked decode
            step before the state advances."""
            block = jnp.concatenate(
                [state.tok, cand.reshape(B, K * W)], axis=1)  # (B, Kt)
            wmask = live[:, None] & jnp.asarray(writer_np)[None, :]
            tree = jnp.broadcast_to(jnp.asarray(mask_np), (B, Kt))
            logits, t_caches = target.verify_step_pages(
                tparams, state.t_caches, block, state.pos,
                state.t_table, wmask=wmask,
                offsets=jnp.asarray(offsets_np), tree=tree)
            vkey = jax.random.fold_in(
                jax.random.fold_in(state.key, state.t), 1 << 20)
            toks, n, alt_depth, alt_tok = tree_speculative_accept(
                vkey, cand, dlogits, logits, T)
            m = jnp.where(live, jnp.minimum(n + 1, remaining), 0)
            tok_new = jnp.take_along_axis(
                toks, jnp.clip(m - 1, 0, K)[:, None], axis=1)
            tok_new = jnp.where(m[:, None] > 0, tok_new, state.tok)
            # repair: overwrite the chain k/v at the sibling's depth
            # with the committed sibling's.  Always ran (parked when
            # no row needs it); safe under the remaining clip — a
            # clipped-out sibling's repair lands past pos_new, in the
            # stale region later rounds overwrite anyway.
            alt_live = live & (alt_depth > 0)
            rpos = state.pos + alt_depth
            _, t_caches = target.decode_step_pages(
                tparams, t_caches, alt_tok[:, None], rpos,
                state.t_table, live=alt_live)
            pos_new = jnp.minimum(state.pos + m, max_len - 1)
            return toks, m, alt_depth, alt_tok, rpos, state._replace(
                t_caches=t_caches, tok=tok_new, pos=pos_new,
                key=jax.random.fold_in(state.key, state.t),
                t=state.t + 1)

        return {"roll": jax.jit(_roll_tree, donate_argnums=(1,)),
                "verify": jax.jit(_verify_tree, donate_argnums=(1,))}

    # the prefix-cache and page-allocation machinery is byte-for-byte
    # StepEngine's, pointed at the TARGET column (``self._pages`` aliases
    # the target pool; the draft column never shares pages)
    _reclaim = StepEngine._reclaim
    _prefix_plan = StepEngine._prefix_plan
    _route_prefix = StepEngine._route_prefix
    _take_prefix_pages = StepEngine._take_prefix_pages
    _drop_prefix_pages = StepEngine._drop_prefix_pages
    _index_prompt = StepEngine._index_prompt
    _take_pages = StepEngine._take_pages
    _note_chunk = StepEngine._note_chunk

    # ------------------------------------------------------------- lifecycle
    def reset(self, seed: Optional[int] = None):
        B = self.batch_size
        # give the target column's pages back before the host pools reset:
        # a private pool just resets; a shared bank keeps serving the
        # OTHER engines, so only this engine's own rows release
        if self._bank is not None:
            own = []
            for g in self.slots:
                if g is not None and g.pages:
                    own += g.pages
                    g.pages = None
            for ps in self._pending:
                for g in ps.gens:
                    if g.pages:
                        own += g.pages
                        g.pages = None
            if own:
                self._t_pages.release(own)
        else:
            self._t_pages.reset()
            if self._prefix is not None:
                self._prefix.clear()   # its pages just left the allocator
        self._d_pages.reset()
        self._d_owned = {}
        self._pending.clear()

        def _alive(c):
            return c is not None and not any(
                getattr(x, "is_deleted", lambda: False)()
                for x in jax.tree.leaves(c))

        d_caches = t_caches = None
        if self.state is not None:
            d_caches, t_caches = self.state.d_caches, self.state.t_caches
        if self._bank is not None and self._bank.caches is not None:
            t_caches = self._bank.caches   # the bank copy is authoritative
        if not _alive(d_caches):
            d_caches = self.draft_model.init_page_pool(
                self.num_pages, self.page_size,
                quantized=self.quantize_kv is not None)
        if not _alive(t_caches):
            t_caches = self.target_model.init_page_pool(
                self._t_pages.total_pages, self.page_size,
                quantized=self.quantize_kv is not None)
        if self._bank is not None:
            self._bank.caches = t_caches
        P = self.pages_per_row
        self.state = SpecState(
            d_caches=d_caches, t_caches=t_caches,
            tok=jnp.zeros((B, 1), jnp.int32),
            pos=jnp.zeros((B,), jnp.int32),
            key=jax.random.PRNGKey(self.seed if seed is None else seed),
            t=jnp.zeros((), jnp.int32),
            # every table entry must be a valid pool index; park (0) is
            # the safe default — empty slots read/write garbage space
            d_table=jnp.zeros((B, P), jnp.int32),
            t_table=jnp.zeros((B, P), jnp.int32))
        self._pool_reset()

    def _call(self, which: str, fn, params, *args):
        if self.runner is not None:
            return self.runner(which, fn, *args)
        dp, tp = params
        return fn(dp if which == "draft" else tp, *args)

    def _bank_pull(self):
        """Adopt the bank's current target pages: another engine's jitted
        call may have donated the buffers this state still references."""
        if (self._bank is not None and self._bank.caches is not None
                and self.state is not None
                and self._bank.caches is not self.state.t_caches):
            self.state = self.state._replace(t_caches=self._bank.caches)

    def _bank_push(self):
        """Publish the (possibly donated-and-replaced) target pages back
        to the bank for the next engine."""
        if self._bank is not None and self.state is not None:
            self._bank.caches = self.state.t_caches

    # -------------------------------------------------------------- queries
    @property
    def accepted_per_round(self) -> float:
        """Mean committed tokens per row per verify pass, in [1, K+1]
        (> 1 means speculation is paying: extra tokens rode each target
        pass)."""
        return safe_ratio(self.stats["committed_tokens"],
                          self.stats["row_rounds"])

    def pending_slots(self) -> int:
        return sum(len(ps.gens) for ps in self._pending)

    def free_pages(self) -> int:
        """Admission headroom is the TIGHTER column."""
        return min(self._d_pages.free_pages(), self._t_pages.free_pages())

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        """Pages one row needs per column: a round's block writes run up
        to ``k_max`` positions past the last committed token (position
        ``prompt_len + max_new - 2 + k_max`` at worst), and the admission
        bound ``prompt + max_new + k_max <= max_len`` guarantees that
        slack exists inside the row's virtual space."""
        return max(1, -(-(prompt_len + max_new + self.k_max - 1)
                        // self.page_size))

    def can_admit(self, tokens, max_new: int) -> bool:
        if not SlotPool.can_admit(self, tokens, max_new):
            return False
        tokens = np.asarray(tokens)
        b, S = (1, tokens.shape[0]) if tokens.ndim == 1 else tokens.shape
        needed = b * self.pages_needed(S, max_new)
        if needed > self._d_pages.free_pages():
            self.last_admit_block = "pages"
            return False               # the draft column has no cache to
        #                                reclaim from — pages or nothing
        t_needed = needed
        protect = []
        if self.prefix_cache and b == 1 and self.prefill_chunk is None:
            plan = self._prefix_plan(tokens.reshape(1, S), max_new,
                                     peek=True)
            if plan is not None:
                retained, cow_src, _, owned = plan
                t_needed = owned       # shared pages cost nothing
                protect = retained + ([cow_src] if cow_src is not None
                                      else [])
        if t_needed <= self._t_pages.free_pages():
            return True
        self._reclaim(t_needed - self._t_pages.free_pages(),
                      protect=protect)
        ok = t_needed <= self._t_pages.free_pages()
        if not ok:
            self.last_admit_block = "pages"
        return ok

    # ------------------------------------------------------ page allocation
    def _take_d_pages(self, b: int, npages: int):
        """Allocate the draft column's pages and build the (b, P) tables
        (unused tail entries point at the park page)."""
        pages = self._d_pages.take(b * npages)
        tables = np.full((b, self.pages_per_row), PagePool.PARK, np.int32)
        for i in range(b):
            tables[i, :npages] = pages[i * npages:(i + 1) * npages]
        return tables, pages

    # ------------------------------------------------------------- admission
    def admit(self, params, tokens, max_new: int,
              metas: Optional[list] = None,
              seeds: Optional[list] = None,
              submitted_at: Optional[float] = None) -> list[Generation]:
        """Admit (b, S) prompt rows into b free slots (both columns).

        Needs ``k_max`` extra cache slack beyond ``max_new``: a round's
        block writes run up to K positions past the last committed token
        (and adaptive K may rise back to ``k_max`` at any round)."""
        if seeds and any(s is not None for s in seeds):
            raise ValueError("SpecEngine does not honor per-request seeds; "
                             "route seeded requests to a plain context")
        tokens, _, _ = self._admit_args(tokens, metas, seeds)
        b, S = tokens.shape
        if S + max_new + self.k_max > self.max_len:
            raise ValueError(
                f"prompt {S} + {max_new} new + {self.k_max} speculative "
                f"slack exceeds max_len {self.max_len}")
        self._bank_pull()
        try:
            if self.prefill_chunk is not None:
                return self._admit_chunked(tokens, max_new, metas,
                                           submitted_at)
            plan = (self._prefix_plan(tokens, max_new)
                    if self.prefix_cache else None)
            if plan is not None:
                return self._admit_prefix_hit(params, tokens, max_new,
                                              metas, plan, submitted_at)
            return self._admit_cold(params, tokens, max_new, metas,
                                    submitted_at)
        finally:
            self._bank_push()

    def _admit_cold(self, params, tokens, max_new, metas, submitted_at):
        """One-shot cold admission: whole-prompt prefill into both
        columns' freshly-taken pages."""
        b, S = tokens.shape
        slots = self._take_slots(b)
        npages = self.pages_needed(S, max_new)
        t_pages = []
        try:
            t_tables, t_pages = self._take_pages(b, S, max_new)
            d_tables, d_pages = self._take_d_pages(b, npages)
        except BaseException:
            self._restore_slots(slots)
            if t_pages:
                self._t_pages.restore(t_pages)
            raise
        try:
            tk = jnp.asarray(tokens, jnp.int32)
            sl = jnp.asarray(slots, jnp.int32)
            first, self.state = self._call(
                "target", self._admit_target_fn, params, self.state, tk,
                sl, jnp.asarray(t_tables))
            self.state = self._call(
                "draft", self._admit_draft_fn, params, self.state, tk, sl,
                jnp.asarray(d_tables))
        except BaseException:
            self._restore_slots(slots)   # failed admit must not leak slots
            self._t_pages.restore(t_pages)   # nor either column's pages
            self._d_pages.restore(d_pages)
            raise
        gens = self._register(slots, S, max_new, metas,
                              first=np.asarray(first),
                              submitted_at=submitted_at)
        for i, g in enumerate(gens):
            g.pages = t_pages[i * npages:(i + 1) * npages]
            self._d_owned[g.slot] = d_pages[i * npages:(i + 1) * npages]
            self._index_prompt(tokens[i], g.pages)
        self.stats["admitted_tokens"] += b
        if self._retire_done(gens):
            # same-boundary re-admission of an instantly retired slot must
            # not reuse this draw field (salt disjoint from round folds)
            self._salt_admit_key()
        return gens

    def _admit_prefix_hit(self, params, tokens, max_new, metas, plan,
                          submitted_at):
        """One-shot admission on a target-column prefix hit: the matched
        pages map read-only into the new row's target table, the boundary
        page is copied-on-write when the divergence lands inside one, and
        only the prompt's un-cached suffix runs through the target.  The
        draft column has no sharing — it prefills the whole prompt cold
        into its own pages."""
        b, S = tokens.shape
        retained, cow_src, d, owned = plan
        slots = self._take_slots(b)
        npages = self.pages_needed(S, max_new)
        try:
            t_table, t_pages, fresh = self._take_prefix_pages(plan, S,
                                                              max_new)
        except BaseException:
            self._restore_slots(slots)
            raise
        try:
            d_tables, d_pages = self._take_d_pages(b, npages)
        except BaseException:
            self._restore_slots(slots)
            self._drop_prefix_pages(plan, fresh)
            raise
        jslots = jnp.asarray(slots, jnp.int32)
        jtable = jnp.asarray(t_table)
        try:
            if cow_src is not None:
                self.state = self._call(
                    "target", self._copy_t_fn, params, self.state,
                    jnp.asarray([cow_src], jnp.int32),
                    jnp.asarray([fresh[0]], jnp.int32))
            first, self.state = self._call(
                "target", self._admit_t_hit_fn, params, self.state,
                jnp.asarray(tokens[:, d:], jnp.int32),
                jnp.full((b,), d, jnp.int32), jslots, jtable,
                jnp.full((b,), S - d, jnp.int32))
            self.state = self._call(
                "draft", self._admit_draft_fn, params, self.state,
                jnp.asarray(tokens, jnp.int32), jslots,
                jnp.asarray(d_tables))
        except BaseException:
            self._restore_slots(slots)
            self._drop_prefix_pages(plan, fresh)
            self._d_pages.restore(d_pages)
            raise
        if cow_src is not None:
            self._t_pages.release([cow_src])     # copy done: pin drops
        gens = self._register(slots, S, max_new, metas,
                              first=np.asarray(first),
                              submitted_at=submitted_at)
        gens[0].pages = t_pages
        self._d_owned[gens[0].slot] = d_pages
        self._index_prompt(tokens[0], t_pages)
        self.stats["admitted_tokens"] += b
        self.stats["prefix_hits"] += 1
        self.stats["prefix_pages_mapped"] += len(retained)
        if cow_src is not None:
            self.stats["cow_copies"] += 1
        if self._trace.enabled:
            self._trace.instant(
                f"prefix-hit:{gens[0].rid}", f"{self.telemetry.prefix}eng",
                args={"mapped": len(retained), "cow": cow_src is not None})
        if self._retire_done(gens):
            self._salt_admit_key()
        return gens

    def _admit_chunked(self, tokens, max_new, metas, submitted_at):
        """Reserve slots + pages in both columns and queue the prompt;
        each engine tick streams one (b, C) chunk into BOTH columns.  No
        position parking is needed (unlike the row engine): pending rows
        are not live, so every round-program write they'd make is routed
        to the park page by the live/wmask plumbing."""
        b, S = tokens.shape
        slots = self._take_slots(b)
        npages = self.pages_needed(S, max_new)
        t_pages = []
        try:
            t_tables, t_pages = self._take_pages(b, S, max_new)
            d_tables, d_pages = self._take_d_pages(b, npages)
        except BaseException:
            self._restore_slots(slots)
            if t_pages:
                self._t_pages.restore(t_pages)
            raise
        jslots = jnp.asarray(slots, jnp.int32)
        # tables go live at reserve time: the rounds that run while the
        # prompt streams in don't read them (dead rows park), the chunk
        # programs write through an explicit arg, and the final chunk's
        # sampled row needs them next round
        self.state = self.state._replace(
            t_table=self.state.t_table.at[jslots].set(
                jnp.asarray(t_tables)),
            d_table=self.state.d_table.at[jslots].set(
                jnp.asarray(d_tables)))
        gens = self._register(slots, S, max_new, metas,
                              submitted_at=submitted_at)
        for i, g in enumerate(gens):
            g.pages = t_pages[i * npages:(i + 1) * npages]
            self._d_owned[g.slot] = d_pages[i * npages:(i + 1) * npages]
        self._pending.append(_SpecPending(
            tokens=np.asarray(tokens, np.int32), gens=gens,
            t_tables=t_tables, d_tables=d_tables))
        return gens

    def prefill_tick(self, params) -> list[Generation]:
        """Run at most ONE chunk tick — one (b, C) chunk into EACH
        column — the admission budget per round.  Returns generations
        that finished at this boundary (a final chunk can instant-retire:
        steps==1, or EOS as the first token)."""
        if not self._pending:
            return []
        C = self.prefill_chunk
        ps = self._pending[0]
        b, S = ps.tokens.shape
        start = ps.done
        end = min(start + C, S)
        nvalid = end - start
        chunk = np.zeros((b, C), np.int32)
        chunk[:, :nvalid] = ps.tokens[:, start:end]
        pos = jnp.full((b,), start, jnp.int32)
        nv = jnp.full((b,), nvalid, jnp.int32)
        jchunk = jnp.asarray(chunk)
        t0 = self.telemetry.clock()
        try:
            self.state = self._call(
                "draft", self._chunk_d_fn, params, self.state, jchunk,
                pos, jnp.asarray(ps.d_tables), nv)
            if end < S:
                self.state = self._call(
                    "target", self._chunk_t_fn, params, self.state,
                    jchunk, pos, jnp.asarray(ps.t_tables), nv)
                ps.done = end
                self._note_chunk(ps, t0, start, end, final=False)
                return []
            slots = jnp.asarray([g.slot for g in ps.gens], jnp.int32)
            first, self.state = self._call(
                "target", self._chunk_t_final_fn, params, self.state,
                jchunk, pos, slots, jnp.asarray(ps.t_tables), nv)
        except BaseException:
            # a failed chunk abandons the whole request: release its rows
            # so the pool keeps serving (the caller fails the futures).
            # Each column's pages restore in ONE call, in their original
            # take order — per-gen restores would break FIFO determinism.
            self._pending.popleft()
            t_pg, d_pg = [], []
            for g in ps.gens:
                self.slots[g.slot] = None
                t_pg += g.pages or []
                g.pages = None
                d_pg += self._d_owned.pop(g.slot, [])
            if t_pg:
                self._t_pages.restore(t_pg)
            if d_pg:
                self._d_pages.restore(d_pg)
            self._restore_slots([g.slot for g in ps.gens])
            raise
        self._pending.popleft()
        self._note_chunk(ps, t0, start, end, final=True)
        first = np.asarray(first)
        tok_now = self.telemetry.clock()
        for i, g in enumerate(ps.gens):
            g.tokens.append(int(first[i]))
            self._live[g.slot] = True
            self.stats["tokens_out"] += 1
            self._note_first_token(g, tok_now)
        self.stats["admitted_tokens"] += b
        for i, g in enumerate(ps.gens):
            # the prompt is now fully written into the target column: its
            # whole pages become indexable (BEFORE retirement, so an
            # instant retire still populates the cache)
            self._index_prompt(ps.tokens[i], g.pages)
        finished = self._retire_done(ps.gens)
        if finished:
            self._salt_admit_key()
        return finished

    # ----------------------------------------------------------- retirement
    def _retire_done(self, gens: list[Generation]) -> list[Generation]:
        """Retire finished rows AND release both columns' pages (FIFO: to
        the back of each free-list).  No device-side table reset is
        needed: the retired slot stops being live, so its writes route to
        the park page from the next round on."""
        finished = SlotPool._retire_done(self, gens)
        for g in finished:
            if g.pages:
                self._t_pages.release(g.pages)
                g.pages = None
            d = self._d_owned.pop(g.slot, None)
            if d:
                self._d_pages.release(d)
        return finished

    # ----------------------------------------------------------------- round
    def step(self, params=None) -> list[Generation]:
        """One engine tick: at most one chunk tick (chunked admission),
        then one speculative round for every live slot — K+1 draft steps,
        one verify pass, 1..K+1 committed tokens per row.  Returns the
        generations that finished at this boundary."""
        self._bank_pull()
        try:
            finished = self.prefill_tick(params) if self._pending else []
            if not self._live.any():
                return finished
            remaining = np.zeros(self.batch_size, np.int32)
            for s, g in enumerate(self.slots):
                if g is not None and self._live[s]:
                    remaining[s] = g.remaining
            live = jnp.asarray(self._live)
            fns = self._programs(self.k)
            t0 = self.telemetry.clock()
            props, dlogits, self.state = self._call(
                "draft", fns["roll"], params, self.state, live)
            if self.tree_width == 1:
                toks, m, self.state = self._call(
                    "target", fns["verify"], params, self.state, props,
                    dlogits, live, jnp.asarray(remaining))
            else:
                (toks, m, alt_depth, alt_tok, rpos,
                 self.state) = self._call(
                    "target", fns["verify"], params, self.state, props,
                    dlogits, live, jnp.asarray(remaining))
                # the target column repaired itself inside the verify
                # program; the draft column repairs here, host-gated (the
                # common all-chain rounds skip the extra draft step)
                alt_live = self._live & (np.asarray(alt_depth) > 0)
                if alt_live.any():
                    self.state = self._call(
                        "draft", self._repair_d_fn, params, self.state,
                        alt_tok[:, None], rpos, jnp.asarray(alt_live))
                    self.stats["draft_steps"] += 1
            toks, m = np.asarray(toks), np.asarray(m)
            now = self.telemetry.clock()
            stepped = []
            committed = 0
            reg = self.telemetry.registry
            for s in range(self.batch_size):
                g = self.slots[s]
                if g is None or not self._live[s]:
                    continue              # empty, or reserved mid-prefill
                new = [int(x) for x in toks[s, :m[s]]]
                if self.eos_id is not None and self.eos_id in new:
                    new = new[:new.index(self.eos_id) + 1]
                g.tokens.extend(new)
                committed += len(new)
                reg.observe("spec_accept_len", float(len(new)),
                            buckets=SPEC_ACCEPT_BUCKETS,
                            doc="tokens committed per row per "
                                "speculative round")
                stepped.append(g)
            self.stats["rounds"] += 1
            self.stats["row_rounds"] += len(stepped)
            self.stats["draft_steps"] += self.k + 1
            self.stats["committed_tokens"] += committed
            self.stats["tokens_out"] += committed
            # per-token latency: the round amortizes over the tokens each
            # row committed (1..K+1); the round itself is not a decode
            # tick.
            self._note_tick(t0, now, safe_ratio(committed, len(stepped)),
                            len(stepped))
            if self._trace.enabled:
                self._trace.instant(
                    "spec-round", f"{self.telemetry.prefix}eng", ts=now,
                    args={"committed": committed, "rows": len(stepped),
                          "k": self.k, "tree_width": self.tree_width,
                          "accepted": [int(x) for x in m if x]})
            return finished + self._retire_done(stepped)
        finally:
            self._bank_push()
