"""Speculative cascade decode: draft/verify subsystem on the step engine.

The paper's Super-Sub cascade (Fig 6a, S1a) runs the small network while
the big network's context streams into the shadow slot — load hidden
behind execution.  ``SpecEngine`` is the LLM-serving analogue at token
granularity: a cheap *draft* context proposes K tokens per round, the
*target* context scores all K in ONE multi-token verify pass
(``LM.verify_step`` over the ``verify_attention`` kernel), and exact
speculative sampling (Leviathan et al.) accepts a prefix + draws one
continuation token — so the committed stream is distributed exactly as
target-only sampling, and greedy output is token-identical to
``StepEngine.generate`` (tested).

Numerics caveat: "token-identical" is exact up to floating point.  The
multi-token verify computes the same values as the one-token loop through
differently-shaped matmuls; in f32 the resulting ulp differences are far
below any realistic logit gap (the identity tests run in f32), but bf16
activations/caches can round a near-tie argmax the other way.  That is a
property of bf16 greedy decode itself, not of the acceptance rule — the
committed distribution is unaffected.

Structure mirrors ``StepEngine``: one fixed-shape slot pool shared by a
draft-cache column and a target-cache column (``SpecState``), admission
prefills BOTH caches into a free slot's rows, rounds advance every live
slot, retirement (EOS / step limit) frees the slot.  Execution routes
through a ``runner(which, fn, *args)`` hook: the continuous scheduler
points it at a ``ContextSwitchEngine`` so the draft rollout runs in the
active slot while the target streams into the shadow slot (and vice
versa) — each draft/target hand-off is an O(1) select flip and reloads
hide behind the other context's execution, per the paper's dual-copy
primitives.

Rollback is positional: a rejected proposal's stale cache writes are
masked by the row's committed position and overwritten later.  That works
for full attention caches only, so both models must be all-attention with
no sliding window (ring writes wrap onto live slots; recurrent mixers
cannot rewind their state).  ``LM.verify_step`` itself stays general —
the engine is the restricted layer.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.serve.pool import Generation, SlotPool
from repro.serve.telemetry import Telemetry, safe_ratio


def speculative_accept(key, proposals, draft_logits, target_logits,
                       temperature: float):
    """Exact speculative sampling: accept/reject K proposals, draw the
    continuation.

    proposals: (B, K) int32 — draft tokens d_1..d_K; draft_logits:
    (B, K, V) — the distributions each d_i was sampled from;
    target_logits: (B, K+1, V) — target distributions for block-relative
    positions 1..K+1.  Returns (tokens (B, K+1), n_accepted (B,)):
    ``tokens[:, :n]`` are the accepted proposals, entry n is the residual
    draw (n < K) or the bonus token from the target's last distribution
    (n == K); entries past n are undefined.  The committed prefix is
    distributed exactly as target-only sampling for ANY draft
    distribution (tested statistically).

    Greedy (temperature == 0): accept while d_i equals the target argmax;
    the continuation is the target argmax — the committed stream is
    token-identical to plain greedy target decode.
    """
    B, K = proposals.shape
    cols = jnp.arange(K + 1, dtype=jnp.int32)[None, :]
    if temperature <= 0.0:
        tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)
        acc = proposals == tgt[:, :K]
        n = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
        nxt = jnp.take_along_axis(tgt, n[:, None], axis=1)[:, 0]
    else:
        p_all = jax.nn.softmax(target_logits.astype(jnp.float32)
                               / temperature, axis=-1)       # (B, K+1, V)
        q_all = jax.nn.softmax(draft_logits.astype(jnp.float32)
                               / temperature, axis=-1)       # (B, K, V)
        pd = jnp.take_along_axis(p_all[:, :K], proposals[..., None],
                                 axis=-1)[..., 0]            # (B, K)
        qd = jnp.take_along_axis(q_all, proposals[..., None],
                                 axis=-1)[..., 0]
        u = jax.random.uniform(key, (B, K), jnp.float32)
        acc = u * qd <= pd            # accept w.p. min(1, p/q); p==q -> 1
        n = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
        # residual at the rejection point: r ∝ max(p - q, 0); all-accepted
        # rows pad q with zeros so the "residual" is the bonus draw from p
        q_pad = jnp.concatenate(
            [q_all, jnp.zeros_like(q_all[:, :1])], axis=1)
        pn = jnp.take_along_axis(p_all, n[:, None, None], axis=1)[:, 0]
        qn = jnp.take_along_axis(q_pad, n[:, None, None], axis=1)[:, 0]
        r = jnp.clip(pn - qn, 0.0, None)
        rs = jnp.sum(r, axis=-1, keepdims=True)
        r = jnp.where(rs > 0, r / jnp.maximum(rs, 1e-30), pn)
        g = jax.random.gumbel(jax.random.fold_in(key, 1),
                              r.shape, jnp.float32)
        nxt = jnp.argmax(jnp.log(r + 1e-30) + g, axis=-1).astype(jnp.int32)
    props_pad = jnp.concatenate([proposals, proposals[:, :1]], axis=1)
    tokens = jnp.where(cols < n[:, None], props_pad,
                       jnp.where(cols == n[:, None], nxt[:, None], 0))
    return tokens.astype(jnp.int32), n.astype(jnp.int32)


class SpecState(NamedTuple):
    """Device half of the speculative pool (a pytree; donated each call).

    One slot pool, two cache columns: at every round boundary both caches
    hold exactly the committed prefix (positions <= pos-1) and ``tok`` is
    the last committed token at position ``pos`` — the same invariant
    ``decode_step`` keeps, so draft and target stay interchangeable views
    of one sequence."""
    d_caches: Any         # draft decode-cache pytree, leaves (R, B, ...)
    t_caches: Any         # target decode-cache pytree
    tok: jax.Array        # (B, 1) int32 — last committed token per slot
    pos: jax.Array        # (B,) int32  — its cache position
    key: jax.Array        # PRNG key, folded once per round
    t: jax.Array          # () int32    — round counter


class SpecEngine(SlotPool):
    """Speculative continuous-batching engine for one draft/target pair.

    Host surface is the shared ``SlotPool`` base ``StepEngine`` also
    builds on (slots, free-list, ``admit``, ``step``, ``drain``) so the
    continuous scheduler drives either interchangeably; one ``step()`` is
    a full speculative ROUND — a K+1 draft rollout plus one multi-token
    verify — committing between 1 and K+1 tokens per live row.

    ``params`` per call is ``(draft_params, target_params)``, or ``None``
    when ``runner`` is set: the scheduler's runner receives
    ``(which, fn, *args)`` with ``which`` in {"draft", "target"} and runs
    the program against the right context slot (switching + hidden-load
    accounting included) — the engine never captures weights.
    """

    def __init__(self, draft: LM, target: LM, batch_size: int, max_len: int,
                 k: int = 4, temperature: float = 0.0, seed: int = 0,
                 eos_id: Optional[int] = None,
                 telemetry: Optional[Telemetry] = None):
        for m, role in ((draft, "draft"), (target, "target")):
            if any(mix != "attn" for mix, _ in m.pattern):
                raise ValueError(
                    f"speculative decode needs an all-attention {role} "
                    "(recurrent state cannot rewind a rejected proposal)")
            if m.cfg.sliding_window:
                raise ValueError(
                    f"speculative decode needs a full-cache {role} (ring "
                    "writes wrap onto slots a rollback must preserve)")
        if draft.cfg.vocab_size != target.cfg.vocab_size:
            raise ValueError("draft and target must share a vocabulary")
        self.draft_model = draft
        self.target_model = target
        self.batch_size = batch_size
        self.max_len = max_len
        self.k = k
        self.temperature = temperature
        self.seed = seed
        self.eos_id = eos_id

        B, K, T = batch_size, k, temperature
        V = target.cfg.vocab_size

        def _admit_target(tparams, state: SpecState, tokens, slots):
            """Target prefill into cache rows `slots` + first-token draw
            (the target's draw: the committed stream must be target-
            distributed from token one).  Past t=0 the draw key is salted
            (same hazard and same salt as ``StepEngine._admit``): the
            stored key equals round t-1's roll base, whose small-integer
            folds generated that round's draft fields — an unsalted
            admission at t <= K would reuse one of them."""
            S = tokens.shape[1]
            logits, rows = target.prefill(tparams, tokens, max_len)
            last = logits[:, -1]
            if T > 0.0:
                salted = jax.random.fold_in(state.key,
                                            (1 << 30) ^ state.t)
                akey = jnp.where(state.t == 0, state.key, salted)
                g = jax.random.gumbel(akey, (B, V), jnp.float32)
                first = jnp.argmax(last / T + g[slots], axis=-1)
            else:
                first = jnp.argmax(last, axis=-1)
            first = first.astype(jnp.int32)
            t_caches = target.insert_cache_rows(state.t_caches, rows, slots)
            return first, state._replace(
                t_caches=t_caches,
                tok=state.tok.at[slots].set(first[:, None]),
                pos=state.pos.at[slots].set(jnp.int32(S)))

        def _admit_draft(dparams, state: SpecState, tokens, slots):
            """Draft prefill into the same slots (its last-token logits are
            unused — the draft only needs the prompt in its cache)."""
            _, rows = draft.prefill(dparams, tokens, max_len)
            return state._replace(
                d_caches=draft.insert_cache_rows(state.d_caches, rows,
                                                 slots))

        def _roll(dparams, state: SpecState):
            """K+1 draft decode steps from the committed token: iteration i
            feeds block token i at pos+i, sampling proposal d_{i+1}.  The
            extra iteration feeds d_K so its k/v lands in the draft cache
            (needed when the whole block is accepted); its sample is
            discarded.  Returns proposals (B, K), their logits (B, K, V),
            and the rolled draft caches."""
            base = jax.random.fold_in(state.key, state.t)

            def body(carry, i):
                caches, tok = carry
                logits, caches = draft.decode_step(dparams, caches, tok,
                                                   state.pos + i)
                last = logits[:, -1]
                if T > 0.0:
                    g = jax.random.gumbel(jax.random.fold_in(base, i),
                                          (B, V), jnp.float32)
                    nxt = jnp.argmax(last / T + g, axis=-1)
                else:
                    nxt = jnp.argmax(last, axis=-1)
                nxt = nxt.astype(jnp.int32)
                return (caches, nxt[:, None]), (nxt, last)

            (d_caches, _), (props, dlogits) = jax.lax.scan(
                body, (state.d_caches, state.tok),
                jnp.arange(K + 1, dtype=jnp.int32))
            return (props[:K].T, dlogits[:K].transpose(1, 0, 2),
                    state._replace(d_caches=d_caches))

        def _verify(tparams, state: SpecState, props, dlogits, live,
                    remaining):
            """One multi-token target pass over [t0, d_1..d_K] + exact
            accept/reject.  Commits m = min(n_accepted+1, remaining)
            tokens per live row; stale cache writes past pos+m are masked
            by position and overwritten by later rounds."""
            block = jnp.concatenate([state.tok, props], axis=1)  # (B, K+1)
            logits, t_caches = target.verify_step(tparams, state.t_caches,
                                                  block, state.pos)
            vkey = jax.random.fold_in(
                jax.random.fold_in(state.key, state.t), 1 << 20)
            toks, n = speculative_accept(vkey, props, dlogits, logits, T)
            m = jnp.where(live, jnp.minimum(n + 1, remaining), 0)
            tok_new = jnp.take_along_axis(
                toks, jnp.clip(m - 1, 0, K)[:, None], axis=1)
            tok_new = jnp.where(m[:, None] > 0, tok_new, state.tok)
            pos_new = jnp.minimum(state.pos + m, max_len - 1)
            # advance the key once per round (like StepEngine._step): a
            # later admission must draw from a FRESH field, not the one
            # every earlier admission into that slot already used
            return toks, m, state._replace(
                t_caches=t_caches, tok=tok_new, pos=pos_new,
                key=jax.random.fold_in(state.key, state.t), t=state.t + 1)

        self._admit_target_fn = jax.jit(_admit_target, donate_argnums=(1,))
        self._admit_draft_fn = jax.jit(_admit_draft, donate_argnums=(1,))
        self._roll_fn = jax.jit(_roll, donate_argnums=(1,))
        self._verify_fn = jax.jit(_verify, donate_argnums=(1,))

        # Execution hook: when set, every device program runs as
        # ``runner(which, fn, *args)`` with which in {"draft", "target"} —
        # the continuous scheduler activates the matching context slot and
        # prefetches the other into the shadow slot before each call.
        self.runner = None

        self.state: Optional[SpecState] = None
        self._pool_init(B, telemetry=telemetry)
        # speculative accounting rides the shared pool counters; the tick
        # counters stay 0 — a round is not a decode round-trip and must
        # not skew the steps-per-tick aggregate.
        self.stats.update({"rounds": 0, "row_rounds": 0, "draft_steps": 0,
                           "committed_tokens": 0, "admitted_tokens": 0})
        self.reset()

    # ------------------------------------------------------------- lifecycle
    def reset(self, seed: Optional[int] = None):
        B = self.batch_size
        caches = None
        if self.state is not None and not any(
                getattr(x, "is_deleted", lambda: False)()
                for x in jax.tree.leaves((self.state.d_caches,
                                          self.state.t_caches))):
            caches = (self.state.d_caches, self.state.t_caches)
        if caches is None:
            caches = (self.draft_model.init_cache(B, self.max_len),
                      self.target_model.init_cache(B, self.max_len))
        self.state = SpecState(
            d_caches=caches[0], t_caches=caches[1],
            tok=jnp.zeros((B, 1), jnp.int32),
            pos=jnp.zeros((B,), jnp.int32),
            key=jax.random.PRNGKey(self.seed if seed is None else seed),
            t=jnp.zeros((), jnp.int32))
        self._pool_reset()

    def _call(self, which: str, fn, params, *args):
        if self.runner is not None:
            return self.runner(which, fn, *args)
        dp, tp = params
        return fn(dp if which == "draft" else tp, *args)

    # -------------------------------------------------------------- queries
    @property
    def accepted_per_round(self) -> float:
        """Mean committed tokens per row per verify pass, in [1, K+1]
        (> 1 means speculation is paying: extra tokens rode each target
        pass)."""
        return safe_ratio(self.stats["committed_tokens"],
                          self.stats["row_rounds"])

    # ------------------------------------------------------------- admission
    def admit(self, params, tokens, max_new: int,
              metas: Optional[list] = None,
              seeds: Optional[list] = None,
              submitted_at: Optional[float] = None) -> list[Generation]:
        """Admit (b, S) prompt rows into b free slots (both caches).

        Needs ``k`` extra cache slack beyond ``max_new``: a round's block
        writes run up to K positions past the last committed token."""
        if seeds and any(s is not None for s in seeds):
            raise ValueError("SpecEngine does not honor per-request seeds; "
                             "route seeded requests to a plain context")
        tokens, _, _ = self._admit_args(tokens, metas, seeds)
        b, S = tokens.shape
        if S + max_new + self.k > self.max_len:
            raise ValueError(
                f"prompt {S} + {max_new} new + {self.k} speculative slack "
                f"exceeds max_len {self.max_len}")
        slots = self._take_slots(b)
        try:
            tk = jnp.asarray(tokens, jnp.int32)
            sl = jnp.asarray(slots, jnp.int32)
            first, self.state = self._call("target", self._admit_target_fn,
                                           params, self.state, tk, sl)
            self.state = self._call("draft", self._admit_draft_fn, params,
                                    self.state, tk, sl)
        except BaseException:
            self._restore_slots(slots)
            raise
        gens = self._register(slots, S, max_new, metas,
                              first=np.asarray(first),
                              submitted_at=submitted_at)
        self.stats["admitted_tokens"] += b
        if self._retire_done(gens):
            # same-boundary re-admission of an instantly retired slot must
            # not reuse this draw field (salt disjoint from round folds)
            self._salt_admit_key()
        return gens

    # ----------------------------------------------------------------- round
    def step(self, params=None) -> list[Generation]:
        """One speculative round for every live slot: K+1 draft steps, one
        verify pass, 1..K+1 committed tokens per row.  Returns the
        generations that finished at this boundary."""
        if not self._live.any():
            return []
        remaining = np.zeros(self.batch_size, np.int32)
        for s, g in enumerate(self.slots):
            if g is not None:
                remaining[s] = g.remaining
        live = jnp.asarray(self._live)
        t0 = self.telemetry.clock()
        props, dlogits, self.state = self._call(
            "draft", self._roll_fn, params, self.state)
        toks, m, self.state = self._call(
            "target", self._verify_fn, params, self.state, props, dlogits,
            live, jnp.asarray(remaining))
        toks, m = np.asarray(toks), np.asarray(m)
        now = self.telemetry.clock()
        stepped = []
        committed = 0
        for s in range(self.batch_size):
            g = self.slots[s]
            if g is None:
                continue
            new = [int(x) for x in toks[s, :m[s]]]
            if self.eos_id is not None and self.eos_id in new:
                new = new[:new.index(self.eos_id) + 1]
            g.tokens.extend(new)
            committed += len(new)
            stepped.append(g)
        self.stats["rounds"] += 1
        self.stats["row_rounds"] += len(stepped)
        self.stats["draft_steps"] += self.k + 1
        self.stats["committed_tokens"] += committed
        self.stats["tokens_out"] += committed
        # per-token latency: the round amortizes over the tokens each row
        # committed (1..K+1); the round itself is not a decode tick.
        self._note_tick(t0, now, safe_ratio(committed, len(stepped)),
                        len(stepped))
        if self._trace.enabled:
            self._trace.instant(
                "spec-round", f"{self.telemetry.prefix}eng", ts=now,
                args={"committed": committed, "rows": len(stepped),
                      "k": self.k,
                      "accepted": [int(x) for x in m if x]})
        return self._retire_done(stepped)
