"""Context-switching serving — the paper's architecture applied to the
serving tier.

``SwitchableServer`` keeps N model contexts behind a ``ContextSwitchEngine``:
the active model serves batched requests while the next model's weights
stream into the shadow slot; switching models is an O(1) activation flip.
Which context loads/evicts when is decided by the engine's shared
``ReconfigPolicy`` — the same object the analytical simulator runs.

One ``ServingEngine`` (jitted prefill/decode) is cached per context, so a
multi-step request never re-compiles; sampling threads a fresh per-request
seed so temperature>0 requests are independent draws.  Per-context decode
state (KV caches / SSM states) can be snapshotted with the slot, which goes
beyond the paper (an FPGA loses flip-flop state on switch).

For request-level scheduling (queueing, coalescing, shadow-slot prefetch
under mixed traffic) see ``repro.serve.scheduler.SwitchScheduler``.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.context import ContextDescriptor, ContextSwitchEngine
from repro.core.policy import ReconfigPolicy
from repro.models.model import LM
from repro.serve.engine import (EngineKey, ServingEngine, StepEngine,
                                _sample)
from repro.serve.pool import PagePool, SharedBank, ShardedPagePool
from repro.serve.speculative import SpecEngine, SpecKey
from repro.serve.telemetry import Telemetry


@dataclass
class ServedModel:
    name: str
    model: LM
    weights_fn: Callable[[], Any]
    max_len: int = 256
    temperature: float = 0.0


class SwitchableServer:
    def __init__(self, num_slots: int = 2, mesh=None,
                 policy: Optional[ReconfigPolicy] = None,
                 telemetry: Optional[Telemetry] = None):
        # one shared registry/tracer/clock for the whole serving stack:
        # the context engine writes ``ctx.*``, each pooled engine gets
        # ``eng.<i>.*``, schedulers write ``sched.*``, and request-level
        # histograms land unprefixed — one snapshot sees every layer
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.engine = ContextSwitchEngine(num_slots=num_slots, mesh=mesh,
                                          policy=policy,
                                          telemetry=self.telemetry)
        self._served: dict[str, ServedModel] = {}
        self._engines: dict[str, ServingEngine] = {}   # jit cache per context
        self._step_engines: dict[EngineKey, StepEngine] = {}
        self._spec_engines: dict[SpecKey, SpecEngine] = {}
        # shared page banks, keyed by BANK CONTENT — (context name,
        # page_size, quantize_kv) — never by pool shape: any engine whose
        # pages would hold the same bytes (a plain paged pool, a spec
        # target column, any batch size) resolves to the same bank, so a
        # prefix one engine indexed is a hit for all of them
        self._banks: dict[tuple, SharedBank] = {}
        self._eng_seq = itertools.count()   # telemetry namespace ids
        self._state_snapshots: dict[str, Any] = {}
        self._req_seq = itertools.count()
        self.log: list[dict] = []

    # ------------------------------------------------------------------
    def register(self, sm: ServedModel):
        self._served[sm.name] = sm

        def apply_fn(params, tokens, key):
            logits, caches = sm.model.prefill(params, tokens, sm.max_len)
            return _sample(logits[:, -1], key, sm.temperature)

        self.engine.register(ContextDescriptor(
            name=sm.name, apply_fn=apply_fn, weights_fn=sm.weights_fn))

    def served(self) -> list[str]:
        return list(self._served)

    def preload(self, name: str, block: bool = False):
        return self.engine.preload(name, block=block)

    def next_seed(self) -> int:
        """Monotonic per-request sampling seed (identical prompts at
        temperature>0 must be independent draws, not clones)."""
        return next(self._req_seq)

    def _serving_engine(self, name: str, params) -> ServingEngine:
        """Per-context ServingEngine cache: prefill/decode are jitted once
        at first use ("synthesis time"), then reused across every request
        and every switch — only the params pointer is refreshed (the slot
        may have been evicted and reloaded since)."""
        eng = self._engines.get(name)
        if eng is None:
            sm = self._served[name]
            eng = ServingEngine(sm.model, params, sm.max_len, sm.temperature,
                                telemetry=self.telemetry.scoped(
                                    f"eng.{next(self._eng_seq)}."))
            self._engines[name] = eng
        else:
            eng.params = params
        return eng

    def shared_bank(self, name: str, page_size: int,
                    quantize_kv: Optional[str] = None,
                    num_pages: Optional[int] = None,
                    num_shards: int = 1) -> SharedBank:
        """Get-or-create the shared page bank for one cache content —
        ``(context name, page_size, quantize_kv)``.  The first caller
        sizes the pool (``num_pages``, and ``num_shards`` > 1 for a
        sharded bank); later callers allocate from it whatever their
        batch size or engine kind, and all of them see one
        ``PrefixIndex`` over those pages."""
        key = (name, int(page_size), quantize_kv)
        bank = self._banks.get(key)
        if bank is None:
            if num_pages is None:
                raise ValueError(
                    f"shared bank {key} does not exist yet: the first "
                    "caller must size it (num_pages)")
            tel = self.telemetry.scoped(f"eng.{next(self._eng_seq)}.")
            pool = (ShardedPagePool(num_pages, num_shards, telemetry=tel)
                    if num_shards > 1 else PagePool(num_pages,
                                                   telemetry=tel))
            bank = SharedBank(pool)
            self._banks[key] = bank
        elif num_shards != getattr(bank.pool, "num_shards", 1):
            raise ValueError(
                f"shared bank {key} has {getattr(bank.pool, 'num_shards', 1)} "
                f"shard(s); requested {num_shards}")
        return bank

    def step_engine(self, name: str, batch_size: int,
                    prefill_chunk: Optional[int] = None,
                    paged: bool = False,
                    page_size: int = 256,
                    multi_step: int = 1,
                    quantize_kv: Optional[str] = None,
                    prefix_cache: bool = False,
                    num_pages: Optional[int] = None,
                    share_bank: bool = False,
                    shards: Optional[int] = None,
                    mesh=None) -> StepEngine:
        """Per-context continuous-batching engine (jitted once per pool
        shape at first use).  Its decode state — slot-pooled KV rows,
        positions, free-list — persists across context switches, so a
        paused context resumes exactly where its last step left off;
        weights are NOT captured (every call runs against the engine
        slot's current buffers via the scheduler's runner hook).  Every
        engine knob is a field of the frozen ``EngineKey``: each
        combination builds different jitted programs (and for int8 or a
        prefix cache, different bank bookkeeping) over the same pool
        shape, and a knob that isn't in the key cannot exist."""
        sm = self._served[name]
        eff_ps = min(page_size, sm.max_len) if paged else None
        n_shards = shards if shards is not None else (
            mesh.shape[mesh.axis_names[0]] if mesh is not None else 1)
        key = EngineKey(name=name, batch_size=batch_size,
                        prefill_chunk=prefill_chunk,
                        page_size=eff_ps,
                        multi_step=multi_step, quantize_kv=quantize_kv,
                        prefix_cache=prefix_cache,
                        shared_bank=share_bank, shards=n_shards)
        eng = self._step_engines.get(key)
        if eng is None:
            bank = None
            if share_bank:
                if not paged:
                    raise ValueError("share_bank needs paged=True")
                ppr = sm.max_len // eff_ps
                need = batch_size * ppr
                default_np = (n_shards * (-(-need // n_shards) + 1)
                              if n_shards > 1 else need + 1)
                bank = self.shared_bank(
                    name, eff_ps, quantize_kv,
                    num_pages=(num_pages if num_pages is not None
                               else default_np),
                    num_shards=n_shards)
            eng = StepEngine(sm.model, batch_size, sm.max_len,
                             temperature=sm.temperature,
                             prefill_chunk=prefill_chunk,
                             paged=paged, page_size=page_size,
                             multi_step=multi_step,
                             quantize_kv=quantize_kv,
                             prefix_cache=prefix_cache,
                             num_pages=num_pages, bank=bank,
                             shards=shards, mesh=mesh,
                             telemetry=self.telemetry.scoped(
                                 f"eng.{next(self._eng_seq)}."))
            self._step_engines[key] = eng
        return eng

    def spec_engine(self, name: str, draft: str, batch_size: int,
                    k: int = 4, tree_width: int = 1,
                    page_size: Optional[int] = None,
                    num_pages: Optional[int] = None,
                    prefill_chunk: Optional[int] = None,
                    prefix_cache: bool = False,
                    quantize_kv: Optional[str] = None,
                    share_bank: bool = False) -> SpecEngine:
        """Per-(target, draft) speculative engine (jitted once per pool
        shape).  Like ``step_engine``, decode state persists across
        context switches and weights are never captured — every draft /
        target program runs against the matching context slot via the
        scheduler's runner hook.  ``k`` is the engine's K_MAX: adaptive
        schedulers move ``eng.set_k`` under it without changing which
        engine serves the pair.  With ``share_bank`` the TARGET column
        allocates from (and indexes prefixes into) the context's shared
        bank, so prompts cached by a plain paged engine of ``name`` are
        prefix hits here and vice versa; the draft column always stays
        private (different bytes)."""
        sm, dm = self._served[name], self._served[draft]
        eff_ps = (min(page_size, sm.max_len) if page_size is not None
                  else math.gcd(sm.max_len, 256))
        key = SpecKey(name=name, draft=draft, batch_size=batch_size,
                      k=k, tree_width=tree_width, page_size=eff_ps,
                      quantize_kv=quantize_kv, prefix_cache=prefix_cache,
                      prefill_chunk=prefill_chunk, shared_bank=share_bank)
        eng = self._spec_engines.get(key)
        if eng is None:
            bank = None
            if share_bank:
                ppr = sm.max_len // eff_ps
                bank = self.shared_bank(
                    name, eff_ps, quantize_kv,
                    num_pages=(num_pages if num_pages is not None
                               else batch_size * ppr + 1))
            eng = SpecEngine(dm.model, sm.model, batch_size, sm.max_len,
                             k=k, temperature=sm.temperature,
                             tree_width=tree_width, page_size=eff_ps,
                             num_pages=num_pages,
                             prefill_chunk=prefill_chunk,
                             prefix_cache=prefix_cache,
                             quantize_kv=quantize_kv, bank=bank,
                             telemetry=self.telemetry.scoped(
                                 f"eng.{next(self._eng_seq)}."))
            self._spec_engines[key] = eng
        return eng

    # ------------------------------------------------------------------
    def serve_batch(self, name: str, tokens, steps: int = 1,
                    seed: Optional[int] = None) -> np.ndarray:
        """Serve one batch on `name`, switching contexts if needed.

        The switch is O(1) when `name` is resident (paper case 2); if it is
        still loading, the visible stall is only the *remaining* load time
        (paper case 3 — reconfiguration partially hidden).
        """
        t0 = self.telemetry.clock()
        if seed is None:
            seed = self.next_seed()
        active = self.engine.active
        if active is not None and active.name == name:
            sw = 0.0                         # already selected: no flip
        else:
            self.engine.preload(name)        # no-op if resident
            sw = self.engine.switch(name, wait=True)
        slot = self.engine.active
        if steps == 1:
            out = np.asarray(self.engine.run(jnp.asarray(tokens),
                                             jax.random.PRNGKey(seed)))
        else:
            eng = self._serving_engine(name, slot.buffers)
            out = eng.generate(jnp.asarray(tokens), steps, seed=seed)
        self.log.append({"name": name, "switch_s": sw,
                         "total_s": self.telemetry.clock() - t0,
                         "batch": int(np.asarray(tokens).shape[0]),
                         "steps": steps, "seed": seed})
        return out

    def serve_stream(self, requests: list[tuple[str, Any]],
                     lookahead: bool = True) -> list[np.ndarray]:
        """Serve a stream of (model_name, batch) requests.

        With ``lookahead`` the policy streams the next needed model into
        the shadow slot while the current batch executes — the paper's
        dynamic reconfiguration (victim choice and all, via
        ``engine.prefetch``; no inline slot logic here).
        """
        outs = []
        for i, (name, toks) in enumerate(requests):
            self.engine.preload(name)
            self.engine.switch(name, wait=True)
            if lookahead:
                self.engine.prefetch([n for n, _ in requests[i + 1:]],
                                     limit=1)   # hidden behind this batch
            outs.append(self.serve_batch(name, toks))
        return outs

    # ---------------------------------------------------------------- state
    def snapshot_state(self, name: str, caches):
        """Keep a context's decode state across switches (beyond-paper)."""
        self._state_snapshots[name] = jax.tree.map(jnp.asarray, caches)

    def restore_state(self, name: str):
        return self._state_snapshots.get(name)

    def shutdown(self):
        self.engine.shutdown()
