"""Context-switching serving — the paper's architecture applied to the
serving tier.

``SwitchableServer`` keeps N model contexts behind a ``ContextSwitchEngine``:
the active model serves batched requests while the next model's weights
stream into the shadow slot; switching models is an O(1) activation flip.
Per-context decode state (KV caches / SSM states) is snapshotted with the
slot, which goes beyond the paper (an FPGA loses flip-flop state on switch).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.context import ContextDescriptor, ContextSwitchEngine
from repro.models.model import LM
from repro.serve.engine import ServingEngine, _sample


@dataclass
class ServedModel:
    name: str
    model: LM
    weights_fn: Callable[[], Any]
    max_len: int = 256
    temperature: float = 0.0


class SwitchableServer:
    def __init__(self, num_slots: int = 2, mesh=None):
        self.engine = ContextSwitchEngine(num_slots=num_slots, mesh=mesh)
        self._served: dict[str, ServedModel] = {}
        self._gen_fns: dict[str, Callable] = {}
        self._state_snapshots: dict[str, Any] = {}
        self.log: list[dict] = []

    # ------------------------------------------------------------------
    def register(self, sm: ServedModel):
        self._served[sm.name] = sm

        def apply_fn(params, tokens, key):
            logits, caches = sm.model.prefill(params, tokens, sm.max_len)
            return _sample(logits[:, -1], key, sm.temperature)

        self.engine.register(ContextDescriptor(
            name=sm.name, apply_fn=apply_fn, weights_fn=sm.weights_fn))

    def preload(self, name: str, block: bool = False):
        return self.engine.preload(name, block=block)

    # ------------------------------------------------------------------
    def serve_batch(self, name: str, tokens, steps: int = 1) -> np.ndarray:
        """Serve one batch on `name`, switching contexts if needed.

        The switch is O(1) when `name` is resident (paper case 2); if it is
        still loading, the visible stall is only the *remaining* load time
        (paper case 3 — reconfiguration partially hidden).
        """
        sm = self._served[name]
        t0 = time.perf_counter()
        self.engine.preload(name)            # no-op if resident
        sw = self.engine.switch(name, wait=True)
        slot = self.engine.active
        key = jax.random.PRNGKey(0)
        if steps == 1:
            out = np.asarray(self.engine.run(jnp.asarray(tokens), key))
        else:
            eng = ServingEngine(sm.model, slot.buffers, sm.max_len,
                                sm.temperature)
            out = eng.generate(jnp.asarray(tokens), steps)
        self.log.append({"name": name, "switch_s": sw,
                         "total_s": time.perf_counter() - t0,
                         "batch": int(np.asarray(tokens).shape[0])})
        return out

    def serve_stream(self, requests: list[tuple[str, Any]],
                     lookahead: bool = True) -> list[np.ndarray]:
        """Serve a stream of (model_name, batch) requests.

        With ``lookahead`` the next request's model is preloaded while the
        current batch executes — the paper's dynamic reconfiguration.
        """
        outs = []
        for i, (name, toks) in enumerate(requests):
            if lookahead and i + 1 < len(requests) and \
                    requests[i + 1][0] != name:
                self.engine.preload(requests[i + 1][0])
            outs.append(self.serve_batch(name, toks))
        return outs

    # ---------------------------------------------------------------- state
    def snapshot_state(self, name: str, caches):
        """Keep a context's decode state across switches (beyond-paper)."""
        self._state_snapshots[name] = jax.tree.map(jnp.asarray, caches)

    def restore_state(self, name: str):
        return self._state_snapshots.get(name)

    def shutdown(self):
        self.engine.shutdown()
