"""Switch-aware asynchronous request scheduler.

The paper's timing result — reconfiguration hidden behind execution — only
materializes at serving scale if *something* orders the traffic so that
(a) requests for the resident model run back-to-back (one switch amortized
over many batches) and (b) the next model's weights stream into the shadow
slot while the current streak executes.  A synchronous single-caller server
leaves both to the client.  ``SwitchScheduler`` is that something:

    clients ──submit(name, tokens)──▶ per-context queues
                                         │   pick next context:
                                         │   policy.rank_contexts
                                         │   (queue pressure − load cost,
                                         │    age-boosted for fairness)
                                         ▼
                                   service streak ──▶ SwitchableServer
                                         │                 │
                                         │   engine.prefetch(next ranked)
                                         │   (shadow-slot load hidden
                                         ▼    behind the active streak)
                                      futures resolve

All slot/eviction/prefetch decisions route through the engine's shared
``ReconfigPolicy`` (``repro.core.policy``) — the scheduler only shapes the
traffic.  Same-shape greedy requests inside a streak are stacked into one
forward pass; everything else is served back-to-back after a single switch.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict, deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class _Request:
    name: str
    tokens: np.ndarray
    steps: int
    seed: int
    future: Future
    submitted_at: float


class SwitchScheduler:
    """Async front door over a ``SwitchableServer``.

    ``submit`` enqueues and returns a ``Future``; one scheduler thread
    drains per-context queues in policy-ranked order, coalescing each
    chosen context's backlog into a single service streak and preloading
    the next-ranked context into the shadow slot before the streak runs.

    ``max_streak`` bounds how many requests one context may serve before
    the scheduler re-ranks (starvation bound); ``age_weight`` converts
    request age (seconds) into extra queue pressure so a low-traffic
    context eventually wins over a flooded one.
    """

    def __init__(self, server, max_streak: int = 16,
                 age_weight: float = 10.0, cost_weight: float = 1.0):
        self.server = server
        self.max_streak = max_streak
        self.age_weight = age_weight
        self.cost_weight = cost_weight
        self._queues: dict[str, deque[_Request]] = defaultdict(deque)
        self._cv = threading.Condition()
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        self._load_cost: dict[str, float] = {}   # measured seconds, EMA
        self.stats = {
            "requests": 0, "batches": 0, "streaks": 0,
            "stacked_requests": 0, "busy_seconds": 0.0,
        }

    # ------------------------------------------------------------- client
    def submit(self, name: str, tokens, steps: int = 1,
               seed: Optional[int] = None) -> Future:
        """Enqueue one request; resolves to the (B, steps) output array."""
        if name not in self.server.served():
            raise KeyError(f"model {name!r} not registered")
        fut: Future = Future()
        req = _Request(name=name, tokens=np.asarray(tokens), steps=steps,
                       seed=self.server.next_seed() if seed is None else seed,
                       future=fut, submitted_at=time.perf_counter())
        with self._cv:
            if self._stopping:
                raise RuntimeError("scheduler is stopped")
            self._queues[name].append(req)
            self.stats["requests"] += 1
            self._cv.notify()
        return fut

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "SwitchScheduler":
        assert self._thread is None, "already started"
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="switch-scheduler")
        self._thread.start()
        return self

    def stop(self, drain: bool = True):
        """Stop the loop; with ``drain`` every queued request is served
        first, otherwise leftovers get a RuntimeError.  Requests that can
        no longer drain (scheduler never started, or its thread died) are
        always failed rather than left with futures that never resolve."""
        with self._cv:
            self._stopping = True
            self._drain = drain
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        for q in self._queues.values():
            while q:
                q.popleft().future.set_exception(
                    RuntimeError("scheduler stopped before serving this "
                                 "request"))

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop(drain=exc[0] is None)

    # ------------------------------------------------------------ ranking
    def _pressures(self, now: float) -> dict[str, float]:
        """Queue pressure per context: backlog size plus age boost (an old
        request in a quiet queue counts as much as `age_weight`·seconds of
        backlog, so no context starves)."""
        out = {}
        for name, q in self._queues.items():
            if q:
                age = now - q[0].submitted_at
                out[name] = len(q) + self.age_weight * age
        return out

    def _ranked(self, now: float) -> list[str]:
        return self.server.engine.policy.rank_contexts(
            self._pressures(now), self._load_cost,
            cost_weight=self.cost_weight)

    def _note_load_cost(self, name: str, seconds: float):
        prev = self._load_cost.get(name)
        self._load_cost[name] = (seconds if prev is None
                                 else 0.5 * prev + 0.5 * seconds)

    # --------------------------------------------------------------- loop
    def _loop(self):
        while True:
            with self._cv:
                while not self._stopping and not any(
                        self._queues.values()):
                    self._cv.wait(timeout=0.1)
                if self._stopping and (not getattr(self, "_drain", True)
                                       or not any(self._queues.values())):
                    return
                now = time.perf_counter()
                ranked = self._ranked(now)
                name = ranked[0]
                streak: list[_Request] = []
                q = self._queues[name]
                while q and len(streak) < self.max_streak:
                    streak.append(q.popleft())
                # next context with pending work (after this streak drains)
                upcoming = [n for n in ranked[1:] if self._queues[n]]
                if not upcoming and q:
                    upcoming = [name]        # more of the same backlog
            try:
                self._serve_streak(name, streak, upcoming)
            except BaseException as e:       # backstop: never die with
                for r in streak:             # unresolved futures behind
                    if not r.future.done():
                        r.future.set_exception(e)

    def _serve_streak(self, name: str, streak: list[_Request],
                      upcoming: list[str]):
        engine = self.server.engine
        t0 = time.perf_counter()
        try:
            was_resident = engine.policy.holds(name)
            engine.preload(name)
            engine.switch(name, wait=True)
        except BaseException as e:           # context unloadable: fail the
            for r in streak:                 # streak, keep the loop alive
                if not r.future.done():
                    r.future.set_exception(e)
            return
        if not was_resident:
            self._note_load_cost(name, time.perf_counter() - t0)
        # the paper's dynamic reconfiguration: next context streams into
        # the shadow slot while this streak executes (policy picks victims).
        # Prefetch is advisory: a failure must not take the streak down
        # (the next streak pays a demand load instead).
        try:
            engine.prefetch(upcoming, limit=1)
        except Exception:
            pass
        for group in self._stack(streak):
            try:
                out = self._run_group(name, group)
            except BaseException as e:       # a bad batch fails only itself
                for r in group:
                    if not r.future.done():
                        r.future.set_exception(e)
                continue
            off = 0
            for r in group:
                n = r.tokens.shape[0]
                r.future.set_result(out[off:off + n])
                off += n
            self.stats["batches"] += 1
        self.stats["streaks"] += 1
        self.stats["busy_seconds"] += time.perf_counter() - t0

    # ------------------------------------------------------------ batching
    def _stack(self, streak: list[_Request]) -> list[list[_Request]]:
        """Coalesce same-shape requests into joint forward passes.

        Only greedy (temperature==0) contexts stack — stacked rows share
        one sampling key, which would correlate temperature>0 draws.
        Non-stackable requests run back-to-back, still amortizing the
        switch across the streak.
        """
        sm = self.server._served[streak[0].name]
        if sm.temperature > 0.0:
            return [[r] for r in streak]
        groups: dict[tuple, list[_Request]] = {}
        order: list[tuple] = []
        for r in streak:
            key = (r.tokens.shape[1], r.steps)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(r)
        self.stats["stacked_requests"] += sum(
            len(g) - 1 for g in groups.values() if len(g) > 1)
        return [groups[k] for k in order]

    def _run_group(self, name: str, group: list[_Request]) -> np.ndarray:
        tokens = (group[0].tokens if len(group) == 1 else
                  np.concatenate([r.tokens for r in group], axis=0))
        return self.server.serve_batch(name, tokens, steps=group[0].steps,
                                       seed=group[0].seed)

    # ------------------------------------------------------------- report
    def snapshot(self) -> dict:
        engine = self.server.engine
        eng = engine.stats
        return {**self.stats, "switches": eng["switches"],
                "loads": eng["loads"], "evictions": eng["evictions"],
                "hidden_load_fraction": engine.hidden_load_fraction()}
