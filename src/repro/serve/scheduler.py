"""Switch-aware asynchronous request schedulers.

Two front doors over a ``SwitchableServer``:

  * ``SwitchScheduler``     — streak-batched: coalesces each context's
    backlog into run-to-completion batches (one switch per streak).
  * ``ContinuousScheduler`` — token-granular: a persistent ``StepEngine``
    per context; requests join/leave at every decode step, and the
    active context is re-decided at step boundaries (drain-vs-stack),
    with the next context streaming into the shadow slot while steps of
    the active one execute.

The paper's timing result — reconfiguration hidden behind execution — only
materializes at serving scale if *something* orders the traffic so that
(a) requests for the resident model run back-to-back (one switch amortized
over many batches) and (b) the next model's weights stream into the shadow
slot while the current streak executes.  A synchronous single-caller server
leaves both to the client.  ``SwitchScheduler`` is that something:

    clients ──submit(name, tokens)──▶ per-context queues
                                         │   pick next context:
                                         │   policy.rank_contexts
                                         │   (queue pressure − load cost,
                                         │    age-boosted for fairness)
                                         ▼
                                   service streak ──▶ SwitchableServer
                                         │                 │
                                         │   engine.prefetch(next ranked)
                                         │   (shadow-slot load hidden
                                         ▼    behind the active streak)
                                      futures resolve

All slot/eviction/prefetch decisions route through the engine's shared
``ReconfigPolicy`` (``repro.core.policy``) — the scheduler only shapes the
traffic.  Same-shape greedy requests inside a streak are stacked into one
forward pass; everything else is served back-to-back after a single switch.
"""
from __future__ import annotations

import math
import threading
import time
from collections import defaultdict, deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.serve.engine import EngineKey
from repro.serve.speculative import SpecKey
from repro.serve.telemetry import Telemetry, safe_ratio

# request-level histograms surfaced by every scheduler snapshot
_LATENCY_HISTS = ("ttft_s", "queue_wait_s", "token_latency_s",
                  "decode_stall_s", "admit_to_first_chunk_s",
                  "gen_latency_s", "request_latency_s")


@dataclass
class _Request:
    name: str
    tokens: np.ndarray
    steps: int
    seed: int
    future: Future
    submitted_at: float
    explicit_seed: bool = False    # caller pinned `seed` (reproducible row)


class SwitchScheduler:
    """Async front door over a ``SwitchableServer``.

    ``submit`` enqueues and returns a ``Future``; one scheduler thread
    drains per-context queues in policy-ranked order, coalescing each
    chosen context's backlog into a single service streak and preloading
    the next-ranked context into the shadow slot before the streak runs.

    ``max_streak`` bounds how many requests one context may serve before
    the scheduler re-ranks (starvation bound); ``age_weight`` converts
    request age (seconds) into extra queue pressure so a low-traffic
    context eventually wins over a flooded one.
    """

    def __init__(self, server, max_streak: int = 16,
                 age_weight: float = 10.0, cost_weight: float = 1.0):
        self.server = server
        self.max_streak = max_streak
        self.age_weight = age_weight
        self.cost_weight = cost_weight
        self._queues: dict[str, deque[_Request]] = defaultdict(deque)
        self._cv = threading.Condition()
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        self._load_cost: dict[str, float] = {}   # measured seconds, EMA
        # scheduler stats live in the server's shared MetricRegistry under
        # ``sched.`` (dict-compatible view); a fresh scheduler zeroes its
        # own keys, matching the old fresh-dict semantics
        self.telemetry = getattr(server, "telemetry", None) or Telemetry()
        self._clock = self.telemetry.clock
        self._trace = self.telemetry.tracer
        self.stats = self.telemetry.view("sched.")
        self.stats.update({
            "requests": 0, "batches": 0, "streaks": 0,
            "stacked_requests": 0, "busy_seconds": 0.0,
            "admitted_requests": 0, "rejected_requests": 0,
            "queued_requests": 0,
        })

    # ------------------------------------------------------------- client
    def submit(self, name: str, tokens, steps: int = 1,
               seed: Optional[int] = None) -> Future:
        """Enqueue one request; resolves to the (B, steps) output array."""
        if name not in self.server.served():
            raise KeyError(f"model {name!r} not registered")
        fut: Future = Future()
        req = _Request(name=name, tokens=np.asarray(tokens), steps=steps,
                       seed=self.server.next_seed() if seed is None else seed,
                       future=fut, submitted_at=self._clock())
        with self._cv:
            if self._stopping:
                raise RuntimeError("scheduler is stopped")
            self._queues[name].append(req)
            self.stats["requests"] += 1
            self._note_queued_locked()
            self._cv.notify()
        if self._trace.enabled:
            self._trace.instant(f"submit:{name}", "sched",
                                ts=req.submitted_at)
        return fut

    def _note_queued_locked(self):
        """Refresh the queued-requests gauge; caller holds ``_cv``."""
        self.stats["queued_requests"] = sum(
            len(q) for q in self._queues.values())

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "SwitchScheduler":
        assert self._thread is None, "already started"
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="switch-scheduler")
        self._thread.start()
        return self

    def stop(self, drain: bool = True):
        """Stop the loop; with ``drain`` every queued request is served
        first, otherwise leftovers get a RuntimeError.  Requests that can
        no longer drain (scheduler never started, or its thread died) are
        always failed rather than left with futures that never resolve."""
        with self._cv:
            self._stopping = True
            self._drain = drain
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        for q in self._queues.values():
            while q:
                q.popleft().future.set_exception(
                    RuntimeError("scheduler stopped before serving this "
                                 "request"))
                self.stats["rejected_requests"] += 1
        with self._cv:
            self._note_queued_locked()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop(drain=exc[0] is None)

    # ------------------------------------------------------------ ranking
    def _pressures(self, now: float) -> dict[str, float]:
        """Queue pressure per context: backlog size plus age boost (an old
        request in a quiet queue counts as much as `age_weight`·seconds of
        backlog, so no context starves)."""
        out = {}
        for name, q in self._queues.items():
            if q:
                age = now - q[0].submitted_at
                out[name] = len(q) + self.age_weight * age
        return out

    def _ranked(self, now: float) -> list[str]:
        return self.server.engine.policy.rank_contexts(
            self._pressures(now), self._load_cost,
            cost_weight=self.cost_weight)

    def _note_load_cost(self, name: str, seconds: float):
        prev = self._load_cost.get(name)
        self._load_cost[name] = (seconds if prev is None
                                 else 0.5 * prev + 0.5 * seconds)

    # --------------------------------------------------------------- loop
    def _loop(self):
        while True:
            with self._cv:
                while not self._stopping and not any(
                        self._queues.values()):
                    self._cv.wait(timeout=0.1)
                if self._stopping and (not getattr(self, "_drain", True)
                                       or not any(self._queues.values())):
                    return
                now = self._clock()
                ranked = self._ranked(now)
                name = ranked[0]
                streak: list[_Request] = []
                q = self._queues[name]
                while q and len(streak) < self.max_streak:
                    streak.append(q.popleft())
                self.stats["admitted_requests"] += len(streak)
                self._note_queued_locked()
                for r in streak:
                    self.telemetry.observe(
                        "queue_wait_s", now - r.submitted_at,
                        doc="seconds between submit and admission")
                # next context with pending work (after this streak drains)
                upcoming = [n for n in ranked[1:] if self._queues[n]]
                if not upcoming and q:
                    upcoming = [name]        # more of the same backlog
            try:
                self._serve_streak(name, streak, upcoming)
            except BaseException as e:       # backstop: never die with
                for r in streak:             # unresolved futures behind
                    if not r.future.done():
                        r.future.set_exception(e)

    def _serve_streak(self, name: str, streak: list[_Request],
                      upcoming: list[str]):
        engine = self.server.engine
        t0 = self._clock()
        try:
            was_resident = engine.policy.holds(name)
            engine.preload(name)
            engine.switch(name, wait=True)
        except BaseException as e:           # context unloadable: fail the
            for r in streak:                 # streak, keep the loop alive
                if not r.future.done():
                    r.future.set_exception(e)
            return
        if not was_resident:
            self._note_load_cost(name, self._clock() - t0)
        # the paper's dynamic reconfiguration: next context streams into
        # the shadow slot while this streak executes (policy picks victims).
        # Prefetch is advisory: a failure must not take the streak down
        # (the next streak pays a demand load instead).
        try:
            engine.prefetch(upcoming, limit=1)
        except Exception:
            pass
        for group in self._stack(streak):
            try:
                out = self._run_group(name, group)
            except BaseException as e:       # a bad batch fails only itself
                for r in group:
                    if not r.future.done():
                        r.future.set_exception(e)
                continue
            off = 0
            done = self._clock()
            for r in group:
                n = r.tokens.shape[0]
                r.future.set_result(out[off:off + n])
                off += n
                self.telemetry.observe(
                    "request_latency_s", done - r.submitted_at,
                    doc="seconds between submit and future resolution")
            self.stats["batches"] += 1
        now = self._clock()
        self.stats["streaks"] += 1
        self.stats["busy_seconds"] += now - t0
        if self._trace.enabled:
            self._trace.span(f"streak:{name}", "sched", t0, now,
                             args={"requests": len(streak)})

    # ------------------------------------------------------------ batching
    def _stack(self, streak: list[_Request]) -> list[list[_Request]]:
        """Coalesce same-shape requests into joint forward passes.

        Only greedy (temperature==0) contexts stack — stacked rows share
        one sampling key, which would correlate temperature>0 draws.
        Non-stackable requests run back-to-back, still amortizing the
        switch across the streak.
        """
        sm = self.server._served[streak[0].name]
        if sm.temperature > 0.0:
            return [[r] for r in streak]
        groups: dict[tuple, list[_Request]] = {}
        order: list[tuple] = []
        for r in streak:
            key = (r.tokens.shape[1], r.steps)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(r)
        self.stats["stacked_requests"] += sum(
            len(g) - 1 for g in groups.values() if len(g) > 1)
        return [groups[k] for k in order]

    def _run_group(self, name: str, group: list[_Request]) -> np.ndarray:
        tokens = (group[0].tokens if len(group) == 1 else
                  np.concatenate([r.tokens for r in group], axis=0))
        return self.server.serve_batch(name, tokens, steps=group[0].steps,
                                       seed=group[0].seed)

    # ------------------------------------------------------------- report
    def snapshot(self) -> dict:
        return _snapshot(self.stats, self.server.engine, self.telemetry)


def _snapshot(stats: dict, engine, telemetry=None) -> dict:
    """Scheduler stats merged with the context engine's switching stats —
    one shape for every scheduler's report.  With a telemetry handle,
    request-level latency histograms (summaries) ride along too."""
    eng = engine.stats
    out = {**stats, "switches": eng["switches"],
           "context_changes": eng["context_changes"],
           "loads": eng["loads"], "evictions": eng["evictions"],
           "hidden_load_fraction": engine.hidden_load_fraction()}
    if telemetry is not None:
        hists = {}
        for name in _LATENCY_HISTS:
            h = telemetry.registry.histogram(name)
            if h is not None and h.count:
                hists[name] = h.summary()
        if hists:
            out["latency_hists"] = hists
    return out


# ---------------------------------------------------------------------------
# token-granular continuous batching
# ---------------------------------------------------------------------------

@dataclass
class _Inflight:
    """One submitted request fanned out over `need` slot rows."""
    req: _Request
    need: int
    rows: dict = None

    def __post_init__(self):
        self.rows = {}


class ContinuousScheduler:
    """Token-granular front door: one persistent ``StepEngine`` per
    context, advanced one decode step at a time.

    Every iteration of the loop is one step boundary, where ALL of the
    paper's hide-the-load machinery happens at token granularity:

      * admission    — queued requests prefill into free slots of the
                       active context's pool (no padding to the slowest
                       request: a finished row frees its slot immediately)
      * retirement   — EOS / step-limit rows leave, futures resolve
      * ranking      — ``policy.rank_contexts`` on queue pressure (age
                       boosted) + a paused context's stranded live rows
      * drain-vs-stack — if another context's pressure beats the active
                       one by ``switch_margin``, stop admitting (drain)
                       and start its shadow-slot preload behind the
                       remaining steps; keep stacking otherwise
      * switch       — O(1) select flip once the pool drains (or
                       immediately past ``preempt_margin`` — paused rows
                       stay frozen in their engine's state and resume on
                       switch-back)

    Decode state persists per context across switches (beyond-paper: an
    FPGA loses flip-flop state on reconfiguration; our slots are HBM).

    ``draft`` maps a context name to a *draft* context: requests for that
    context run on a speculative ``SpecEngine`` (draft proposes K tokens,
    the target verifies them in one multi-token pass) instead of a plain
    ``StepEngine`` — mixed speculative/plain traffic shares the same
    rank/drain/stack loop, and each draft/target hand-off inside a round
    is an O(1) select flip with the other context prefetched into the
    shadow slot.

    Per-request seeds ARE honored for plain contexts: a seeded row draws
    from its own key column (folded with the row's token position), so a
    seeded resubmission reproduces its tokens exactly regardless of slot
    or surrounding traffic.  Speculative contexts reject seeds (the
    accept/reject cascade has no per-row schedule).
    """

    def __init__(self, server, batch_size: int = 8,
                 age_weight: float = 10.0, cost_weight: float = 1.0,
                 switch_margin: float = 1.5, preempt_margin: float = 6.0,
                 draft: Optional[dict] = None, spec_k: int = 4,
                 spec_tree: int = 1, spec_adaptive: bool = False,
                 prefill_chunk: Optional[int] = None,
                 paged: bool = False, page_size: int = 256,
                 multi_step: int = 1,
                 quantize_kv: Optional[str] = None,
                 prefix_cache: bool = False,
                 share_bank: bool = False,
                 shards: Optional[int] = None, mesh=None):
        self.server = server
        self.batch_size = batch_size
        # sharded page bank (paged mode): engines partition their page
        # pool over `shards` per-shard free-lists (and over `mesh`'s
        # first axis when given) with locality-routed admission
        if (shards or mesh) and not paged:
            raise ValueError("shards/mesh need paged=True")
        self.shards = shards
        self.mesh = mesh
        # device-resident multi-step decode: each engine tick runs up to
        # ``multi_step`` fused decode steps, so the scheduler's
        # rank/drain/admit bookkeeping amortizes over several tokens
        # (snapshot()['steps_per_tick'] reports the realized ratio)
        self.multi_step = multi_step
        # int8 page bank (paged mode): ~2x pages per HBM budget
        self.quantize_kv = quantize_kv
        # prefix cache (paged mode): admissions whose prompt starts with
        # an already-written whole-page run map those pages read-only
        # and prefill only the divergent suffix; ``can_admit`` evicts
        # cached pages LRU-first under page pressure
        self.prefix_cache = prefix_cache
        if prefix_cache and not paged:
            raise ValueError("prefix_cache needs paged=True")
        # chunked admission: engines split prefill into (b, C) chunks,
        # one per tick, so a long prompt's admission hides behind decode
        # steps instead of stalling them (speculative engines chunk BOTH
        # cache columns)
        self.prefill_chunk = prefill_chunk
        # paged slot pool: plain contexts' engines pool KV pages across
        # slots (per-request memory ∝ its own length, not max_len), so
        # the same HBM serves more concurrent short requests; admission
        # additionally gates on free pages via ``can_admit``
        self.paged = paged
        self.page_size = page_size
        self.age_weight = age_weight
        self.cost_weight = cost_weight
        self.switch_margin = switch_margin
        self.preempt_margin = preempt_margin
        self.draft = dict(draft or {})
        self.spec_k = spec_k
        # speculative tree width (siblings per depth; 1 == flat chain)
        if spec_tree < 1:
            raise ValueError(f"spec_tree must be >= 1, got {spec_tree}")
        self.spec_tree = spec_tree
        # acceptance-driven adaptive K: EWMA the measured per-tick
        # acceptance fraction and walk each spec engine's K inside
        # [1, spec_k] (spec_k is the ceiling — admission slack, program
        # cache, and submit validation all use it)
        self.spec_adaptive = spec_adaptive
        self._accept_ewma: dict[str, float] = {}
        self._spec_prev: dict[str, tuple[int, int]] = {}
        # shared page banks: engines of the same context content (plain
        # paged pools and spec target columns) allocate from one pool and
        # share one prefix index
        if share_bank and not paged:
            raise ValueError("share_bank needs paged=True")
        self.share_bank = share_bank
        self._queues: dict[str, deque[_Request]] = defaultdict(deque)
        self._inflight: dict[int, _Inflight] = {}
        self._inflight_seq = 0          # monotonic key: ids recycle, this
        self._cv = threading.Condition()                      # never does
        self._stopping = False
        self._drain = True
        self._thread: Optional[threading.Thread] = None
        self._load_cost: dict[str, float] = {}
        # paused contexts with frozen rows: when they went stranded (only
        # touched by the loop thread) — the starvation guard's age base
        self._stranded_since: dict[str, float] = {}
        self._tick_ctx: Optional[str] = None   # context the current tick
        #                                        acts on (failure target)
        # shared-registry stats view (see SwitchScheduler.__init__)
        self.telemetry = getattr(server, "telemetry", None) or Telemetry()
        self._clock = self.telemetry.clock
        self._trace = self.telemetry.tracer
        self.stats = self.telemetry.view("sched.")
        self.stats.update({
            "requests": 0, "steps": 0, "admitted_rows": 0,
            "drain_switches": 0, "preempt_switches": 0,
            "busy_seconds": 0.0,
            "admitted_requests": 0, "rejected_requests": 0,
            "queued_requests": 0,
            "admit_blocked_no_slots": 0, "admit_blocked_no_pages": 0,
            "admit_blocked_no_shard_pages": 0,
        })

    # ------------------------------------------------------------- client
    def submit(self, name: str, tokens, steps: int = 1,
               seed: Optional[int] = None) -> Future:
        """Enqueue one request; resolves to the (b, steps) output array.

        ``seed`` pins the request's sampling draws to its own per-slot key
        column (``DecodeState.rkey``), folded with each token's position:
        a seeded resubmission reproduces its tokens exactly, independent
        of slot assignment, admission boundary, and pool traffic.
        Speculative contexts (see ``draft``) reject seeds."""
        if name not in self.server.served():
            raise KeyError(f"model {name!r} not registered")
        if seed is not None and name in self.draft:
            raise ValueError(
                "speculative contexts do not honor per-request seeds; "
                "submit to a plain context for seed reproducibility")
        tokens = np.asarray(tokens)
        if tokens.ndim == 1:
            tokens = tokens[None]
        b, S = tokens.shape
        if b > self.batch_size:
            raise ValueError(f"request batch {b} > pool size "
                             f"{self.batch_size}")
        sm = self.server._served[name]
        slack = self.spec_k if name in self.draft else 0
        if S + steps + slack > sm.max_len:
            raise ValueError(f"prompt {S} + {steps} steps (+{slack} "
                             f"speculative slack) exceeds max_len "
                             f"{sm.max_len}")
        fut: Future = Future()
        req = _Request(name=name, tokens=tokens, steps=steps,
                       seed=self.server.next_seed() if seed is None
                       else seed,
                       future=fut, submitted_at=self._clock(),
                       explicit_seed=seed is not None)
        with self._cv:
            if self._stopping:
                raise RuntimeError("scheduler is stopped")
            self._queues[name].append(req)
            self.stats["requests"] += 1
            self._note_queued_locked()
            self._cv.notify()
        if self._trace.enabled:
            self._trace.instant(f"submit:{name}", "sched",
                                ts=req.submitted_at)
        return fut

    def _note_queued_locked(self):
        """Refresh the queued-requests gauge; caller holds ``_cv``."""
        self.stats["queued_requests"] = sum(
            len(q) for q in self._queues.values())

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "ContinuousScheduler":
        assert self._thread is None, "already started"
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="continuous-scheduler")
        self._thread.start()
        return self

    def stop(self, drain: bool = True):
        with self._cv:
            self._stopping = True
            self._drain = drain
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        err = RuntimeError("scheduler stopped before serving this request")
        for q in self._queues.values():
            while q:
                q.popleft().future.set_exception(err)
                self.stats["rejected_requests"] += 1
        for inf in list(self._inflight.values()):   # admitted, unfinished
            if not inf.req.future.done():
                inf.req.future.set_exception(err)
        self._inflight.clear()
        with self._cv:
            self._note_queued_locked()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop(drain=exc[0] is None)

    # ------------------------------------------------------------ engines
    def _engine(self, name: str):
        if name in self.draft:
            return self._spec_engine(name)
        eng = self.server.step_engine(name, self.batch_size,
                                      prefill_chunk=self.prefill_chunk,
                                      paged=self.paged,
                                      page_size=self.page_size,
                                      multi_step=self.multi_step,
                                      quantize_kv=self.quantize_kv,
                                      prefix_cache=self.prefix_cache,
                                      share_bank=self.share_bank,
                                      shards=self.shards, mesh=self.mesh)
        if eng.runner is None:
            cse = self.server.engine
            # every device program (prefill + step) routes through the
            # context engine so shadow-slot loads overlap *steps* and the
            # hidden-load accounting sees token-granular execution; the
            # params slot is filled with the ACTIVE buffers by run_step.
            eng.runner = lambda fn, params, *args: cse.run_step(fn, *args)
        return eng

    def _spec_engine(self, name: str):
        dname = self.draft[name]
        eng = self.server.spec_engine(
            name, dname, self.batch_size, k=self.spec_k,
            tree_width=self.spec_tree,
            page_size=self.page_size if self.paged else None,
            prefill_chunk=self.prefill_chunk,
            prefix_cache=self.prefix_cache,
            quantize_kv=self.quantize_kv,
            share_bank=self.share_bank)
        if eng.runner is None:
            cse = self.server.engine

            def runner(which, fn, *args, _t=name, _d=dname):
                # the paper's dual-copy cascade at program granularity:
                # activate the side this program needs (O(1) when
                # resident) and stream the OTHER side into the shadow
                # slot behind this program's execution
                want, other = (_t, _d) if which == "target" else (_d, _t)
                cse.preload(want)
                cse.switch(want, wait=True)
                try:
                    cse.prefetch([other], limit=1)
                except Exception:
                    pass
                return cse.run_step(fn, *args)

            eng.runner = runner
        return eng

    def _step_key(self, name: str) -> EngineKey:
        """The server-side ``_step_engines`` cache key this scheduler's
        configuration resolves to (the same frozen ``EngineKey``
        ``SwitchableServer.step_engine`` builds; full-key matching
        matters because the server outlives schedulers with different
        configurations)."""
        n_shards = self.shards if self.shards is not None else (
            self.mesh.shape[self.mesh.axis_names[0]]
            if self.mesh is not None else 1)
        return EngineKey(name=name, batch_size=self.batch_size,
                         prefill_chunk=self.prefill_chunk,
                         page_size=self.page_size if self.paged else None,
                         multi_step=self.multi_step,
                         quantize_kv=self.quantize_kv,
                         prefix_cache=self.prefix_cache,
                         shared_bank=self.share_bank, shards=n_shards)

    def _spec_key(self, name: str) -> SpecKey:
        """The server-side ``_spec_engines`` cache key this scheduler's
        configuration resolves to — the resolved page size mirrors
        ``SwitchableServer.spec_engine`` (scheduler page size when paged,
        the SpecEngine default otherwise)."""
        sm = self.server._served[name]
        ps = (min(self.page_size, sm.max_len) if self.paged
              else math.gcd(sm.max_len, 256))
        return SpecKey(name=name, draft=self.draft[name],
                       batch_size=self.batch_size, k=self.spec_k,
                       tree_width=self.spec_tree, page_size=ps,
                       quantize_kv=self.quantize_kv,
                       prefix_cache=self.prefix_cache,
                       prefill_chunk=self.prefill_chunk,
                       shared_bank=self.share_bank)

    def _live_engines(self):
        out = {}
        for name in self.server.served():
            if name in self.draft:
                eng = self.server._spec_engines.get(self._spec_key(name))
            else:
                eng = self.server._step_engines.get(self._step_key(name))
            if eng is not None and eng.live_slots():
                out[name] = eng
        return out

    # ------------------------------------------------------------ ranking
    def _pressures(self, now: float) -> dict[str, float]:
        out = {}
        with self._cv:
            for name, q in self._queues.items():
                if q:
                    age = now - q[0].submitted_at
                    out[name] = len(q) + self.age_weight * age
        # a paused context's stranded rows count as pressure too — they
        # must eventually be resumed and retired.  Age-boost them exactly
        # like queued requests (starvation guard): sustained pressure on a
        # hot competitor must not defer a preempted context's frozen rows
        # indefinitely.
        for name, eng in self._live_engines().items():
            age = now - self._stranded_since.get(name, now)
            out[name] = (out.get(name, 0.0) + eng.live_slots()
                         + self.age_weight * age)
        return out

    def _note_load_cost(self, name: str, seconds: float):
        prev = self._load_cost.get(name)
        self._load_cost[name] = (seconds if prev is None
                                 else 0.5 * prev + 0.5 * seconds)

    # --------------------------------------------------------------- loop
    def _has_work(self) -> bool:
        return (any(self._queues.values())
                or bool(self._live_engines()))

    def _loop(self):
        cur: Optional[str] = None
        while True:
            with self._cv:
                if not self._has_work():
                    if self._stopping:
                        return
                    self._cv.wait(timeout=0.05)
                    continue
                if self._stopping and not self._drain:
                    return
            try:
                cur = self._tick(cur)
            except BaseException as e:
                # fail the context the tick was ACTING on when it raised
                # (_tick may have switched away from `cur` first — failing
                # the stale name would poison an innocent context's
                # requests), keep the loop alive
                self._fail_context(self._tick_ctx, e)
                cur = None

    def _tick(self, cur: Optional[str]) -> Optional[str]:
        """One step boundary: rank, maybe switch, admit, step, retire."""
        self._tick_ctx = cur                  # who a mid-tick failure hits
        now = self._clock()
        pressures = self._pressures(now)
        if not pressures:
            return cur
        policy = self.server.engine.policy
        ranked = policy.rank_contexts(pressures, self._load_cost,
                                      cost_weight=self.cost_weight)
        cand = ranked[0]
        stack = True                          # keep admitting `cur`
        if cur is None:
            cur = self._try_activate(cand, cur)
            self._tick_ctx = cur
            if cur is None:
                return None
        elif cand != cur:
            cur_p = pressures.get(cur, 0.0)
            cand_p = pressures.get(cand, 0.0)
            eng = self._engine(cur)
            if eng.live_slots() == 0 and not self._queues[cur]:
                nxt = self._try_activate(cand, cur)   # free flip: nothing
                if nxt == cand:                       # to drain
                    self.stats["drain_switches"] += 1
                    if self._trace.enabled:
                        self._trace.instant(f"drain-switch:{cand}", "sched")
                cur = nxt
                self._tick_ctx = cur
            elif cand_p > self.switch_margin * max(cur_p, 1e-9):
                # drain decision: stop stacking; stream the winner into
                # the shadow slot behind the remaining steps (advisory —
                # a failed prefetch just means a demand load later)
                stack = False
                try:
                    self.server.engine.prefetch([cand], limit=1)
                except Exception:
                    pass
                drained = eng.live_slots() == 0
                preempt = cand_p > self.preempt_margin * max(cur_p, 1e-9)
                if drained or (preempt and policy.is_resident(cand)):
                    nxt = self._try_activate(cand, cur)
                    if nxt == cand:
                        kind = ("drain_switches" if drained
                                else "preempt_switches")
                        self.stats[kind] += 1
                        if self._trace.enabled:
                            self._trace.instant(
                                f"{kind[:-len('_switches')]}-switch:{cand}",
                                "sched")
                    cur = nxt
                    self._tick_ctx = cur
        eng = self._engine(cur)
        if stack:
            self._admit(cur, eng)
        if eng.live_slots():
            t0 = self._clock()
            finished = eng.step(None)         # params come from run_step
            self.stats["steps"] += 1
            self.stats["busy_seconds"] += self._clock() - t0
            self._resolve(finished)
            if self.spec_adaptive and cur in self.draft:
                self._adapt_k(cur, eng)
        else:
            time.sleep(0.0005)                # waiting on a load/queue
        # starvation-guard bookkeeping: stamp contexts left holding frozen
        # rows; the stamp ages their pressure until they are resumed
        mark = self._clock()
        live = self._live_engines()
        for name in live:
            self._stranded_since.setdefault(name, mark)
        self._stranded_since.pop(cur, None)
        for name in list(self._stranded_since):
            if name not in live:
                del self._stranded_since[name]
        return cur

    def _adapt_k(self, name: str, eng):
        """Acceptance-driven K: EWMA (alpha=0.2) the fraction of DRAFTED
        tokens the target accepted since the last look (stats deltas, so
        resets and other schedulers' traffic don't pollute it), then walk
        K one step inside [1, spec_k] with hysteresis — above 0.8 the
        draft is tracking the target and a longer chain amortizes more
        target calls per round; below 0.4 most drafted tokens are wasted
        draft steps, so shrink.  The dead band between keeps K stable
        under ordinary acceptance noise."""
        committed = eng.stats["committed_tokens"]
        rows = eng.stats["row_rounds"]
        pc, pr = self._spec_prev.get(name, (0, 0))
        dc, dr = committed - pc, rows - pr
        if dr <= 0:
            return                      # no row finished a round this tick
        self._spec_prev[name] = (committed, rows)
        # each row-round commits accepted+1 (the bonus/correction token)
        acc = (dc / dr - 1.0) / max(eng.k, 1)
        ew = self._accept_ewma.get(name)
        ew = acc if ew is None else 0.8 * ew + 0.2 * acc
        self._accept_ewma[name] = ew
        if ew > 0.8 and eng.k < eng.k_max:
            eng.set_k(eng.k + 1)
        elif ew < 0.4 and eng.k > 1:
            eng.set_k(eng.k - 1)

    def _activate(self, name: str) -> str:
        t0 = self._clock()
        was_resident = self.server.engine.policy.holds(name)
        self.server.engine.preload(name)
        self.server.engine.switch(name, wait=True)
        if not was_resident:
            self._note_load_cost(name, self._clock() - t0)
        return name

    def _try_activate(self, name: str, cur: Optional[str]) -> Optional[str]:
        """Activate `name`; on failure (unloadable context) fail ITS
        requests — queued, in flight, and stranded rows — so its pressure
        drains and the loop doesn't retry the same broken load forever.
        Returns the new active context (`cur` unchanged on failure)."""
        try:
            return self._activate(name)
        except BaseException as e:
            self._fail_context(name, e)   # also drops its engine's rows
            return cur

    # ---------------------------------------------------------- admission
    def _admit(self, name: str, eng):
        """Fill free slots from `name`'s queue (whole requests only: a
        request's rows prefill together, so its draws and MoE routing
        match the run-to-completion path)."""
        while True:
            with self._cv:
                q = self._queues[name]
                if not q:
                    return
                if not eng.can_admit(q[0].tokens, q[0].steps):
                    # distinguish WHY the head of the queue is stuck: no
                    # free slot, no pages pool-wide, or pages exist but
                    # not on the shard its pages route to
                    block = getattr(eng, "last_admit_block", None)
                    key = {"slots": "admit_blocked_no_slots",
                           "pages": "admit_blocked_no_pages",
                           "shard_pages": "admit_blocked_no_shard_pages",
                           }.get(block)
                    if key is not None:
                        self.stats[key] += 1
                    return
                req = q.popleft()
                self._note_queued_locked()
            b = req.tokens.shape[0]
            inf = _Inflight(req=req, need=b)
            key = self._inflight_seq
            self._inflight_seq += 1
            self._inflight[key] = inf
            # explicitly seeded requests pin each row to its own key:
            # split() derives per-row keys deterministically, so the same
            # (seed, prompt) resubmission reproduces row-for-row
            seeds = None
            if req.explicit_seed:
                seeds = list(jax.random.split(
                    jax.random.PRNGKey(req.seed), b))
            try:
                gens = eng.admit(None, req.tokens, max_new=req.steps,
                                 metas=[(key, i) for i in range(b)],
                                 seeds=seeds,
                                 submitted_at=req.submitted_at)
            except BaseException as e:
                del self._inflight[key]
                self.stats["rejected_requests"] += 1
                req.future.set_exception(e)
                continue
            self.stats["admitted_rows"] += b
            self.stats["admitted_requests"] += 1
            self._resolve([g for g in gens if g.done])

    def _resolve(self, finished):
        for g in finished:
            key, row = g.meta
            inf = self._inflight.get(key)
            if inf is None:
                continue
            inf.rows[row] = g.tokens
            if len(inf.rows) == inf.need:
                del self._inflight[key]
                out = np.stack([np.asarray(inf.rows[i], np.int32)
                                for i in range(inf.need)])
                if not inf.req.future.done():
                    inf.req.future.set_result(out)
                    self.telemetry.observe(
                        "request_latency_s",
                        self._clock() - inf.req.submitted_at,
                        doc="seconds between submit and future resolution")

    def _fail_context(self, cur: Optional[str], exc: BaseException):
        """Fail everything belonging to `cur` (all contexts when None):
        queued requests, in-flight requests, and the context's engine
        state — a failed request's rows must not keep stepping, or their
        later retirement would route into the wrong inflight record."""
        with self._cv:
            reqs = []
            if cur is not None:
                q = self._queues[cur]
                while q:
                    reqs.append(q.popleft())
                self._note_queued_locked()
            self.stats["rejected_requests"] += len(reqs)
        for key, inf in list(self._inflight.items()):
            if cur is None or inf.req.name == cur:
                self._inflight.pop(key, None)
                if not inf.req.future.done():
                    inf.req.future.set_exception(exc)
        for r in reqs:
            if not r.future.done():
                r.future.set_exception(exc)
        for (name, bsz, *_), eng in list(
                self.server._step_engines.items()):
            if bsz == self.batch_size and (cur is None or name == cur) \
                    and eng.live_slots():
                eng.reset()
        for skey, eng in list(self.server._spec_engines.items()):
            if skey.batch_size == self.batch_size \
                    and (cur is None or skey.name == cur) \
                    and eng.live_slots():
                eng.reset()

    # ------------------------------------------------------------- report
    def snapshot(self) -> dict:
        out = _snapshot(self.stats, self.server.engine, self.telemetry)
        ticks = dsteps = 0
        prefix = {"prefix_hits": 0, "prefix_pages_mapped": 0,
                  "cow_copies": 0, "cache_evictions": 0}
        for key, eng in self.server._step_engines.items():
            # full-key match, same reason as the spec block below
            if key == self._step_key(key.name):
                ticks += eng.stats["host_ticks"]
                dsteps += eng.stats["device_steps"]
                for k in prefix:
                    prefix[k] += eng.stats.get(k, 0)
        # always present (0 / 0.0 before the first tick) so report
        # consumers never need an existence check
        out["host_ticks"] = ticks
        out["device_steps"] = dsteps
        # the multi-step amortization actually realized: decode steps
        # committed per host round-trip (1.0 when multi_step == 1)
        out["steps_per_tick"] = round(safe_ratio(dsteps, ticks), 3)
        if self.prefix_cache:
            # prefix-cache effectiveness across this config's engines
            out.update(prefix)
        rounds = row_rounds = committed = 0
        for skey, eng in self.server._spec_engines.items():
            # full-key match: the server outlives schedulers, so engines
            # from a prior draft/spec configuration may coexist
            if (self.draft.get(skey.name) == skey.draft
                    and skey == self._spec_key(skey.name)):
                rounds += eng.stats["rounds"]
                row_rounds += eng.stats["row_rounds"]
                committed += eng.stats["committed_tokens"]
        if rounds or self.draft:
            out["spec_rounds"] = rounds
            out["spec_committed_tokens"] = committed
            out["accepted_tokens_per_round"] = round(
                safe_ratio(committed, row_rounds), 3)
            # fraction of *drafted* tokens the target accepted: each row
            # round drafts spec_k and commits accepted+1 (the bonus token)
            out["spec_acceptance_rate"] = round(
                safe_ratio(committed - row_rounds,
                           row_rounds * self.spec_k), 3)
        return out
