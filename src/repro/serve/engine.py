"""Batched serving engine: prefill + decode loops over the model zoo.

Two decode drivers:
  * ``generate``             — host loop calling the jitted single step
                               (realistic serving; cache donated every step)
  * ``generate_fused``       — whole decode loop as one ``lax.scan`` (bench)

Sampling: greedy or temperature; deterministic per request id.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens: int = 0

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.decode_s if self.decode_s else 0.0


class ServingEngine:
    def __init__(self, model: LM, params, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.seed = seed
        self.stats = ServeStats()

        def _prefill(params, tokens, patch_embeds=None):
            return model.prefill(params, tokens, max_len,
                                 patch_embeds=patch_embeds)

        def _step(params, caches, tok, pos, key):
            logits, caches = model.decode_step(params, caches, tok, pos)
            nxt = _sample(logits[:, -1], key, temperature)
            return nxt[:, None], caches

        self._prefill = jax.jit(_prefill)
        self._step = jax.jit(_step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _key(self, seed: Optional[int]):
        """Per-request sampling key: `seed` overrides the engine default
        (the switching server threads a fresh per-request seed through
        here so temperature>0 requests are independent draws)."""
        return jax.random.PRNGKey(self.seed if seed is None else seed)

    def generate(self, tokens, steps: int, patch_embeds=None,
                 seed: Optional[int] = None) -> np.ndarray:
        """tokens: (B, S) prompt; returns (B, steps) generated ids."""
        B, S = tokens.shape
        t0 = time.perf_counter()
        if patch_embeds is not None:
            logits, caches = self._prefill(self.params, tokens, patch_embeds)
            n_patch = patch_embeds.shape[1]
        else:
            logits, caches = self._prefill(self.params, tokens)
            n_patch = 0
        key = self._key(seed)
        tok = _sample(logits[:, -1], key, self.temperature)[:, None]
        jax.block_until_ready(tok)
        self.stats.prefill_s += time.perf_counter() - t0

        out = [np.asarray(tok)]
        t0 = time.perf_counter()
        pos = S + n_patch
        for i in range(steps - 1):
            key = jax.random.fold_in(key, i)
            tok, caches = self._step(self.params, caches, tok,
                                     jnp.int32(pos), key)
            out.append(np.asarray(tok))
            pos += 1
        jax.block_until_ready(tok)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.tokens += B * steps
        return np.concatenate(out, axis=1)

    # ------------------------------------------------------------------
    def generate_paged(self, tokens, steps: int,
                       page: int = 256,
                       seed: Optional[int] = None) -> np.ndarray:
        """Paged-cache decode loop: the big cache is read-only per step
        (one donated active page); filled pages are committed every `page`
        steps.  Identical outputs to generate() — tested."""
        from repro.models.layers import ActKV, BigKV, commit_page
        model = self.model
        B, S = tokens.shape
        page = min(page, self.max_len)

        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, tokens)
        key = self._key(seed)
        tok = _sample(logits[:, -1], key, self.temperature)[:, None]
        self.stats.prefill_s += time.perf_counter() - t0

        # convert the dense prefill cache into (bigs, acts)
        bigs, acts = model.init_paged_cache(B, self.max_len, page=page)
        floor = (S // page) * page
        for bkey in list(bigs):
            if bigs[bkey] is None:                   # recurrent state block
                acts[bkey] = caches[bkey]
                continue
            k, v = caches[bkey].k, caches[bkey].v    # (R, B, Hkv, Smax, hd)
            R, Bk, Hkv, Smax, hd = k.shape
            bigs[bkey] = BigKV(
                k=k.reshape(R, Bk, Hkv, Smax // page, page, hd),
                v=v.reshape(R, Bk, Hkv, Smax // page, page, hd))
            # tokens past the last page boundary live in the active page
            acts[bkey] = ActKV(
                k=jax.lax.dynamic_slice_in_dim(k, floor, page, 3),
                v=jax.lax.dynamic_slice_in_dim(v, floor, page, 3))

        step_fn = jax.jit(
            lambda p, b, a, t, pos, key: (
                lambda lo_a: (_sample(lo_a[0][:, -1], key,
                                      self.temperature)[:, None], lo_a[1])
            )(model.decode_step_paged(p, b, a, t, pos)),
            donate_argnums=(2,))
        commit_fn = jax.jit(jax.vmap(commit_page, in_axes=(0, 0, None)),
                            donate_argnums=(0,))

        out = [np.asarray(tok)]
        t0 = time.perf_counter()
        pos = S
        for i in range(steps - 1):
            key = jax.random.fold_in(key, i)
            tok, acts = step_fn(self.params, bigs, acts, tok,
                                jnp.int32(pos), key)
            out.append(np.asarray(tok))
            if pos % page == page - 1:               # page filled: commit
                for bkey in list(bigs):
                    if bigs[bkey] is not None:
                        bigs[bkey] = commit_fn(bigs[bkey], acts[bkey], pos)
            pos += 1
        jax.block_until_ready(tok)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.tokens += B * steps
        return np.concatenate(out, axis=1)

    # ------------------------------------------------------------------
    def generate_fused(self, tokens, steps: int,
                       seed: Optional[int] = None) -> jax.Array:
        """Whole decode loop in one XLA program (benchmark path)."""
        model, T = self.model, self.temperature

        def run(params, tokens, key):
            B, S = tokens.shape
            logits, caches = model.prefill(params, tokens, self.max_len)
            tok = _sample(logits[:, -1], key, T)[:, None]

            def body(carry, i):
                tok, caches, key = carry
                key = jax.random.fold_in(key, i)
                logits, caches = model.decode_step(params, caches, tok, S + i)
                nxt = _sample(logits[:, -1], key, T)[:, None]
                return (nxt, caches, key), tok

            (_, _, _), toks = jax.lax.scan(
                body, (tok, caches, key), jnp.arange(steps))
            return toks[:, :, 0].T                       # (B, steps)

        return jax.jit(run)(self.params, tokens, self._key(seed))


def _sample(logits, key, temperature: float):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)
