"""Serving execution layer: continuous-batching step engine + batch loops.

The core abstraction is ``StepEngine`` — a persistent, fixed-shape decode
batch advanced one token at a time:

  * ``BatchState``   — slot-pooled KV cache (one cache row per slot, a
                       free-list over rows) + per-slot token/position, all
                       under ONE jitted ``step(params, state) -> (tokens,
                       state)`` with a fixed batch shape (no recompiles as
                       requests come and go)
  * ``admit``        — prefill a prompt into a free slot's cache row
                       (``LM.insert_cache_rows``: only that row changes)
  * ``step``         — one decode step for every live slot; per-request
                       positions go down to the attention kernel as a
                       ``(B,)`` vector
  * retirement       — EOS / step-limit frees the slot back to the pool

Requests join, leave, and (one level up, in ``serve/scheduler.py``) switch
model contexts at *step* boundaries — the paper's hide-the-load principle
at token granularity instead of batch granularity.

``ServingEngine`` keeps the classic run-to-completion API; ``generate`` is
now a thin wrapper that admits the whole batch into a ``StepEngine`` and
steps it to completion (token-for-token identical — tested).  Sampling:
greedy or temperature; draws match ``jax.random.categorical`` exactly,
including single-row admissions (the per-row gumbel trick below).
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.serve.pool import (Generation, PagePool, PrefixIndex, SharedBank,
                              ShardedPagePool, SlotPool)
from repro.serve.telemetry import Telemetry, safe_ratio

__all__ = ["DecodeState", "EngineKey", "Generation", "PagePool",
           "PrefixIndex", "ServeStats", "ServingEngine", "SharedBank",
           "ShardedPagePool", "SlotPool", "StepEngine"]


class EngineKey(NamedTuple):
    """Frozen cache key for ONE step-engine configuration.

    Every knob that changes a compiled program or the cache layout is a
    named field; the engine caches in ``ServingEngine``,
    ``SwitchableServer``, and ``ContinuousScheduler`` all key on this
    type, so adding the next knob means adding a field here (with a
    default) — it can no longer silently alias two configurations the
    way a growing positional tuple could.  ``page_size is None`` means
    the row cache layout (``paged=False``); a paged engine always
    records its page size."""
    name: Optional[str] = None          # model context (None: single-model)
    batch_size: int = 1
    prefill_chunk: Optional[int] = None
    page_size: Optional[int] = None     # None == row layout (paged off)
    multi_step: int = 1
    quantize_kv: Optional[str] = None
    prefix_cache: bool = False
    shared_bank: bool = False           # pages/prefixes from a SharedBank
    shards: int = 1                     # page-bank shards (1 == unsharded)


class ServeStats:
    """Run-to-completion loop accounting.  Same attribute API as the old
    dataclass (``stats.tokens += ...``), but the values live in the shared
    ``MetricRegistry`` (``serve.*`` under a server) so one snapshot sees
    the batch loops next to the step engines and the context engine."""

    __slots__ = ("_v",)
    _FLOATS = ("prefill_s", "decode_s")

    def __init__(self, view=None):
        if view is None:
            view = Telemetry().view()
        object.__setattr__(self, "_v", view)
        for k in self._FLOATS:
            view.setdefault(k, 0.0)
        view.setdefault("tokens", 0)

    def __getattr__(self, k):
        try:
            return self._v[k]
        except KeyError:
            raise AttributeError(k) from None

    def __setattr__(self, k, v):
        self._v[k] = v

    @property
    def tok_per_s(self) -> float:
        return safe_ratio(self._v["tokens"], self._v["decode_s"])


# ---------------------------------------------------------------------------
# continuous-batching step engine
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Device half of the batch state (a pytree; donated every step).

    ``key``/``t`` implement the same cumulative fold-in schedule the
    run-to-completion loop uses, so a batch admitted at t=0 samples
    token-for-token identically to ``generate``.

    ``rkey``/``seeded`` are the per-request seed column: a seeded slot
    draws from its own key folded with the *position of the token being
    produced* instead of the pool schedule, so a seeded resubmission
    reproduces its tokens exactly regardless of which slot it lands in or
    what else shares the pool.  Unseeded slots keep the pool schedule
    (bitwise ``generate`` equality).
    """
    caches: Any           # decode-cache pytree: leaves (R, B, ...) for the
    #                       row layout, (R, NP, ...) PagedKV banks when paged
    tok: jax.Array        # (B, 1) int32 — last sampled token per slot
    pos: jax.Array        # (B,) int32  — cache position `tok` is fed at
    key: jax.Array        # PRNG key, folded once per step
    t: jax.Array          # () int32    — global step counter
    rkey: jax.Array       # (B, 2) uint32 — per-slot request PRNG key
    seeded: jax.Array     # (B,) bool — slot draws from rkey, not the pool
    table: jax.Array      # (B, P) int32 — per-slot page table (paged mode;
    #                       (B, 0) placeholder for the row layout)


@dataclass
class _PendingPrefill:
    """One admitted-but-still-prefilling request (chunked admission):
    its slots are reserved, its prompt streams into their cache rows one
    chunk per engine tick."""
    tokens: np.ndarray                    # (b, S) full prompt, int32
    gens: list                            # Generation handles (slots set)
    rkeys: np.ndarray                     # (b, 2) uint32 per-row keys
    seeded: np.ndarray                    # (b,) bool
    done: int = 0                         # prompt tokens already chunked
    #                                       (starts at the first divergent
    #                                       token on a prefix hit)
    tables: Optional[np.ndarray] = None   # (b, P) page tables (paged mode)
    cow: Optional[tuple] = None           # (src, dst) page pair to copy
    #                                       before the first chunk write;
    #                                       src holds a pool reference
    #                                       (dropped when the copy runs)
    hit: bool = False                     # admitted through a prefix hit
    mapped: int = 0                       # shared pages mapped read-only
    had_cow: bool = False                 # plan included a boundary copy
    started: bool = False                 # first chunk has executed
    #                                       (admit-to-first-chunk latency)


class StepEngine(SlotPool):
    """Continuous-batching decode engine for one model context.

    Fixed batch shape ``batch_size``; requests occupy slots.  All device
    work happens in three jitted programs: ``_admit_<S>`` (per prompt
    length), ``_step``, and the cache-row insert fused into admit.  The
    engine is deliberately un-timed and thread-free: callers (the classic
    ``generate`` wrapper, the token-granular ``ContinuousScheduler``)
    decide when to step, when to switch contexts, and what to measure.

    ``params`` is passed per call: under the context-switching server the
    weights live in a ``ContextSwitchEngine`` slot that may be evicted and
    reloaded between steps; the engine never captures them.

    ``prefill_chunk=C`` switches admission to *chunked prefill*: instead
    of one whole-prompt program per prompt length, ``admit`` reserves the
    slots and queues the prompt, and each engine tick runs at most ONE
    fixed-shape (b, C) chunk program (``LM.prefill_chunk``, the verify
    machinery pointed at admission) before the decode step.  Admission
    latency for live rows is therefore bounded by one chunk regardless of
    prompt length, prompts pad to the chunk width (≤2 compiled chunk
    programs total: streaming + final), and the prompt streams into its
    slot behind decode the way context loads stream into the shadow slot.
    The final chunk samples the first token under the same admission
    gumbel rules as one-shot admit, so greedy and seeded-temperature
    streams are token-identical across chunk sizes (tested).  Chunked
    mode needs an all-attention model with a full (non-ring) cache: a
    mid-prefill row's parked decode writes go to the last cache slot,
    which a ring would wrap onto live window entries, and recurrent state
    cannot carry across host-side chunk boundaries.

    ``paged=True`` swaps the row-granular cache for a *paged slot pool*:
    instead of one ``max_len`` cache row per slot, the cache is ONE
    shared bank of ``num_pages`` fixed-size pages (``page_size`` tokens
    each), each admitted row owns only the ``ceil((S+max_new-1)/page)``
    pages its own lifetime needs, and a per-slot page table
    (``DecodeState.table``, scalar-prefetched down to the
    ``paged_attention`` kernel) maps virtual positions onto pool pages.
    ``num_pages`` is the HBM budget knob: the default
    ``batch_size * max_len/page_size + 1`` matches the row layout's
    capacity, while a smaller bank serves MORE concurrent short requests
    in the same memory (admission gates on ``can_admit``: free slots AND
    free pages).  Retirement returns pages, not a whole row (FIFO
    recycling, see ``PagePool``); non-live rows' per-step writes route to
    the park page so a freed page can be recycled instantly without
    disturbing its new owner.  Sampling never sees the cache layout, so
    paged and row streams are bitwise-identical (greedy + seeded
    temperature, one-shot + chunked admission — tested).  Paged mode
    needs an all-attention, non-ring model, same as chunked prefill.

    ``multi_step=T`` fuses up to T decode steps into ONE device program
    per tick (``LM.decode_multi_step[_pages]``): the host's
    rank/drain/admit bookkeeping amortizes over every committed step
    instead of being paid per token.  On-device EOS / token-budget /
    page-exhaustion bitmaps early-exit the loop the moment any slot
    would change occupancy, so retirement timing — and, because the
    sampling rule and key-fold chain are shared with the single-step
    program, every sampled token — is bitwise-identical to T single
    steps (tested).  While a chunked prefill is mid-stream the engine
    drops to single steps so the prompt keeps its one-chunk-per-tick
    admission latency.

    ``quantize_kv="int8"`` (paged mode only) stores the shared page bank
    as int8 codes with per-token-per-head f32 scales in parallel leaves
    — about half the bytes per page, so roughly 2x the pages fit in the
    same HBM budget and admitted concurrency rises with them.  Writes
    quantize on insert/decode/verify; the paged attention kernel
    dequantizes in VMEM (the scales ride the same scalar-prefetched page
    table).  Outputs are no longer bitwise-equal to fp16 — the parity
    suite bounds greedy logit divergence and distribution-level sampling
    drift instead (tested).

    ``prefix_cache=True`` (paged mode only) shares already-written
    prompt pages across admissions: every completed prompt's whole pages
    are indexed by their token runs (``PrefixIndex``), and a new
    admission whose prompt starts with an indexed run maps those page
    ids straight into its table — refcounted, read-only — and prefills
    only from the first divergent token.  A full-prefix hit recomputes
    just the last prompt token, and because that write would land in a
    *shared* page, the engine copy-on-writes that one boundary page
    (``LM.copy_cache_pages``) before it: shared pages are never mutated,
    so a prefix-hit stream is bitwise-identical to the same request
    admitted cold (greedy + seeded temperature — tested).  Retired
    prompts' pages live on in the cache at refcount 1; when admission
    would fail on pages, ``can_admit`` evicts those cached pages
    LRU-first (leaf pages before their parents) until the request fits
    or nothing evictable remains.  Lookup is per-request (single-row
    admissions; multi-row admits stay cold but still populate the
    index).  int8 banks index under their own namespace — codes are a
    lossy function of the same tokens, so fp16 and int8 entries never
    cross-match.

    ``shards=N`` / ``mesh=...`` (paged mode only) partition the page
    bank into N equal slices with one host-side free-list each
    (``ShardedPagePool``): a page id encodes (shard, local page) as
    ``(id // pages_per_shard, id % pages_per_shard)``, admission routes
    whole small requests to one shard (prefix hits to the shard holding
    their cached pages, cold admissions to the least-loaded shard) and
    spans big requests across shards.  ``shards`` alone is *logical*
    sharding — allocator routing plus per-shard telemetry on a single
    device.  ``mesh`` additionally lays the bank leaves out over the
    mesh's ``shard_axis`` (``NamedSharding`` on the page axis) so shard
    s's pages live on device s.  Allocation order is the only thing
    that changes and the gathered attention math is permutation-
    invariant in page ids, so sharded streams stay bitwise-identical to
    the single-device paged engine (tested under forced host device
    count).  ``local_read=True`` (needs ``mesh``) additionally
    shard_maps decode/verify so each shard's kernel instance reads ONLY
    its local bank slice and partial softmaxes merge with one
    pmax/psum; the merge changes the reduction order, so that path is
    allclose-, not bitwise-, equivalent.
    """

    def __init__(self, model: LM, batch_size: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 eos_id: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 paged: bool = False, page_size: int = 256,
                 num_pages: Optional[int] = None,
                 admit_jump_limit: int = 4,
                 multi_step: int = 1,
                 quantize_kv: Optional[str] = None,
                 prefix_cache: bool = False,
                 bank: Optional[SharedBank] = None,
                 shards: Optional[int] = None,
                 mesh=None, shard_axis: Optional[str] = None,
                 local_read: bool = False,
                 telemetry: Optional[Telemetry] = None):
        self.model = model
        telemetry = telemetry if telemetry is not None else Telemetry()
        self.max_len = max_len
        self.temperature = temperature
        self.seed = seed
        self.eos_id = eos_id
        if multi_step < 1:
            raise ValueError(f"multi_step must be >= 1, got {multi_step}")
        self.multi_step = multi_step
        if quantize_kv not in (None, "int8"):
            raise ValueError(f"quantize_kv must be None or 'int8', got "
                             f"{quantize_kv!r}")
        if quantize_kv is not None and not paged:
            raise ValueError(
                "quantize_kv targets the shared page bank: it needs "
                "paged=True (the row cache stays full precision)")
        self.quantize_kv = quantize_kv
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{prefill_chunk}")
            if any(mix != "attn" for mix, _ in model.pattern):
                raise ValueError(
                    "chunked prefill needs an all-attention model "
                    "(recurrent state cannot carry across chunk "
                    "boundaries)")
            if model.cfg.sliding_window:
                raise ValueError(
                    "chunked prefill needs a full (non-ring) cache: a "
                    "pending row's parked decode writes would wrap onto "
                    "window entries the chunks just filled")
        self.prefill_chunk = prefill_chunk
        self.admit_jump_limit = admit_jump_limit
        self._jumps = 0              # consecutive short-prompt jump-aheads
        self._pending: deque[_PendingPrefill] = deque()

        # ---- sharded page bank: resolve the mesh/shard knobs up front
        # (the pool they configure is built in the paged branch below)
        if mesh is not None:
            if shard_axis is None:
                shard_axis = mesh.axis_names[0]
            if shard_axis not in mesh.axis_names:
                raise ValueError(f"shard_axis {shard_axis!r} is not a mesh "
                                 f"axis {tuple(mesh.axis_names)}")
            mesh_n = mesh.shape[shard_axis]
            if shards is None:
                shards = mesh_n
            elif shards != mesh_n:
                raise ValueError(
                    f"shards={shards} disagrees with mesh axis "
                    f"{shard_axis!r} of size {mesh_n}")
        if shards is not None and shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if (mesh is not None or (shards or 1) > 1) and not paged:
            raise ValueError(
                "sharding partitions the page bank: shards/mesh need "
                "paged=True (the row cache has per-slot affinity)")
        if local_read and mesh is None:
            raise ValueError(
                "local_read shard_maps the bank reads over mesh devices: "
                "it needs mesh=")
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.local_read = bool(local_read)
        self.num_shards = 1

        # ---- paged slot pool: per-slot page tables over one shared bank
        self.paged = paged
        if bank is not None and not paged:
            raise ValueError(
                "a shared bank IS a page pool: it needs paged=True")
        self._bank = bank
        if paged:
            model._require_paged_support()   # all-attention, non-ring
            page_size = min(page_size, max_len)
            if max_len % page_size:
                raise ValueError(
                    f"page_size {page_size} must divide max_len "
                    f"{max_len}: a row's virtual space is a whole number "
                    "of pages (and the gathered view must equal the row "
                    "cache elementwise for the identity guarantees)")
            self.page_size = page_size
            self.pages_per_row = max_len // page_size
            if bank is not None:
                # the bank's creator sized AND sharded the pool; this
                # engine just allocates from it alongside its siblings
                bank_shards = getattr(bank.pool, "num_shards", 1)
                if shards is not None and shards != bank_shards:
                    raise ValueError(
                        f"shards={shards} but the shared bank's pool has "
                        f"{bank_shards} shard(s) — the bank's creator "
                        "fixes the sharding")
                self.num_shards = bank_shards
                if bank.pool.total_pages - bank_shards < self.pages_per_row:
                    raise ValueError(
                        f"shared bank of {bank.pool.total_pages} pages "
                        f"cannot hold one worst-case row "
                        f"({self.pages_per_row} pages) plus the reserved "
                        "park page(s)")
                self.num_pages = bank.pool.total_pages
                self._pages = bank.pool
            else:
                self.num_shards = shards or 1
                if num_pages is None:
                    # capacity parity with the row layout: every slot can
                    # always hold a worst-case row, split evenly across
                    # shards (+1 reserved local park page per shard)
                    need = batch_size * self.pages_per_row
                    num_pages = self.num_shards * (
                        -(-need // self.num_shards) + 1)
                if self.num_shards > 1 and num_pages % self.num_shards:
                    raise ValueError(
                        f"num_pages {num_pages} must divide by shards "
                        f"{self.num_shards}: the bank splits into equal "
                        "per-shard slices")
                if num_pages - self.num_shards < self.pages_per_row:
                    raise ValueError(
                        f"num_pages {num_pages} cannot hold one worst-case "
                        f"row ({self.pages_per_row} pages) plus the "
                        "reserved park page(s)")
                self.num_pages = num_pages
                self._pages = (
                    ShardedPagePool(num_pages, self.num_shards,
                                    telemetry=telemetry)
                    if self.num_shards > 1
                    else PagePool(num_pages, telemetry=telemetry))
        else:
            self.page_size = None
            self.pages_per_row = 0
            self.num_pages = 0
            self._pages = None
        if prefix_cache and not paged:
            raise ValueError(
                "prefix_cache shares pages of the pooled bank: it needs "
                "paged=True (the row cache has nothing to share)")
        self.prefix_cache = prefix_cache
        # int8 codes are a lossy function of the same source tokens:
        # namespacing keeps fp16/int8 entries from ever cross-matching
        if not prefix_cache:
            self._prefix = None
        elif bank is not None:
            # one index per bank: prefixes another engine of this bank
            # indexed are hits here — the pages are the same pool
            if bank.index is None:
                bank.index = PrefixIndex(self.page_size,
                                         namespace=quantize_kv or "fp16")
            self._prefix = bank.index
        else:
            self._prefix = PrefixIndex(self.page_size,
                                       namespace=quantize_kv or "fp16")

        B, T, V = batch_size, temperature, model.cfg.vocab_size
        # local_read: the paged programs shard_map attention so each mesh
        # shard reads only its local bank slice (None == global gather)
        shard_arg = (mesh, shard_axis) if self.local_read else None

        def _row_gumbel(rkeys, produced_at):
            """Per-slot gumbel fields for seeded rows: each slot's key is
            folded with the position of the token being produced — unique
            per draw, and independent of slot index, admission boundary,
            or pool traffic (that's what makes seeds reproducible)."""
            folded = jax.vmap(jax.random.fold_in)(rkeys, produced_at)
            return jax.vmap(
                lambda k: jax.random.gumbel(k, (V,), jnp.float32))(folded)

        def _sample_tok(last, key, pos, live, seeded, rkey):
            """The engine's ONE sampling rule, shared verbatim by the
            single-step and fused multi-step programs — that sharing is
            what makes ``multi_step=T`` bitwise-identical to T single
            steps.  Pool schedule: argmax(l/T + gumbel) IS categorical's
            own computation, bitwise (same key, same (B, V) field).  The
            per-row seeded field only exists while a LIVE seeded row
            does (lax.cond) — unseeded pools pay nothing extra."""
            if T > 0.0:
                g = jax.random.gumbel(key, (B, V), jnp.float32)
                sl = seeded & live
                g = jax.lax.cond(
                    sl.any(),
                    lambda g: jnp.where(
                        sl[:, None], _row_gumbel(rkey, pos + 1), g),
                    lambda g: g, g)
                return jnp.argmax(last / T + g, axis=-1).astype(jnp.int32)
            return jnp.argmax(last, axis=-1).astype(jnp.int32)

        def _step(params, state: DecodeState, live):
            key = jax.random.fold_in(state.key, state.t)
            if paged:
                # non-live rows' per-step writes route to the park page
                # (their pages may already be recycled to a neighbor)
                logits, caches = model.decode_step_pages(
                    params, state.caches, state.tok, state.pos,
                    state.table, live=live, shard=shard_arg)
            else:
                logits, caches = model.decode_step(params, state.caches,
                                                   state.tok, state.pos)
            nxt = _sample_tok(logits[:, -1], key, state.pos, live,
                              state.seeded, state.rkey)
            pos = jnp.where(live, state.pos + 1, state.pos)
            pos = jnp.minimum(pos, max_len - 1)               # parked slots
            return nxt, state._replace(caches=caches, tok=nxt[:, None],
                                       pos=pos, key=key, t=state.t + 1)

        MS = multi_step
        eos = eos_id

        def _mstep(params, state: DecodeState, live, rem, budget):
            """Up to ``multi_step`` decode steps in ONE device program
            (``LM.decode_multi_step[_pages]``): the host tick amortizes
            over every committed step.  ``rem`` ((B,) int32) is each live
            row's remaining token budget and ``budget`` its position cap
            (page allocation / cache end); together with EOS they form
            the on-device occupancy bitmap — the loop exits the moment
            any live slot would change occupancy, so the host's view of
            the pool is never stale.  The (key, t) fold chain threads
            through the loop carry exactly as the single-step program
            advances it."""

            def sample_fn(last, pos, carry):
                key, t = carry
                k2 = jax.random.fold_in(key, t)
                nxt = _sample_tok(last, k2, pos, live, state.seeded,
                                  state.rkey)
                return nxt, (k2, t + 1)

            def stop_fn(nxt, posr, i):
                done = live & (rem <= i + 1)          # token budget spent
                if eos is not None:
                    done = done | (live & (nxt == eos))
                done = done | (live & (posr >= budget))   # pages exhausted
                return done.any()

            carry = (state.key, state.t)
            if paged:
                out, n, caches, tok, pos, carry = (
                    model.decode_multi_step_pages(
                        params, state.caches, state.tok, state.pos,
                        state.table, MS, sample_fn, stop_fn, carry,
                        live=live, pos_cap=max_len - 1, shard=shard_arg))
            else:
                out, n, caches, tok, pos, carry = model.decode_multi_step(
                    params, state.caches, state.tok, state.pos, MS,
                    sample_fn, stop_fn, carry, live=live,
                    pos_cap=max_len - 1)
            key, t = carry
            return out, n, state._replace(caches=caches, tok=tok, pos=pos,
                                          key=key, t=t)

        def _admit(params, state: DecodeState, tokens, slots, tables,
                   rkeys, seeded):
            """Prefill (b, S) prompts into cache rows `slots`; sample their
            first tokens at t=0 with the *current* (unfolded) key — the
            same draw ``generate`` makes from its prefill logits.  Row r
            of a (B, V) gumbel field reproduces ``categorical``'s row r
            exactly, so a single-row admission in a half-full batch
            samples the same token it would in a full batched prefill.
            Past t=0 the admission key is salted: ``state.key`` is the key
            step t-1 DREW from, and a slot retired by that step and
            recycled here must not hand the newcomer the old occupant's
            last gumbel row (the salt lives above 2^30, disjoint from
            step folds).  Seeded rows draw from their own key instead
            (folded with S: the first token is produced at position S).

            ``tables`` is the admitted rows' (b, P) page tables in paged
            mode ((b, 0) placeholder otherwise): the prefilled rows
            scatter into the rows' own pages instead of a slot row, and
            the draw logic above is UNTOUCHED — sampling never sees the
            cache layout, which is what makes paged and row streams
            token-identical."""
            S = tokens.shape[1]
            logits, rows = model.prefill(params, tokens, max_len)
            last = logits[:, -1]                               # (b, V) f32
            if T > 0.0:
                salted = jax.random.fold_in(state.key,
                                            (1 << 30) ^ state.t)
                akey = jnp.where(state.t == 0, state.key, salted)
                g = jax.random.gumbel(akey, (B, V), jnp.float32)[slots]
                g = jax.lax.cond(
                    seeded.any(),
                    lambda g: jnp.where(
                        seeded[:, None],
                        _row_gumbel(rkeys, jnp.full(slots.shape, S,
                                                    jnp.int32)), g),
                    lambda g: g, g)
                first = jnp.argmax(last / T + g, axis=-1)
            else:
                first = jnp.argmax(last, axis=-1)
            first = first.astype(jnp.int32)
            if paged:
                caches = model.insert_cache_pages(state.caches, rows,
                                                  tables)
            else:
                caches = model.insert_cache_rows(state.caches, rows, slots)
            tok = state.tok.at[slots].set(first[:, None])
            pos = state.pos.at[slots].set(jnp.int32(S))
            return first, state._replace(
                caches=caches, tok=tok, pos=pos,
                table=state.table.at[slots].set(tables),
                rkey=state.rkey.at[slots].set(rkeys),
                seeded=state.seeded.at[slots].set(seeded))

        C = prefill_chunk

        def _chunk(params, state: DecodeState, tokens, pos, slots, tables):
            """One streaming (non-final) prefill chunk: write the (b, C)
            block's k/v into cache rows `slots` at per-row offsets `pos`.
            No logits, no sampling — ONE compiled program serves every
            non-final chunk of every prompt length.  Paged mode writes
            through the rows' page tables instead: exactly the chunk's
            (pos, pos+C) positions move, O(C) per chunk instead of the
            row path's O(max_len) gather/scatter."""
            if paged:
                _, caches = model.prefill_chunk_pages(
                    params, state.caches, tokens, pos, tables,
                    need_logits=False, shard=shard_arg)
            else:
                _, caches = model.prefill_chunk(params, state.caches,
                                                tokens, pos, slots,
                                                need_logits=False)
            return state._replace(caches=caches)

        def _chunk_final(params, state: DecodeState, tokens, pos, slots,
                         tables, nvalid, rkeys, seeded):
            """Final prefill chunk: the block is padded to C (`nvalid`
            real tokens per row; the write mask keeps pad k/v out of the
            cache) and the last real token's logits sample the first
            token under the SAME admission gumbel rules as one-shot
            ``_admit`` — shared (B, V) field indexed by slot for pool
            rows, per-row key folded with the prompt length for seeded
            rows — so chunked and one-shot admission are token-identical
            for greedy and seeded-temperature streams.  The chunk width
            is read off ``tokens`` (not the closure) so the same program
            also serves one-shot prefix-hit admission, which runs the
            prompt's un-cached suffix — whatever its width — as one
            final chunk."""
            W = tokens.shape[1]
            wmask = jnp.arange(W, dtype=jnp.int32)[None, :] < nvalid[:, None]
            if paged:
                logits, caches = model.prefill_chunk_pages(
                    params, state.caches, tokens, pos, tables, wmask=wmask,
                    shard=shard_arg)
            else:
                logits, caches = model.prefill_chunk(params, state.caches,
                                                     tokens, pos, slots,
                                                     wmask=wmask)
            last = jnp.take_along_axis(
                logits, (nvalid - 1)[:, None, None], axis=1)[:, 0]  # (b, V)
            plen = pos + nvalid                    # (b,) prompt length S
            if T > 0.0:
                salted = jax.random.fold_in(state.key,
                                            (1 << 30) ^ state.t)
                akey = jnp.where(state.t == 0, state.key, salted)
                g = jax.random.gumbel(akey, (B, V), jnp.float32)[slots]
                g = jax.lax.cond(
                    seeded.any(),
                    lambda g: jnp.where(seeded[:, None],
                                        _row_gumbel(rkeys, plen), g),
                    lambda g: g, g)
                first = jnp.argmax(last / T + g, axis=-1)
            else:
                first = jnp.argmax(last, axis=-1)
            first = first.astype(jnp.int32)
            return first, state._replace(
                caches=caches, tok=state.tok.at[slots].set(first[:, None]),
                pos=state.pos.at[slots].set(plen),
                rkey=state.rkey.at[slots].set(rkeys),
                seeded=state.seeded.at[slots].set(seeded))

        def _copy(params, state: DecodeState, src, dst):
            """Copy-on-write: duplicate pool pages src -> dst across all
            banks BEFORE the diverging row's first write.  ``params`` is
            unused but keeps the runner's uniform ``fn(params, *args)``
            calling convention."""
            del params
            return state._replace(
                caches=model.copy_cache_pages(state.caches, src, dst))

        self._step_fn = jax.jit(_step, donate_argnums=(1,))
        self._mstep_fn = jax.jit(_mstep, donate_argnums=(1,))
        self._admit_fn = jax.jit(_admit, donate_argnums=(1,))
        self._chunk_fn = jax.jit(_chunk, donate_argnums=(1,))
        self._chunk_final_fn = jax.jit(_chunk_final, donate_argnums=(1,))
        self._copy_fn = jax.jit(_copy, donate_argnums=(1,))

        # Execution hook: when set, every device program runs as
        # ``runner(fn, params, *args)`` — the continuous scheduler points
        # this at ``ContextSwitchEngine.run_step`` so steps execute
        # against the ACTIVE slot's buffers with hidden-load accounting.
        self.runner = None

        self.state: Optional[DecodeState] = None
        self._pool_init(B, telemetry=telemetry)
        if paged:
            # prefix-cache counters (stay 0 with the cache off): benches
            # and the scheduler snapshot surface them engine-lifetime
            self.stats.update(prefix_hits=0, prefix_pages_mapped=0,
                              cow_copies=0, cache_evictions=0)
        self.reset()

    # ------------------------------------------------------------- lifecycle
    def reset(self, seed: Optional[int] = None, keep_prefix: bool = False):
        """Empty pool + restarted key schedule.  Cache buffers are reused
        when they exist: a freed slot's stale row is dead weight that the
        next admission overwrites in full, so only the first reset pays
        the allocation (generate() resets per call — keep it cheap).

        ``keep_prefix=True`` carries the prefix cache across the reset:
        the index is snapshotted before the allocator clears, and — if
        the bank's buffers survived (no rebuild) — its pages are
        re-adopted from the fresh free-list afterwards, so the first
        post-reset admission of a cached prompt still hits.  A rebuilt
        (zeroed) bank drops the snapshot instead: the pages' bytes are
        gone and a restored index would serve zero k/v."""
        B = self.batch_size
        snap = None
        if keep_prefix and self._bank is None and self._prefix is not None:
            snap = self._prefix.snapshot()
        # a private page pool just resets; a shared bank keeps serving
        # the OTHER engines, so only this engine's own rows release
        if self._bank is not None:
            own = []
            for g in self.slots:
                if g is not None and g.pages:
                    own += g.pages
                    g.pages = None
            for ps in self._pending:
                for g in ps.gens:
                    if g.pages:
                        own += g.pages
                        g.pages = None
            if own:
                self._pages.release(own)
        elif self._pages is not None:
            self._pages.reset()
        if self._bank is None and self._prefix is not None:
            self._prefix.clear()     # its pages just left the allocator
        caches = None
        if self.state is not None and not any(
                getattr(x, "is_deleted", lambda: False)()
                for x in jax.tree.leaves(self.state.caches)):
            caches = self.state.caches   # reuse, unless a failed step
        if self._bank is not None and self._bank.caches is not None:
            caches = self._bank.caches   # the bank copy is authoritative
        rebuilt = caches is None
        if rebuilt:                      # donated them out from under us
            caches = (self.model.init_page_pool(
                          self.num_pages, self.page_size,
                          quantized=self.quantize_kv is not None)
                      if self.paged else
                      self.model.init_cache(B, self.max_len))
            if self.paged and self.mesh is not None:
                # lay the bank over the mesh: the page axis of every
                # leaf splits across shard_axis so shard s physically
                # holds local pages [s*per, (s+1)*per)
                caches = self._place_bank(caches)
        if self._bank is not None:
            self._bank.caches = caches
        self.state = DecodeState(
            caches=caches,
            tok=jnp.zeros((B, 1), jnp.int32),
            pos=jnp.zeros((B,), jnp.int32),
            key=jax.random.PRNGKey(self.seed if seed is None else seed),
            t=jnp.zeros((), jnp.int32),
            rkey=jnp.zeros((B, 2), jnp.uint32),
            seeded=jnp.zeros((B,), bool),
            # every table entry must be a valid pool index; park (0) is
            # the safe default — empty slots read/write garbage space
            table=jnp.zeros((B, self.pages_per_row), jnp.int32))
        self._pool_reset()
        self._pending.clear()
        self._jumps = 0
        if snap is not None and not rebuilt:
            # the bank's buffers survived the reset: the snapshot's pages
            # still hold their token runs, so re-adopt them from the
            # fresh free-list (refcount 1 each, LRU recency preserved)
            self._prefix.restore(snap, self._pages.adopt)

    def _place_bank(self, caches):
        """``jax.device_put`` every page-bank leaf with its mesh layout
        (page axis split over ``shard_axis``, everything else
        replicated) — see ``LM.page_pool_shardings``."""
        shardings = self.model.page_pool_shardings(caches, self.mesh,
                                                   self.shard_axis)
        return jax.tree.map(jax.device_put, caches, shardings)

    def export_prefix_index(self) -> Optional[dict]:
        """Host-side snapshot of the prefix index.  The page bank keeps
        the k/v bytes; this captures which pool pages hold which token
        runs so a later engine over the SAME bank content can re-adopt
        them (``restore_prefix_index``).  ``None`` with the cache off."""
        return None if self._prefix is None else self._prefix.snapshot()

    def restore_prefix_index(self, snap: dict) -> list[int]:
        """Re-adopt a snapshot's cached pages into this engine's index:
        every page still on the free-list is claimed back at refcount 1
        with its LRU recency; entries whose page was reallocated in the
        meantime drop out along with their subtrees (their bytes are
        someone else's now).  Returns the page ids adopted."""
        if self._prefix is None:
            raise ValueError("prefix_cache is off: nothing to restore "
                             "into")
        return self._prefix.restore(snap, self._pages.adopt)

    def _call(self, fn, params, *args):
        if self.runner is None:
            return fn(params, *args)
        return self.runner(fn, params, *args)

    def _bank_pull(self):
        """Adopt the bank's current pages: another engine's jitted call
        may have donated the buffers this state still references."""
        if (self._bank is not None and self._bank.caches is not None
                and self.state is not None
                and self._bank.caches is not self.state.caches):
            self.state = self.state._replace(caches=self._bank.caches)

    def _bank_push(self):
        """Publish the (possibly donated-and-replaced) pages back to the
        bank for the next engine."""
        if self._bank is not None and self.state is not None:
            self._bank.caches = self.state.caches

    # -------------------------------------------------------------- queries
    def pending_slots(self) -> int:
        return sum(len(ps.gens) for ps in self._pending)

    def free_pages(self) -> int:
        return self._pages.free_pages() if self.paged else 0

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        """Pages one row needs for its whole lifetime: positions
        ``0 .. prompt_len + max_new - 2`` are written/read (the final
        sampled token is never fed back), so the last page is the one
        holding position ``prompt_len + max_new - 2``."""
        return max(1, -(-(prompt_len + max_new - 1) // self.page_size))

    def can_admit(self, tokens, max_new: int) -> bool:
        if not super().can_admit(tokens, max_new):
            return False                 # super set last_admit_block
        if not self.paged:
            return True
        tokens = np.asarray(tokens)
        b, S = (1, tokens.shape[0]) if tokens.ndim == 1 else tokens.shape
        npages = self.pages_needed(S, max_new)
        plan = None
        protect = []
        if self.prefix_cache and b == 1:
            plan = self._prefix_plan(tokens.reshape(1, S), max_new,
                                     peek=True)
            if plan is not None:
                retained, cow_src, _, _ = plan
                protect = retained + ([cow_src] if cow_src is not None
                                      else [])
        block = self._admit_block(b, npages, plan)
        if block is not None:
            # under pressure the cache gives memory back before admission
            # is rejected: refcount-1 cached pages (no live table maps
            # them) leave LRU-first until the request fits or nothing
            # evictable remains — never the pages this very request is
            # about to map.  A shard-local shortage ("shard_pages")
            # scopes eviction to the routed shard: freeing elsewhere
            # cannot help the shard the request must land on.
            need = plan[3] if plan is not None else b * npages
            if block == "shard_pages":
                shard = (self._route_prefix(plan) if plan is not None
                         else self._pages.route(npages))
                if shard is not None:
                    self._reclaim(need - self._pages.shard_free(shard),
                                  protect=protect, shard=shard)
            else:
                self._reclaim(need - self.free_pages(), protect=protect)
            block = self._admit_block(b, npages, plan)
        self.last_admit_block = block
        return block is None

    def _admit_block(self, b: int, npages: int, plan) -> Optional[str]:
        """Why the next admission would fail on pages: ``None`` (it
        fits), ``"pages"`` (pool-wide shortage) or ``"shard_pages"``
        (the routed shard is short even though the pool is not — sharded
        pools only)."""
        if plan is not None:
            return self._pages.blocked(plan[3],
                                       shard=self._route_prefix(plan))
        if b == 1:
            return self._pages.blocked(npages)
        return self._pages.blocked_rows(b, npages)

    def _route_prefix(self, plan) -> Optional[int]:
        """Locality routing for a prefix hit: the row's fresh pages land
        on the shard already holding the matched pages (the CoW boundary
        page when there is one — its copy destination must be
        co-resident with the source under local reads).  ``None`` (route
        free / spanning) when nothing anchors the hit or the pool is
        unsharded."""
        if self._pages.num_shards == 1:
            return None
        retained, cow_src, _, _ = plan
        anchor = cow_src if cow_src is not None else (
            retained[-1] if retained else None)
        return None if anchor is None else self._pages.shard_of(anchor)

    # -------------------------------------------------------- prefix cache
    def _reclaim(self, deficit: int, protect=(),
                 shard: Optional[int] = None) -> int:
        """Evict up to ``deficit`` cached prefix pages (LRU leaves first;
        only refcount-1 pages, i.e. held by nothing but the index) back
        into the free-list.  ``shard`` scopes eviction to pages owned by
        that shard — relieving a shard-local shortage without spending
        cache entries whose pages could not help.  -> pages reclaimed."""
        if self._prefix is None or deficit <= 0:
            return 0
        keep = set(protect)

        def _evictable(p):
            if p in keep or self._pages.refcount(p) != 1:
                return False
            return shard is None or self._pages.shard_of(p) == shard

        evicted = self._prefix.evict_lru(deficit, _evictable)
        if evicted:
            self._pages.release(evicted)
            self._pages.note_reclaimed(evicted)
            self.stats["cache_evictions"] += len(evicted)
            if self._trace.enabled:
                self._trace.instant(
                    "page-reclaim", f"{self.telemetry.prefix}eng",
                    args={"evicted": len(evicted)})
        return len(evicted)

    def _prefix_plan(self, tokens, max_new: int, peek: bool = False):
        """Look up the longest indexed whole-page prefix of a single-row
        prompt.  -> ``(retained, cow_src, d, owned)`` or ``None`` (miss /
        cache off / multi-row): ``retained`` are the page ids mapped
        read-only, ``d`` the position prefill resumes at (the first
        divergent token, floored at S-1 — the last prompt token is always
        recomputed so there are logits to sample from), ``cow_src`` the
        shared boundary page to copy-on-write when ``d`` lands mid-page
        inside it, and ``owned`` the fresh pages still to allocate
        (including the CoW destination).  ``peek`` keeps the index's LRU
        recency untouched — ``can_admit`` is a pure capacity probe and
        the ``admit`` that may follow does the one real (bumping)
        lookup."""
        if self._prefix is None or tokens.shape[0] != 1:
            return None
        b, S = tokens.shape
        hit = self._prefix.lookup(tokens[0], peek=peek)
        if not hit:
            return None
        ps = self.page_size
        d = min(len(hit) * ps, S - 1)
        retained = hit[:d // ps]
        cow_src = hit[d // ps] if d < len(hit) * ps else None
        owned = self.pages_needed(S, max_new) - len(retained)
        return retained, cow_src, d, owned

    def _take_prefix_pages(self, plan, S: int, max_new: int):
        """Build a prefix-hit row's table: matched pages mapped read-only
        (one pool reference each), fresh pages for the rest — the first
        fresh page is the CoW destination when the plan has one.  The CoW
        *source* also takes a pool reference even though it never enters
        the table: the copy may run later (chunked admission defers it to
        the first chunk tick), and without the pin an interleaved
        admission's ``_reclaim`` could see it at refcount 1 once its
        original owner retired, evict it, and recycle the storage before
        the copy reads it.  The pin drops when the copy executes (or on
        the failure paths).  Returns ``(table (1, P), pages in table
        order, fresh)``."""
        retained, cow_src, d, owned = plan
        shard = self._route_prefix(plan)
        protect = retained + ([cow_src] if cow_src is not None else [])
        block = self._pages.blocked(owned, shard=shard)
        if block == "shard_pages" and shard is not None:
            self._reclaim(owned - self._pages.shard_free(shard),
                          protect=protect, shard=shard)
        elif block is not None:
            self._reclaim(owned - self._pages.free_pages(),
                          protect=protect)
        fresh = self._pages.take(owned, shard=shard)   # raises if short
        self._pages.acquire(retained)
        if cow_src is not None:
            self._pages.acquire([cow_src])       # pinned until the copy
        npages = len(retained) + owned
        table = np.full((1, self.pages_per_row), PagePool.PARK, np.int32)
        table[0, :len(retained)] = retained
        table[0, len(retained):npages] = fresh
        return table, retained + fresh, fresh

    def _drop_prefix_pages(self, plan, fresh):
        """Failed prefix-hit admission: fresh pages back to the FRONT in
        original order (the retry re-draws them), the mapped references
        dropped (the index still pins those pages, so they never free),
        and the CoW-source pin released."""
        retained, cow_src, _, _ = plan
        self._pages.restore(fresh)
        self._pages.release(retained)
        if cow_src is not None:
            self._pages.release([cow_src])

    def _index_prompt(self, tokens_row, pages):
        """Index one row's *fully written* prompt pages — called only
        once its prefill completed, so every indexed page holds its
        complete token run and is never written again (the owner's
        remaining writes are decode tokens at positions >= S).  The
        partially-filled last prompt page never enters.  The index takes
        one pool reference per page it newly adopted; runs already
        indexed keep their first writer's page."""
        if self._prefix is None or pages is None:
            return
        n = len(tokens_row) // self.page_size
        if n:
            self._pages.acquire(self._prefix.insert(tokens_row, pages[:n]))

    # ------------------------------------------------------ page allocation
    def _take_pages(self, b: int, S: int, max_new: int):
        """Allocate each admitted row its pages and build the (b, P)
        tables (unused tail entries point at the park page).  Returns
        (tables, flat page list for failure restore)."""
        npages = self.pages_needed(S, max_new)
        if self._pages.num_shards > 1:
            return self._take_pages_sharded(b, npages)
        if self.prefix_cache and b * npages > self._pages.free_pages():
            self._reclaim(b * npages - self._pages.free_pages())
        pages = self._pages.take(b * npages)
        tables = np.full((b, self.pages_per_row), PagePool.PARK, np.int32)
        for i in range(b):
            tables[i, :npages] = pages[i * npages:(i + 1) * npages]
        return tables, pages

    def _take_pages_sharded(self, b: int, npages: int):
        """Cold admission on a sharded pool: each row routes to the
        least-loaded shard at its turn (spanning when a row outgrows one
        shard), so a multi-row admit spreads across shards exactly as
        ``b`` sequential single-row admits would — the simulation
        ``ShardedPagePool.blocked_rows`` prices.  Rows allocate
        sequentially; a mid-batch shortage rolls the earlier rows' takes
        back so the caller sees one atomic failure."""
        if self.prefix_cache:
            blk = self._pages.blocked_rows(b, npages)
            if blk == "pages":
                self._reclaim(b * npages - self._pages.free_pages())
            elif blk == "shard_pages":
                # the pool has room but the routed shard does not; evict
                # up to one row's worth scoped to the shard the next row
                # would land on
                shard = self._pages.route(npages)
                if shard is not None:
                    self._reclaim(npages - self._pages.shard_free(shard),
                                  shard=shard)
        taken: list[list[int]] = []
        tables = np.full((b, self.pages_per_row), PagePool.PARK, np.int32)
        try:
            for i in range(b):
                rows = self._pages.take(npages)   # routed internally
                tables[i, :npages] = rows
                taken.append(rows)
        except BaseException:
            for rows in reversed(taken):
                self._pages.restore(rows)
            raise
        return tables, [p for rows in taken for p in rows]

    # ------------------------------------------------------------- admission
    def admit(self, params, tokens, max_new: int,
              metas: Optional[list] = None,
              seeds: Optional[list] = None,
              submitted_at: Optional[float] = None) -> list[Generation]:
        """Admit (b, S) prompt rows into b free slots.  Raises if the pool
        lacks room or the request would run past the cache; callers gate
        on ``free_slots()``.

        One-shot mode (``prefill_chunk is None``): prefill + first token
        happen here, in one whole-prompt program.  Chunked mode: the
        slots are reserved and the prompt queued; chunks stream in one
        per subsequent ``step``/``prefill_tick``, and the returned
        ``Generation``s stay token-less until their final chunk samples
        the first token.

        ``seeds``: optional per-row sampling seeds — ``None`` entries keep
        the pool's shared key schedule; an int (or raw (2,) uint32 key)
        pins that row to its own key column, making its draws reproducible
        independent of slot, admission boundary, and surrounding traffic.
        """
        self._bank_pull()
        try:
            return self._admit_dispatch(params, tokens, max_new, metas,
                                        seeds, submitted_at)
        finally:
            self._bank_push()

    def _admit_dispatch(self, params, tokens, max_new, metas, seeds,
                        submitted_at) -> list[Generation]:
        tokens, rkeys, seeded = self._admit_args(tokens, metas, seeds)
        b, S = tokens.shape
        if S + max_new > self.max_len:
            raise ValueError(f"prompt {S} + {max_new} new tokens exceeds "
                             f"max_len {self.max_len}")
        plan = (self._prefix_plan(tokens, max_new) if self.paged
                and self.prefix_cache else None)
        if self.prefill_chunk is not None:
            return self._admit_chunked(tokens, max_new, metas, rkeys,
                                       seeded, plan=plan,
                                       submitted_at=submitted_at)
        if plan is not None:
            return self._admit_prefix_hit(params, tokens, max_new, metas,
                                          rkeys, seeded, plan,
                                          submitted_at=submitted_at)
        slots = self._take_slots(b)
        tables = np.zeros((b, self.pages_per_row), np.int32)
        pages = []
        if self.paged:
            try:
                tables, pages = self._take_pages(b, S, max_new)
            except BaseException:
                self._restore_slots(slots)
                raise
        try:
            first, self.state = self._call(
                self._admit_fn, params, self.state,
                jnp.asarray(tokens, jnp.int32), jnp.asarray(slots, jnp.int32),
                jnp.asarray(tables), jnp.asarray(rkeys), jnp.asarray(seeded))
        except BaseException:
            self._restore_slots(slots)   # failed admit must not leak slots
            if pages:                    # nor pages (front, original order)
                self._pages.restore(pages)
            raise
        gens = self._register(slots, S, max_new, metas,
                              first=np.asarray(first),
                              submitted_at=submitted_at)
        if self.paged:
            npages = self.pages_needed(S, max_new)
            for i, g in enumerate(gens):
                g.pages = pages[i * npages:(i + 1) * npages]
                self._index_prompt(tokens[i], g.pages)
        if self._retire_done(gens):
            # a slot freed with no step in between (steps==1 / EOS at
            # admission): advance the key so a same-boundary re-admission
            # of that slot cannot reuse this draw field.
            self._salt_admit_key()
        return gens

    def _admit_prefix_hit(self, params, tokens, max_new: int, metas,
                          rkeys, seeded, plan,
                          submitted_at=None) -> list[Generation]:
        """One-shot admission on a prefix hit: the matched pages map
        read-only into the new row's table, the boundary page is
        copied-on-write when the divergence lands inside one (BEFORE any
        write — shared pages are never mutated), and only the prompt's
        un-cached suffix runs, as ONE final-chunk program.  The final
        chunk samples under the same admission gumbel rules as
        ``_admit`` and the shared pages hold bitwise the k/v this
        prompt's own prefill would have written (same tokens, same
        positions, same math), so the stream is bitwise a cold
        admission's."""
        b, S = tokens.shape
        retained, cow_src, d, owned = plan
        slots = self._take_slots(b)
        try:
            table, pages, fresh = self._take_prefix_pages(plan, S, max_new)
        except BaseException:
            self._restore_slots(slots)
            raise
        jslots = jnp.asarray(slots, jnp.int32)
        jtable = jnp.asarray(table)
        try:
            if cow_src is not None:
                self.state = self._call(
                    self._copy_fn, params, self.state,
                    jnp.asarray([cow_src], jnp.int32),
                    jnp.asarray([fresh[0]], jnp.int32))
            self.state = self.state._replace(
                table=self.state.table.at[jslots].set(jtable))
            first, self.state = self._call(
                self._chunk_final_fn, params, self.state,
                jnp.asarray(tokens[:, d:], jnp.int32),
                jnp.full((b,), d, jnp.int32), jslots, jtable,
                jnp.full((b,), S - d, jnp.int32),
                jnp.asarray(rkeys), jnp.asarray(seeded))
        except BaseException:
            self._restore_slots(slots)
            self._drop_prefix_pages(plan, fresh)
            raise
        if cow_src is not None:
            self._pages.release([cow_src])       # copy done: pin drops
        gens = self._register(slots, S, max_new, metas,
                              first=np.asarray(first),
                              submitted_at=submitted_at)
        gens[0].pages = pages
        self._index_prompt(tokens[0], pages)
        # counters only once the admission committed — a failed program
        # rolls pages and slots back and must leave the stats (and the
        # BENCH gates reading them) untouched
        self.stats["prefix_hits"] += 1
        self.stats["prefix_pages_mapped"] += len(retained)
        if self._trace.enabled:
            self._trace.instant(
                f"prefix-hit:{gens[0].rid}", f"{self.telemetry.prefix}eng",
                args={"mapped": len(retained), "cow": cow_src is not None})
        if cow_src is not None:
            self.stats["cow_copies"] += 1
        if self._retire_done(gens):
            self._salt_admit_key()
        return gens

    def _admit_chunked(self, tokens, max_new, metas, rkeys, seeded,
                       plan=None, submitted_at=None):
        """Reserve slots and queue the prompt for chunked prefill.  The
        reserved rows' parked position moves to the LAST cache slot:
        every decode step still writes a (garbage) k/v for every row, and
        a pending row's default parked slot could sit inside the prompt
        region a later chunk just filled.  Slot max_len-1 is the one safe
        parking spot because it is never READABLE: with the admit check
        ``prompt + max_new <= max_len``, a row's decode feeds stop at
        position S+max_new-2 <= max_len-2, and the attention mask only
        reads slots <= the query position — nothing ever overwrites the
        parked garbage, nothing ever attends to it.  (Relaxing the admit
        bound, adding speculative K-slack, or a ring cache would break
        this — hence the all-attention/non-ring constructor gate.)"""
        b, S = tokens.shape
        slots = self._take_slots(b)
        tables, pages, done, cow = None, [], 0, None
        if self.paged:
            try:
                if plan is not None:
                    # prefix hit: matched pages map read-only, chunking
                    # resumes at the first divergent token; the boundary
                    # page (if any) copies right before the first chunk
                    tables, pages, fresh = self._take_prefix_pages(
                        plan, S, max_new)
                    done = plan[2]
                    if plan[1] is not None:
                        cow = (plan[1], fresh[0])
                else:
                    tables, pages = self._take_pages(b, S, max_new)
            except BaseException:
                self._restore_slots(slots)
                raise
        jslots = jnp.asarray(slots, jnp.int32)
        st = self.state._replace(
            pos=self.state.pos.at[jslots].set(self.max_len - 1))
        if self.paged:
            # tables go live at reserve time: the decode steps that run
            # while the prompt streams in don't read them (non-live rows
            # park), the chunk programs write through an explicit arg,
            # and the final chunk's sampled row needs them next step
            st = st._replace(table=st.table.at[jslots].set(
                jnp.asarray(tables)))
        self.state = st
        gens = self._register(slots, S, max_new, metas,
                              submitted_at=submitted_at)
        if self.paged:
            npages = self.pages_needed(S, max_new)
            for i, g in enumerate(gens):
                g.pages = pages[i * npages:(i + 1) * npages]
        self._pending.append(_PendingPrefill(
            tokens=np.asarray(tokens, np.int32), gens=gens, rkeys=rkeys,
            seeded=seeded, done=done, tables=tables, cow=cow,
            hit=plan is not None,
            mapped=len(plan[0]) if plan is not None else 0,
            had_cow=cow is not None))
        return gens

    def _promote_pending(self):
        """Admission priority: a short prompt (whole prompt in ONE chunk)
        may jump ahead of a long prompt's queued chunk work — its single
        final chunk costs the long prompt one tick of streaming but gets
        the short request its first token immediately.  Bounded by a
        fairness counter: after ``admit_jump_limit`` consecutive jumps
        the head MUST run a chunk, so a stream of shorts can delay a
        long prompt by at most ``limit`` ticks per chunk, never starve
        it.  Rotates the chosen entry to the queue front."""
        C = self.prefill_chunk
        head = self._pending[0]
        head_remaining = head.tokens.shape[1] - head.done
        if (len(self._pending) > 1 and head_remaining > C
                and self._jumps < self.admit_jump_limit):
            for i in range(1, len(self._pending)):
                if self._pending[i].tokens.shape[1] <= C:
                    ps = self._pending[i]
                    del self._pending[i]
                    self._pending.appendleft(ps)
                    self._jumps += 1
                    return
        if self._pending[0] is head:
            self._jumps = 0              # the head made progress

    def _note_chunk(self, ps: _PendingPrefill, t0: float, start: int,
                    end: int, final: bool):
        """Chunk-program telemetry: the admit-to-first-chunk latency
        sample (admission until its first chunk starts) and the chunk
        span on this engine's track."""
        now = self.telemetry.clock()
        if not ps.started:
            ps.started = True
            self.telemetry.observe("admit_to_first_chunk_s",
                                   t0 - ps.gens[0].admitted_at)
        if self._trace.enabled:
            self._trace.span(
                "prefill-chunk", f"{self.telemetry.prefix}eng", t0, now,
                args={"rid": ps.gens[0].rid, "start": start, "end": end,
                      "final": final})

    def prefill_tick(self, params) -> list[Generation]:
        """Run at most ONE chunk program — the admission budget.  A live
        decode row therefore waits for one (b, C) chunk per step, never a
        whole prompt.  Returns generations that finished at this boundary
        (a final chunk can instant-retire: steps==1, or EOS as the first
        token)."""
        if not self._pending:
            return []
        self._bank_pull()
        try:
            return self._prefill_tick_impl(params)
        finally:
            self._bank_push()

    def _prefill_tick_impl(self, params) -> list[Generation]:
        C = self.prefill_chunk
        if self.admit_jump_limit:
            self._promote_pending()
        ps = self._pending[0]
        b, S = ps.tokens.shape
        start = ps.done
        end = min(start + C, S)
        nvalid = end - start
        chunk = np.zeros((b, C), np.int32)
        chunk[:, :nvalid] = ps.tokens[:, start:end]
        slots = np.asarray([g.slot for g in ps.gens], np.int32)
        tables = (ps.tables if ps.tables is not None
                  else np.zeros((b, self.pages_per_row), np.int32))
        pos = np.full((b,), start, np.int32)
        t0 = self.telemetry.clock()
        try:
            if ps.cow is not None:
                # copy-on-write the shared boundary page BEFORE this
                # request's first write lands in it
                src, dst = ps.cow
                self.state = self._call(
                    self._copy_fn, params, self.state,
                    jnp.asarray([src], jnp.int32),
                    jnp.asarray([dst], jnp.int32))
                ps.cow = None
                self._pages.release([src])   # copy done: the admission-
                #                              time pin on the source
                #                              drops (the index still
                #                              holds its own reference)
            if end < S:
                self.state = self._call(
                    self._chunk_fn, params, self.state,
                    jnp.asarray(chunk), jnp.asarray(pos),
                    jnp.asarray(slots), jnp.asarray(tables))
                ps.done = end
                self._note_chunk(ps, t0, start, end, final=False)
                return []
            first, self.state = self._call(
                self._chunk_final_fn, params, self.state,
                jnp.asarray(chunk), jnp.asarray(pos), jnp.asarray(slots),
                jnp.asarray(tables), jnp.full((b,), nvalid, jnp.int32),
                jnp.asarray(ps.rkeys), jnp.asarray(ps.seeded))
        except BaseException:
            # a failed chunk abandons the whole request: release its rows
            # so the pool keeps serving (the caller fails the futures).
            # Pages restore in ONE call, in their original take order —
            # per-gen restore calls would reverse the group order and
            # break the free-list's documented FIFO determinism.
            self._pending.popleft()
            if ps.cow is not None:
                # the deferred copy never ran: drop the source pin so the
                # page goes back to being plain index-cached (evictable)
                self._pages.release([ps.cow[0]])
            pages = []
            for g in ps.gens:
                self.slots[g.slot] = None
                pages += g.pages or []
                g.pages = None
            if pages:
                self._pages.restore(pages)
            self._restore_slots([g.slot for g in ps.gens])
            raise
        self._pending.popleft()
        self._note_chunk(ps, t0, start, end, final=True)
        if ps.hit:
            # counters only once the prefix-hit admission committed (its
            # final chunk sampled): an abandoned pending rolled its pages
            # back and must not inflate the stats
            self.stats["prefix_hits"] += 1
            self.stats["prefix_pages_mapped"] += ps.mapped
            if self._trace.enabled:
                self._trace.instant(
                    f"prefix-hit:{ps.gens[0].rid}",
                    f"{self.telemetry.prefix}eng",
                    args={"mapped": ps.mapped, "cow": ps.had_cow})
            if ps.had_cow:
                self.stats["cow_copies"] += 1
        first = np.asarray(first)
        tok_now = self.telemetry.clock()
        for i, g in enumerate(ps.gens):
            g.tokens.append(int(first[i]))
            self._live[g.slot] = True
            self.stats["tokens_out"] += 1
            self._note_first_token(g, tok_now)
        if self.paged:
            # the prompt is now fully written: its whole pages become
            # indexable (BEFORE retirement, so an instant retire still
            # populates the cache — the index reference outlives the row)
            for i, g in enumerate(ps.gens):
                self._index_prompt(ps.tokens[i], g.pages)
        finished = self._retire_done(ps.gens)
        if finished:
            self._salt_admit_key()
        return finished

    # ----------------------------------------------------------- retirement
    def _retire_done(self, gens: list[Generation]) -> list[Generation]:
        """Retire finished rows AND release their pages (FIFO: to the
        back of the page free-list).  No device-side table reset is
        needed: the retired slot stops being ``live``, so its per-step
        writes route to the park page from the next step on, and its
        stale reads only feed a discarded output — freed pages can be
        recycled to a neighbor immediately without a disturb hazard."""
        finished = super()._retire_done(gens)
        if self.paged:
            for g in finished:
                if g.pages:
                    self._pages.release(g.pages)
                    g.pages = None
        return finished

    # ---------------------------------------------------------------- step
    def step(self, params) -> list[Generation]:
        """One engine tick: at most one prefill chunk (chunked admission),
        then one decode step for every live slot — or, with
        ``multi_step=T`` and no prompt mid-stream, up to T fused decode
        steps in one device program (the loop early-exits the moment any
        slot would change occupancy, so the returned retirements are
        exactly what T single ticks would have produced).  While chunked
        prefill work is pending the engine stays single-step: a fused
        loop would stall the streaming prompt for T tokens instead of
        one.  Returns the generations that finished (EOS or step limit)
        at this boundary; their slots are already back on the
        free-list."""
        finished = self.prefill_tick(params) if self._pending else []
        if not self._live.any():
            return finished
        self._bank_pull()
        try:
            return finished + self._step_live(params)
        finally:
            self._bank_push()

    def _step_live(self, params) -> list[Generation]:
        if self.multi_step > 1 and not self._pending:
            return self._step_multi(params)
        t0 = self.telemetry.clock()
        nxt, self.state = self._call(self._step_fn, params, self.state,
                                     jnp.asarray(self._live))
        nxt = np.asarray(nxt)
        now = self.telemetry.clock()
        self.stats["host_ticks"] += 1
        self.stats["device_steps"] += 1
        stepped = []
        for s in range(self.batch_size):
            g = self.slots[s]
            if g is None or not self._live[s]:
                continue                  # empty, or reserved mid-prefill
            g.tokens.append(int(nxt[s]))
            stepped.append(g)
        self.stats["tokens_out"] += len(stepped)
        self._note_tick(t0, now, 1, len(stepped))
        return self._retire_done(stepped)

    def _step_multi(self, params) -> list[Generation]:
        """The fused tick: ship every live row's remaining-token budget
        and position cap to the device, run up to ``multi_step`` decode
        steps, read back ONE (tokens, n_steps) pair.  Exactly one host
        sync per call regardless of how many steps committed."""
        B = self.batch_size
        rem = np.zeros((B,), np.int32)
        budget = np.zeros((B,), np.int32)
        for s in range(B):
            g = self.slots[s]
            if g is None or not self._live[s]:
                continue
            rem[s] = g.remaining
            budget[s] = (len(g.pages) * self.page_size
                         if self.paged and g.pages else self.max_len)
        t0 = self.telemetry.clock()
        toks, n, self.state = self._call(
            self._mstep_fn, params, self.state, jnp.asarray(self._live),
            jnp.asarray(rem), jnp.asarray(budget))
        toks = np.asarray(toks)
        n = int(n)
        now = self.telemetry.clock()
        self.stats["host_ticks"] += 1
        self.stats["device_steps"] += n
        stepped = []
        for s in range(B):
            g = self.slots[s]
            if g is None or not self._live[s]:
                continue
            g.tokens.extend(int(t) for t in toks[s, :n])
            stepped.append(g)
        self.stats["tokens_out"] += n * len(stepped)
        self._note_tick(t0, now, n, len(stepped))
        return self._retire_done(stepped)


# ---------------------------------------------------------------------------
# classic run-to-completion engine (wrappers over StepEngine)
# ---------------------------------------------------------------------------

class ServingEngine:
    def __init__(self, model: LM, params, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 telemetry: Optional[Telemetry] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.seed = seed
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.stats = ServeStats(self.telemetry.view())
        self._eng_seq = 0            # per-engine metric namespace counter
        # Per-batch-size engine cache, LRU-bounded: each entry pins a full
        # (layers, B, max_len) KV pool, so traffic with many distinct
        # batch shapes must not accumulate pools without limit — evicting
        # an entry frees its pool (a returning shape re-compiles, which
        # is what it paid before the step-engine refactor anyway).
        self.max_cached_pools = 4
        # keyed ``EngineKey``: row and paged pools are different engines
        # over different cache layouts, and every future knob is a named
        # field instead of a silently-aliasing positional slot
        self._step_engines: "OrderedDict[EngineKey, StepEngine]" = (
            OrderedDict())

        def _prefill(params, tokens, patch_embeds=None):
            return model.prefill(params, tokens, max_len,
                                 patch_embeds=patch_embeds)

        def _step(params, caches, tok, pos, key):
            logits, caches = model.decode_step(params, caches, tok, pos)
            nxt = _sample(logits[:, -1], key, temperature)
            return nxt[:, None], caches

        self._prefill = jax.jit(_prefill)
        self._step = jax.jit(_step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _key(self, seed: Optional[int]):
        """Per-request sampling key: `seed` overrides the engine default
        (the switching server threads a fresh per-request seed through
        here so temperature>0 requests are independent draws)."""
        return jax.random.PRNGKey(self.seed if seed is None else seed)

    def step_engine(self, batch_size: int, paged: bool = False,
                    page_size: int = 256) -> StepEngine:
        """The continuous-batching engine behind ``generate`` /
        ``generate_paged`` (cached per (batch shape, page layout); jitted
        programs compile once per key; least recently used keys beyond
        ``max_cached_pools`` are dropped to free their KV pools)."""
        key = EngineKey(batch_size=batch_size,
                        page_size=page_size if paged else None)
        eng = self._step_engines.get(key)
        if eng is None:
            eng = StepEngine(self.model, batch_size, self.max_len,
                             temperature=self.temperature, seed=self.seed,
                             paged=paged, page_size=page_size,
                             telemetry=self.telemetry.scoped(
                                 f"eng.{self._eng_seq}."))
            self._eng_seq += 1
            self._step_engines[key] = eng
        self._step_engines.move_to_end(key)
        if len(self._step_engines) > self.max_cached_pools:
            # evict oldest IDLE shapes only: dropping an engine with live
            # rows would split state between the caller's handle and a
            # later recreation
            for b in [b for b, e in self._step_engines.items()
                      if e is not eng and not e.live_slots()]:
                if len(self._step_engines) <= self.max_cached_pools:
                    break
                del self._step_engines[b]
        return eng

    def generate(self, tokens, steps: int, patch_embeds=None,
                 seed: Optional[int] = None) -> np.ndarray:
        """tokens: (B, S) prompt; returns (B, steps) generated ids.

        Thin wrapper over ``StepEngine``: the whole batch is admitted at
        t=0 and stepped to completion — the degenerate (static-batch) case
        of continuous batching, with identical sampling draws."""
        if patch_embeds is not None:
            return self._generate_vision(tokens, steps, patch_embeds, seed)
        tokens = np.asarray(tokens)
        B, S = tokens.shape
        eng = self.step_engine(B)

        t0 = self.telemetry.clock()
        eng.reset(seed=self.seed if seed is None else seed)
        gens = eng.admit(self.params, tokens, max_new=steps)
        jax.block_until_ready(eng.state.tok)
        self.stats.prefill_s += self.telemetry.clock() - t0

        t0 = self.telemetry.clock()
        while eng.live_slots():
            eng.step(self.params)
        jax.block_until_ready(eng.state.tok)
        self.stats.decode_s += self.telemetry.clock() - t0
        self.stats.tokens += B * steps
        return np.stack([np.asarray(g.tokens, np.int32) for g in gens])

    def _generate_vision(self, tokens, steps: int, patch_embeds,
                         seed: Optional[int]) -> np.ndarray:
        """Vision-frontend path: patch embeds prefill with the prompt and
        shift every position by n_patch; decode runs the legacy loop."""
        B, S = tokens.shape
        t0 = self.telemetry.clock()
        logits, caches = self._prefill(self.params, tokens, patch_embeds)
        n_patch = patch_embeds.shape[1]
        key = self._key(seed)
        tok = _sample(logits[:, -1], key, self.temperature)[:, None]
        jax.block_until_ready(tok)
        self.stats.prefill_s += self.telemetry.clock() - t0

        out = [np.asarray(tok)]
        t0 = self.telemetry.clock()
        pos = S + n_patch
        for i in range(steps - 1):
            key = jax.random.fold_in(key, i)
            tok, caches = self._step(self.params, caches, tok,
                                     jnp.int32(pos), key)
            out.append(np.asarray(tok))
            pos += 1
        jax.block_until_ready(tok)
        self.stats.decode_s += self.telemetry.clock() - t0
        self.stats.tokens += B * steps
        return np.concatenate(out, axis=1)

    # ------------------------------------------------------------------
    def generate_paged(self, tokens, steps: int,
                       page: int = 256,
                       seed: Optional[int] = None) -> np.ndarray:
        """Paged-cache decode loop — a thin wrapper over
        ``StepEngine(paged=True)``, exactly as ``generate`` wraps the row
        engine: the whole batch is admitted at t=0 into per-slot page
        tables over one shared page pool and stepped to completion.
        Identical outputs to generate() — tested.  (The earlier
        BigKV/ActKV commit-cadence loop lives on in
        ``LM.decode_step_paged`` for the sharded/analysis paths; the
        serving tier now pools pages across requests instead of
        committing per-batch pages in lockstep.)

        Models the page pool cannot express (recurrent/hybrid mixers,
        sliding-window rings) fall back to the row engine: the output
        contract (== ``generate``) is unchanged, only the cache layout
        differs."""
        tokens = np.asarray(tokens)
        B, S = tokens.shape
        page = min(page, self.max_len)
        try:
            self.model._require_paged_support()
        except ValueError:
            return self.generate(tokens, steps, seed=seed)
        eng = self.step_engine(B, paged=True, page_size=page)

        t0 = self.telemetry.clock()
        eng.reset(seed=self.seed if seed is None else seed)
        gens = eng.admit(self.params, tokens, max_new=steps)
        jax.block_until_ready(eng.state.tok)
        self.stats.prefill_s += self.telemetry.clock() - t0

        t0 = self.telemetry.clock()
        while eng.live_slots():
            eng.step(self.params)
        jax.block_until_ready(eng.state.tok)
        self.stats.decode_s += self.telemetry.clock() - t0
        self.stats.tokens += B * steps
        return np.stack([np.asarray(g.tokens, np.int32) for g in gens])

    # ------------------------------------------------------------------
    def generate_fused(self, tokens, steps: int,
                       seed: Optional[int] = None) -> jax.Array:
        """Whole decode loop in one XLA program (benchmark path)."""
        model, T = self.model, self.temperature

        def run(params, tokens, key):
            B, S = tokens.shape
            logits, caches = model.prefill(params, tokens, self.max_len)
            tok = _sample(logits[:, -1], key, T)[:, None]

            def body(carry, i):
                tok, caches, key = carry
                key = jax.random.fold_in(key, i)
                logits, caches = model.decode_step(params, caches, tok, S + i)
                nxt = _sample(logits[:, -1], key, T)[:, None]
                return (nxt, caches, key), tok

            (_, _, _), toks = jax.lax.scan(
                body, (tok, caches, key), jnp.arange(steps))
            return toks[:, :, 0].T                       # (B, steps)

        return jax.jit(run)(self.params, tokens, self._key(seed))


def _sample(logits, key, temperature: float):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)
