"""Unified serving telemetry — public import surface.

The implementation lives in ``repro.core.telemetry`` so that
``repro.core.context`` (which the serving engines import) can use the
same registry/tracer without a package-import cycle through
``repro.serve.__init__``.  Import from here in serving code::

    from repro.serve.telemetry import Telemetry, Tracer, safe_ratio

See docs/observability.md for the metric glossary and span taxonomy.
"""
from repro.core.telemetry import (LATENCY_BUCKETS_S, Histogram, ManualClock,
                                  MetricRegistry, MetricView, Telemetry,
                                  Tracer, safe_ratio)

__all__ = ["LATENCY_BUCKETS_S", "Histogram", "ManualClock", "MetricRegistry",
           "MetricView", "Telemetry", "Tracer", "safe_ratio"]
