"""Host-side slot-pool bookkeeping shared by the serving engines.

``StepEngine`` and ``SpecEngine`` keep the same host-side pool around
their (different) device programs: a fixed bank of ``batch_size`` slots,
a free-list over them, per-slot ``Generation`` handles, retirement back
to the free-list, and the instant-retire key salt.  ``SlotPool`` is that
bookkeeping extracted once, so admission-path changes (validation,
chunked prefill, recycling order) land in one place and every engine
inherits them.

Pool invariants:

  * **FIFO recycling** — slots are taken from the *front* of the
    free-list and retired to the *back*.  The order is load-bearing: the
    admission draw indexes a shared (B, V) gumbel field by slot, so the
    seeded-draw reproducibility tests pin which slot a re-admission
    lands in.  A failed admission restores its slots to the front in
    their original order (``_restore_slots``), making the retry
    indistinguishable from the failed call.
  * **Admission is validated up front** — ``metas`` / ``seeds`` must
    match the prompt row count exactly.  An over-long ``seeds`` list
    used to raise ``IndexError`` deep in the key plumbing, and a short
    ``metas`` list silently mislabeled rows so retirement routed into
    the wrong inflight record.
  * **The device state is the engine's** — this class never touches
    caches or programs; engines that keep a ``.key``/``.t`` NamedTuple
    in ``self.state`` get ``_salt_admit_key`` (the instant-retire salt)
    for free.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.serve.telemetry import Telemetry


@dataclass
class Generation:
    """Host-side handle for one admitted request (one slot row)."""
    rid: int
    prompt_len: int
    max_new: int
    slot: int = -1
    tokens: list = field(default_factory=list)
    done: bool = False
    meta: Any = None                      # scheduler payload (futures etc.)
    pages: Optional[list] = None          # pool pages owned (paged engines);
    #                                       None once released at retirement
    # lifecycle stamps (engine clock), for TTFT / queue-wait / latency
    # histograms and the per-request trace span:
    submitted_at: Optional[float] = None  # scheduler enqueue (if known)
    admitted_at: Optional[float] = None   # slot granted
    first_token_at: Optional[float] = None

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.tokens)


class PagePool:
    """Host-side *refcounted* page allocator over one shared device KV
    page bank.

    The device side is a ``layers.PagedKV`` pool of ``total_pages``
    pages; this class hands out page *ids*.  Page 0 is the PARK page: it
    is never allocated, dead page-table entries point at it (every table
    entry must be a valid pool index for the kernel's prefetch-driven
    DMA), and non-live rows' per-step writes are routed into it — so
    ``allocatable == total_pages - 1``.

    Every allocated page carries a reference count.  ``take`` hands out
    fresh pages at refcount 1; ``acquire`` adds a reference (prefix
    sharing: the same physical page mapped into another table, or held
    by the prefix index); ``release``/``restore`` *decrement*, and a
    page re-enters the free-list only when its count reaches 0.  With
    every page at refcount 1 — the only state that existed before prefix
    sharing — the observable behavior is unchanged, which is what keeps
    the pre-existing reproducibility tests pinned.

    Recycling contract (mirrors ``SlotPool``'s slot free-list, and is
    load-bearing for test reproducibility the same way):

      * **FIFO** — ``take`` pops from the *front*, ``release``
        (retirement) appends pages reaching refcount 0 to the *back*: a
        page is reused as late as possible, and the allocation order of
        a fixed traffic pattern is deterministic.
      * **failed-admit restore** — ``restore`` puts pages reaching
        refcount 0 back at the *front in their original order*, so a
        retried admission draws exactly the pages the failed call drew.
    """

    PARK = 0

    def __init__(self, total_pages: int, telemetry: Telemetry | None = None):
        if total_pages < 2:
            raise ValueError(f"need >= 2 pages (1 park + 1 allocatable), "
                             f"got {total_pages}")
        self.total_pages = total_pages
        self._free: deque[int] = deque(range(1, total_pages))
        self._ref: dict[int, int] = {}   # page id -> refcount (allocated)
        self._tm = telemetry             # optional: free_pages gauge

    def _note_free(self):
        if self._tm is not None:
            self._tm.registry.gauge(
                self._tm.prefix + "free_pages", len(self._free))

    @property
    def allocatable(self) -> int:
        return self.total_pages - 1

    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        """References held on an allocated page (0 == on the free-list)."""
        return self._ref.get(page, 0)

    # Single-shard pools answer the sharded-routing queries trivially so
    # the engine's admission path is uniform over both pool kinds.
    num_shards = 1

    @property
    def per_shard_allocatable(self) -> int:
        return self.allocatable

    def shard_of(self, page: int) -> int:
        return 0

    def shard_free(self, shard: int) -> int:
        return len(self._free)

    def route(self, n: int) -> Optional[int]:
        """Shard a fresh ``n``-page allocation would be routed to
        (``None`` == the pages span shards).  One shard: everything is
        local."""
        return 0

    def blocked(self, n: int, shard: Optional[int] = None) -> Optional[str]:
        """Why ``take(n, shard)`` would fail right now — ``None`` (it
        would not), ``"pages"`` (pool globally short), or
        ``"shard_pages"`` (room exists, but not on the one shard this
        request routes to — sharded pools only)."""
        return None if n <= len(self._free) else "pages"

    def blocked_rows(self, b: int, n: int) -> Optional[str]:
        """Like ``blocked`` for ``b`` independent rows of ``n`` pages
        each, admitted in sequence under the routing policy."""
        return None if b * n <= len(self._free) else "pages"

    def take(self, n: int, shard: Optional[int] = None) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(f"take({n}) with {len(self._free)} free "
                               "pages")
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self._note_free()
        return pages

    def adopt(self, page: int) -> bool:
        """Re-allocate one specific FREE page at refcount 1 — the
        prefix-index restore path: the bank still holds the page's
        bytes, so a surviving trie entry re-pins exactly that page.
        False (and no state change) if the page has been handed out or
        is out of range."""
        try:
            self._free.remove(page)
        except ValueError:
            return False
        self._ref[page] = 1
        self._note_free()
        return True

    def note_reclaimed(self, pages: list[int]):
        """Telemetry hook: pages the engine just reclaimed from the
        prefix cache.  Per-shard pools attribute them to owning shards;
        a single-shard pool has nothing extra to record."""

    def acquire(self, pages: list[int]):
        """Add one reference to each (already-allocated) page — prefix
        sharing maps the same physical page into another table, or the
        prefix index pins it past its owner's retirement."""
        for p in pages:
            if self._ref.get(p, 0) < 1:
                raise ValueError(f"acquire({p}): page is not allocated")
            self._ref[p] += 1

    def _decref(self, pages: list[int]) -> list[int]:
        """Drop one reference per page; -> the pages that hit 0, in the
        order given (those leave ``_ref`` and must rejoin the free-list)."""
        freed = []
        for p in pages:
            n = self._ref.get(p, 0)
            if n < 1:
                raise ValueError(f"refcount underflow on page {p}")
            if n == 1:
                del self._ref[p]
                freed.append(p)
            else:
                self._ref[p] = n - 1
        return freed

    def restore(self, pages: list[int]):
        """Failed admission: drop one reference; pages reaching refcount
        0 go back to the FRONT in original order."""
        self._free.extendleft(reversed(self._decref(pages)))
        self._note_free()

    def release(self, pages: list[int]):
        """Retirement: drop one reference; pages reaching refcount 0 go
        to the BACK (FIFO recycling)."""
        self._free.extend(self._decref(pages))
        self._note_free()

    def reset(self):
        self._free = deque(range(1, self.total_pages))
        self._ref = {}
        self._note_free()


class ShardedPagePool(PagePool):
    """``PagePool`` partitioned into ``num_shards`` equal slices with one
    host-side free-list per shard.

    Page-id encoding: global page ``p`` lives on shard
    ``p // pages_per_shard`` at local index ``p % pages_per_shard`` — a
    page id *is* a (shard, local page) pair, so the device-side table
    stays a plain ``(B, P)`` int32 array and a shard's kernel instance
    recovers its local index by subtracting its base offset.  Local page
    0 of EVERY shard is reserved: shard 0's is the global PARK page
    (id 0), and the other shards' local 0 gives each bank slice a
    resident park target so out-of-slice writes can be routed locally
    without cross-shard traffic.  Hence
    ``allocatable == total_pages - num_shards``.

    Routing policy (deterministic, so randomized fuzz replays exactly):

      * a request that can EVER fit on one shard
        (``n <= per_shard_allocatable``) is placed entirely on one shard
        — callers route prefix-cache hits to the shard already holding
        the cached pages and cold admissions to the least-loaded shard
        (most free pages, ties to the lowest shard index);
      * a bigger request *spans*: pages are drawn one at a time from
        whichever shard is most-free at that moment (same tie-break).

    Refcounts are global (a page's identity does not change);
    ``release``/``restore`` return a freed page to its OWNING shard's
    free-list with the same FIFO/front-restore contract as the base
    class, so per-shard allocation order is deterministic too.
    """

    def __init__(self, total_pages: int, num_shards: int,
                 telemetry: Telemetry | None = None):
        if num_shards < 1:
            raise ValueError(f"need >= 1 shard, got {num_shards}")
        if total_pages % num_shards:
            raise ValueError(f"total_pages {total_pages} must divide by "
                             f"num_shards {num_shards}")
        per = total_pages // num_shards
        if per < 2:
            raise ValueError(f"each shard needs its reserved local page 0 "
                             f"plus >= 1 allocatable page; {total_pages} "
                             f"pages over {num_shards} shards gives {per}")
        self.total_pages = total_pages
        self.num_shards = num_shards
        self.pages_per_shard = per
        self._shards: list[deque[int]] = [
            deque(range(s * per + 1, (s + 1) * per))
            for s in range(num_shards)]
        self._ref: dict[int, int] = {}
        self._tm = telemetry
        self._note_free()

    # `_free` stays undefined on purpose: every base-class method that
    # touched it is overridden, and an attribute error beats silently
    # mutating a stale combined view.

    def _note_free(self):
        if self._tm is None:
            return
        reg, pre = self._tm.registry, self._tm.prefix
        reg.gauge(pre + "free_pages", self.free_pages())
        for s, dq in enumerate(self._shards):
            reg.gauge(f"{pre}shard.{s}.free_pages", len(dq))

    def _note_admitted(self, shard: int, n: int):
        if self._tm is not None and n:
            self._tm.registry.inc(
                f"{self._tm.prefix}shard.{shard}.admitted_pages", n)

    @property
    def allocatable(self) -> int:
        return self.total_pages - self.num_shards

    @property
    def per_shard_allocatable(self) -> int:
        return self.pages_per_shard - 1

    def free_pages(self) -> int:
        return sum(len(dq) for dq in self._shards)

    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    def shard_free(self, shard: int) -> int:
        return len(self._shards[shard])

    def least_loaded(self) -> int:
        """Shard with the most free pages; ties go to the lowest index
        (the determinism the replay fuzz pins)."""
        return max(range(self.num_shards),
                   key=lambda s: (len(self._shards[s]), -s))

    def route(self, n: int) -> Optional[int]:
        if n > self.per_shard_allocatable:
            return None                     # can never fit on one shard
        return self.least_loaded()

    def blocked(self, n: int, shard: Optional[int] = None) -> Optional[str]:
        if shard is None or n > self.per_shard_allocatable:
            shard = self.route(n)           # may still be None (spanning)
        if shard is None:
            return None if n <= self.free_pages() else "pages"
        if n <= len(self._shards[shard]):
            return None
        return "shard_pages" if n <= self.free_pages() else "pages"

    def blocked_rows(self, b: int, n: int) -> Optional[str]:
        """Simulate admitting ``b`` rows of ``n`` pages each through the
        routing policy (each row routed independently, exactly as ``b``
        sequential ``take(n)`` calls would be) without touching state."""
        counts = [len(dq) for dq in self._shards]
        span = n > self.per_shard_allocatable
        for _ in range(b):
            if span:
                if n > sum(counts):
                    return "pages"
                for _ in range(n):      # spanning pops most-free first
                    s = max(range(self.num_shards),
                            key=lambda i: (counts[i], -i))
                    counts[s] -= 1
            else:
                s = max(range(self.num_shards),
                        key=lambda i: (counts[i], -i))
                if n > counts[s]:
                    return ("shard_pages" if n <= sum(counts) else "pages")
                counts[s] -= n
        return None

    def take(self, n: int, shard: Optional[int] = None) -> list[int]:
        if shard is None or n > self.per_shard_allocatable:
            shard = self.route(n)
        if shard is None:
            return self._take_spanning(n)
        dq = self._shards[shard]
        if n > len(dq):
            raise RuntimeError(f"take({n}) with {len(dq)} free pages on "
                               f"routed shard {shard}")
        pages = [dq.popleft() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self._note_admitted(shard, n)
        self._note_free()
        return pages

    def _take_spanning(self, n: int) -> list[int]:
        if n > self.free_pages():
            raise RuntimeError(f"take({n}) with {self.free_pages()} free "
                               "pages")
        pages, counts = [], [0] * self.num_shards
        for _ in range(n):
            s = self.least_loaded()
            p = self._shards[s].popleft()
            self._ref[p] = 1
            counts[s] += 1
            pages.append(p)
        for s, c in enumerate(counts):
            self._note_admitted(s, c)
        self._note_free()
        return pages

    def restore(self, pages: list[int]):
        freed = self._decref(pages)
        for s in range(self.num_shards):
            own = [p for p in freed if self.shard_of(p) == s]
            if own:
                self._shards[s].extendleft(reversed(own))
        self._note_free()

    def release(self, pages: list[int]):
        for p in self._decref(pages):
            self._shards[self.shard_of(p)].append(p)
        self._note_free()

    def adopt(self, page: int) -> bool:
        try:
            self._shards[self.shard_of(page)].remove(page)
        except (ValueError, IndexError):
            return False
        self._ref[page] = 1
        self._note_free()
        return True

    def note_reclaimed(self, pages: list[int]):
        if self._tm is None or not pages:
            return
        counts: dict[int, int] = {}
        for p in pages:
            s = self.shard_of(p)
            counts[s] = counts.get(s, 0) + 1
        for s, c in counts.items():
            self._tm.registry.inc(
                f"{self._tm.prefix}shard.{s}.reclaimed_pages", c)

    def reset(self):
        per = self.pages_per_shard
        self._shards = [deque(range(s * per + 1, (s + 1) * per))
                        for s in range(self.num_shards)]
        self._ref = {}
        self._note_free()


@dataclass
class _PrefixNode:
    """One cached prompt page: the edge from its parent is the page's
    full token run, ``page`` is the pool page holding those tokens'
    k/v."""
    page: int
    run: tuple
    parent: Optional["_PrefixNode"]
    children: dict = field(default_factory=dict)   # run tuple -> node
    last_used: int = 0


class PrefixIndex:
    """Radix / longest-common-prefix index over *fully written* prompt
    pages.

    Granularity is whole pages: an edge is one page's complete
    ``page_size``-token run, so a lookup matches the longest indexed
    prefix in units of pages and nothing finer.  A page is only inserted
    once its owner has completely written it (the last, partially-filled
    prompt page never enters; decode tokens land past the prompt so an
    indexed page is immutable for the rest of its life).  ``namespace``
    keys the bank's value format into every path — an int8 bank's codes
    are a lossy function of the same source tokens, so fp16 and int8
    entries must never cross-match even if an index were shared.

    The index itself holds no refcounts: the engine pairs ``insert``
    with ``PagePool.acquire`` (the index's reference) and ``evict_lru``
    with ``PagePool.release``.  Eviction is leaf-first — an inner node's
    children are only reachable through it — and LRU within the leaves,
    the same recency ranking ``ReconfigPolicy`` uses for context slots.
    """

    def __init__(self, page_size: int, namespace: str = "fp16"):
        self.page_size = page_size
        self.namespace = namespace
        self._root: dict = {}            # (namespace, run) -> _PrefixNode
        self._clock = 0                  # monotonic recency counter

    def __len__(self) -> int:
        return len(self.pages())

    def _runs(self, tokens) -> list[tuple]:
        toks = np.asarray(tokens).reshape(-1)
        ps = self.page_size
        return [tuple(int(x) for x in toks[j * ps:(j + 1) * ps])
                for j in range(len(toks) // ps)]

    def _key(self, node: Optional[_PrefixNode], run: tuple):
        return (self.namespace, run) if node is None else run

    def _children(self, node: Optional[_PrefixNode]) -> dict:
        return self._root if node is None else node.children

    def lookup(self, tokens, peek: bool = False) -> list[int]:
        """Longest indexed prefix of ``tokens`` in WHOLE pages -> the
        page ids holding it (possibly []).  Bumps recency on the path;
        ``peek`` leaves recency untouched — capacity probes
        (``can_admit``) must not keep never-admitted prefixes hot or
        double-bump the path their ``admit`` bumps again."""
        if not peek:
            self._clock += 1
        node, out = None, []
        for run in self._runs(tokens):
            nxt = self._children(node).get(self._key(node, run))
            if nxt is None:
                break
            if not peek:
                nxt.last_used = self._clock
            out.append(nxt.page)
            node = nxt
        return out

    def insert(self, tokens, pages: list[int]) -> list[int]:
        """Index one admitted row's fully-written prompt pages:
        ``pages[j]`` holds tokens ``[j*page_size, (j+1)*page_size)``.
        Runs already indexed keep their existing page (first writer
        wins); -> the page ids NEWLY inserted, for which the caller must
        ``PagePool.acquire`` the index's reference."""
        self._clock += 1
        node, fresh = None, []
        for j, run in enumerate(self._runs(tokens)):
            if j >= len(pages):
                break
            key = self._key(node, run)
            kids = self._children(node)
            nxt = kids.get(key)
            if nxt is None:
                nxt = _PrefixNode(page=int(pages[j]), run=run, parent=node,
                                  last_used=self._clock)
                kids[key] = nxt
                fresh.append(nxt.page)
            else:
                nxt.last_used = self._clock
            node = nxt
        return fresh

    def _nodes(self) -> list[_PrefixNode]:
        out, stack = [], list(self._root.values())
        while stack:
            nd = stack.pop()
            out.append(nd)
            stack.extend(nd.children.values())
        return out

    def pages(self) -> set[int]:
        """Every page id the index currently pins."""
        return {nd.page for nd in self._nodes()}

    def evict_lru(self, n: int, can_evict) -> list[int]:
        """Drop up to ``n`` cached pages, least-recently-used *leaves*
        first (``ReconfigPolicy``-style recency ranking; an inner node
        cannot go before its children or the subtree leaks).  Only pages
        ``can_evict`` approves leave — the engine passes refcount == 1,
        i.e. no live table still maps the page.  -> the evicted page
        ids; the caller drops the index's pool reference for each."""
        out = []
        while len(out) < n:
            leaves = [nd for nd in self._nodes()
                      if not nd.children and can_evict(nd.page)]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: (nd.last_used, nd.page))
            kids = self._children(victim.parent)
            del kids[self._key(victim.parent, victim.run)]
            out.append(victim.page)
        return out

    def clear(self):
        self._root = {}

    def snapshot(self) -> dict:
        """Serializable host state of the trie (plain lists/ints, JSON-
        safe).  The pages themselves live in the device bank and are NOT
        captured — a snapshot is only worth restoring while the bank's
        bytes survive (engine reset reuses the cache arrays; the pool
        free-list is host state that ``restore`` re-pins from)."""
        nodes = []

        def walk(node, path):
            for nd in self._children(node).values():
                rec_path = path + [list(nd.run)]
                nodes.append({"path": rec_path, "page": int(nd.page),
                              "last_used": int(nd.last_used)})
                walk(nd, rec_path)

        walk(None, [])
        return {"namespace": self.namespace, "page_size": self.page_size,
                "clock": int(self._clock), "nodes": nodes}

    def restore(self, snap: dict, adopt) -> list[int]:
        """Rebuild trie branches from a ``snapshot`` taken earlier.

        ``adopt(page) -> bool`` must re-pin the page in the pool (the
        index's reference) — ``PagePool.adopt`` exactly.  A node whose
        page cannot be adopted (recycled since the snapshot) is dropped
        *with its whole subtree*: the children's token runs are only
        reachable through the lost page, so keeping them would serve
        k/v for tokens the table no longer maps.  Existing entries win
        over snapshot entries (first writer wins, as in ``insert``).
        Returns the pages adopted; the caller owns nothing — the index
        now pins them."""
        if (snap["namespace"] != self.namespace
                or snap["page_size"] != self.page_size):
            raise ValueError(
                f"snapshot is {snap['namespace']}/page {snap['page_size']}, "
                f"index is {self.namespace}/page {self.page_size}")
        self._clock = max(self._clock, int(snap["clock"]))
        adopted = []
        # snapshot() emits parents before children, so one forward pass
        # sees every node's parent already rebuilt (or already dropped).
        for rec in snap["nodes"]:
            path = [tuple(r) for r in rec["path"]]
            node, lost = None, False
            for run in path[:-1]:
                node = self._children(node).get(self._key(node, run))
                if node is None:
                    lost = True             # parent branch was dropped
                    break
            if lost:
                continue
            run = path[-1]
            kids = self._children(node)
            key = self._key(node, run)
            if key in kids:
                continue
            if not adopt(rec["page"]):
                continue
            kids[key] = _PrefixNode(page=int(rec["page"]), run=run,
                                    parent=node,
                                    last_used=int(rec["last_used"]))
            adopted.append(int(rec["page"]))
        return adopted


@dataclass
class SharedBank:
    """One shared paged-KV bank: the allocator, the prefix index, and the
    device cache pytree, shared by every engine serving the same context
    content.

    Keyed by *bank content* — (context name, page size, kv format) — not
    by pool shape: a batch-8 plain engine, a batch-2 engine, and a
    speculative target column over the same weights all read/write the
    same pages, so a prompt one of them indexed is a prefix hit for all
    of them.  ``caches`` starts ``None``; the first engine to reset
    populates it.  Engines must re-read ``caches`` at every public entry
    point and write it back after device calls: jitted programs donate
    the buffers, so any reference held across another engine's call is
    stale."""
    pool: PagePool
    index: Optional[PrefixIndex] = None
    caches: Any = None


class SlotPool:
    """Mixin: host-side slot pool for a fixed-shape device batch.

    Subclasses call ``_pool_init`` once and ``_pool_reset`` from their
    ``reset``; they own the device state and the jitted programs.
    """

    eos_id: Optional[int] = None

    def _pool_init(self, batch_size: int, telemetry: Telemetry | None = None):
        self.batch_size = batch_size
        self.slots: list[Optional[Generation]] = [None] * batch_size
        self._free: deque[int] = deque(range(batch_size))
        self._live = np.zeros(batch_size, dtype=bool)
        self._rid = 0
        # Shared measurement layer: ``self.stats`` is a dict-shaped view
        # over the server-wide MetricRegistry (standalone engines get a
        # private one), keeping every existing ``stats["key"]`` call-site
        # while snapshots/benches read one store.  A server hands each
        # engine a scoped ``eng.<i>.`` namespace.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._trace = self.telemetry.tracer
        # Engine-lifetime tick counters (NOT cleared by ``reset``;
        # benches take deltas): ``host_ticks`` counts decode round-trips
        # to the device, ``device_steps`` the decode steps those trips
        # retired — their ratio is the multi-step amortization.
        # Engines with richer accounting (SpecEngine) extend this.
        self.stats = self.telemetry.view()
        self.stats.update({"host_ticks": 0, "device_steps": 0,
                           "admitted_rows": 0, "retired_rows": 0,
                           "tokens_out": 0})
        # inter-commit gap tracking for the decode-stall histogram
        # (engine-lifetime, like the tick counters above)
        self._last_commit_at: Optional[float] = None

    def _pool_reset(self):
        self.slots = [None] * self.batch_size
        self._free = deque(range(self.batch_size))
        self._live[:] = False

    # -------------------------------------------------------------- queries
    def free_slots(self) -> int:
        return len(self._free)

    def live_slots(self) -> int:
        """Occupied slots: live decode rows plus rows still mid-prefill
        (both hold a slot and both are pending work)."""
        return self.batch_size - len(self._free)

    def pending_slots(self) -> int:
        """Slots reserved but still mid-prefill (chunked admission)."""
        return 0

    def live(self) -> list[Generation]:
        return [g for g in self.slots if g is not None]

    # Why the last ``can_admit`` said no: ``None`` (it said yes),
    # ``"slots"``, ``"pages"``, or ``"shard_pages"`` (sharded pools:
    # room exists, just not on the shard the request routes to).
    # Schedulers read this to attribute blocked admissions.
    last_admit_block: Optional[str] = None

    def can_admit(self, tokens, max_new: int) -> bool:
        """Whether ``admit(tokens, max_new)`` would fit *right now*.
        Schedulers gate on this instead of ``free_slots`` so engines
        with extra admission resources (the paged engine's page pool)
        can veto without raising."""
        b = 1 if np.ndim(tokens) == 1 else np.shape(tokens)[0]
        ok = b <= self.free_slots()
        self.last_admit_block = None if ok else "slots"
        return ok

    # ------------------------------------------------------------ admission
    def _admit_args(self, tokens, metas, seeds):
        """Validate + normalize admission arguments.

        Returns ``(tokens (b, S) int32, rkeys (b, 2) uint32, seeded (b,)
        bool)``.  ``seeds`` entries may be ``None`` (pool schedule), an
        int seed, or a raw (2,) uint32 key.
        """
        tokens = np.asarray(tokens)
        if tokens.ndim == 1:
            tokens = tokens[None]
        b, S = tokens.shape
        if metas is not None and len(metas) != b:
            raise ValueError(f"metas has {len(metas)} entries for {b} "
                             "prompt rows")
        if seeds is not None and len(seeds) != b:
            raise ValueError(f"seeds has {len(seeds)} entries for {b} "
                             "prompt rows")
        rkeys = np.zeros((b, 2), np.uint32)
        seeded = np.zeros((b,), bool)
        for i, s in enumerate(seeds or []):
            if s is None:
                continue
            rkeys[i] = np.asarray(s if hasattr(s, "shape") and
                                  np.shape(s) == (2,)
                                  else jax.random.PRNGKey(int(s)))
            seeded[i] = True
        return tokens, rkeys, seeded

    def _take_slots(self, b: int) -> list[int]:
        if b > len(self._free):
            raise RuntimeError(f"admit({b}) with {len(self._free)} free "
                               "slots")
        return [self._free.popleft() for _ in range(b)]

    def _restore_slots(self, slots: list[int]):
        """Failed admission: the slots go back to the FRONT in their
        original order, so a retry draws exactly what the failed call
        drew (FIFO order is load-bearing — see the class docstring)."""
        self._free.extendleft(reversed(slots))

    def _register(self, slots: list[int], prompt_len: int, max_new: int,
                  metas, first=None, submitted_at=None) -> list[Generation]:
        """Create one ``Generation`` per slot.  With ``first`` (the
        sampled first tokens) the rows go live; without it they are
        reserved-but-pending (chunked admission fills them later).
        ``submitted_at`` (scheduler enqueue time, engine clock) feeds the
        queue-wait and TTFT histograms."""
        now = self.telemetry.clock()
        gens = []
        for i, s in enumerate(slots):
            g = Generation(rid=self._rid, prompt_len=prompt_len,
                           max_new=max_new, slot=s,
                           meta=metas[i] if metas else None,
                           submitted_at=submitted_at, admitted_at=now)
            self._rid += 1
            self.stats["admitted_rows"] += 1
            if submitted_at is not None:
                self.telemetry.observe("queue_wait_s", now - submitted_at)
            if first is not None:
                g.tokens.append(int(first[i]))
                self._live[s] = True
                self.stats["tokens_out"] += 1
                self._note_first_token(g, now)
            self.slots[s] = g
            gens.append(g)
        return gens

    def _note_first_token(self, g: Generation, now: Optional[float] = None):
        """Stamp a row's first emitted token; observes TTFT (relative to
        scheduler submit when known, else to admission)."""
        if g.first_token_at is not None:
            return
        if now is None:
            now = self.telemetry.clock()
        g.first_token_at = now
        ref = g.submitted_at if g.submitted_at is not None else g.admitted_at
        self.telemetry.observe("ttft_s", now - ref)
        if self._trace.enabled:
            self._trace.instant(
                f"first-token:{g.rid}",
                f"{self.telemetry.prefix}pool{g.slot}", ts=now)

    def _note_tick(self, t0: float, now: float, nsteps: int, nrows: int):
        """Per-tick telemetry: the per-token latency sample (tick
        duration amortized over the decode steps it committed), the
        host-side inter-commit stall (gap between the previous tick's
        commit and this tick's start — scheduler/bookkeeping overhead),
        and the tick span."""
        if nrows and nsteps:
            self.telemetry.observe("token_latency_s", (now - t0) / nsteps)
        last = self._last_commit_at
        if last is not None and t0 > last:
            self.telemetry.observe("decode_stall_s", t0 - last)
        self._last_commit_at = now
        if self._trace.enabled:
            self._trace.span("tick", f"{self.telemetry.prefix}eng",
                             t0, now, args={"steps": nsteps, "rows": nrows})

    # ----------------------------------------------------------- retirement
    def _retire_done(self, gens: list[Generation]) -> list[Generation]:
        finished = []
        now = None
        for g in gens:
            eos = (self.eos_id is not None and g.tokens
                   and g.tokens[-1] == self.eos_id)
            if len(g.tokens) >= g.max_new or eos:
                g.done = True
                self.slots[g.slot] = None
                self._live[g.slot] = False
                self._free.append(g.slot)
                finished.append(g)
                if now is None:
                    now = self.telemetry.clock()
                self.stats["retired_rows"] += 1
                self.telemetry.observe("gen_latency_s", now - g.admitted_at)
                if self._trace.enabled:
                    # one span per request on its slot's track:
                    # admitted -> retired (Perfetto: slot occupancy).
                    self._trace.span(
                        f"req:{g.rid}",
                        f"{self.telemetry.prefix}pool{g.slot}",
                        g.admitted_at, now,
                        args={"tokens": len(g.tokens),
                              "prompt_len": g.prompt_len, "eos": bool(eos)})
        return finished

    def _salt_admit_key(self):
        """Advance the engine's admission key after an instant retire: a
        slot freed with no step in between (steps==1 / EOS at admission)
        must not hand a same-boundary re-admission the draw field the
        retiree already used.  The salt lives above 2^30, disjoint from
        the step/round folds (which use small ``t``)."""
        self.state = self.state._replace(key=jax.random.fold_in(
            self.state.key, (1 << 30) | int(self.state.t)))

    # ----------------------------------------------------------------- loop
    def drain(self, params=None) -> list[Generation]:
        """Step until the pool is empty; returns everything finished."""
        out = []
        while self.live_slots():
            out.extend(self.step(params))
        return out
