"""Host-side slot-pool bookkeeping shared by the serving engines.

``StepEngine`` and ``SpecEngine`` keep the same host-side pool around
their (different) device programs: a fixed bank of ``batch_size`` slots,
a free-list over them, per-slot ``Generation`` handles, retirement back
to the free-list, and the instant-retire key salt.  ``SlotPool`` is that
bookkeeping extracted once, so admission-path changes (validation,
chunked prefill, recycling order) land in one place and every engine
inherits them.

Pool invariants:

  * **FIFO recycling** — slots are taken from the *front* of the
    free-list and retired to the *back*.  The order is load-bearing: the
    admission draw indexes a shared (B, V) gumbel field by slot, so the
    seeded-draw reproducibility tests pin which slot a re-admission
    lands in.  A failed admission restores its slots to the front in
    their original order (``_restore_slots``), making the retry
    indistinguishable from the failed call.
  * **Admission is validated up front** — ``metas`` / ``seeds`` must
    match the prompt row count exactly.  An over-long ``seeds`` list
    used to raise ``IndexError`` deep in the key plumbing, and a short
    ``metas`` list silently mislabeled rows so retirement routed into
    the wrong inflight record.
  * **The device state is the engine's** — this class never touches
    caches or programs; engines that keep a ``.key``/``.t`` NamedTuple
    in ``self.state`` get ``_salt_admit_key`` (the instant-retire salt)
    for free.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np


@dataclass
class Generation:
    """Host-side handle for one admitted request (one slot row)."""
    rid: int
    prompt_len: int
    max_new: int
    slot: int = -1
    tokens: list = field(default_factory=list)
    done: bool = False
    meta: Any = None                      # scheduler payload (futures etc.)

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.tokens)


class SlotPool:
    """Mixin: host-side slot pool for a fixed-shape device batch.

    Subclasses call ``_pool_init`` once and ``_pool_reset`` from their
    ``reset``; they own the device state and the jitted programs.
    """

    eos_id: Optional[int] = None

    def _pool_init(self, batch_size: int):
        self.batch_size = batch_size
        self.slots: list[Optional[Generation]] = [None] * batch_size
        self._free: deque[int] = deque(range(batch_size))
        self._live = np.zeros(batch_size, dtype=bool)
        self._rid = 0

    def _pool_reset(self):
        self.slots = [None] * self.batch_size
        self._free = deque(range(self.batch_size))
        self._live[:] = False

    # -------------------------------------------------------------- queries
    def free_slots(self) -> int:
        return len(self._free)

    def live_slots(self) -> int:
        """Occupied slots: live decode rows plus rows still mid-prefill
        (both hold a slot and both are pending work)."""
        return self.batch_size - len(self._free)

    def pending_slots(self) -> int:
        """Slots reserved but still mid-prefill (chunked admission)."""
        return 0

    def live(self) -> list[Generation]:
        return [g for g in self.slots if g is not None]

    # ------------------------------------------------------------ admission
    def _admit_args(self, tokens, metas, seeds):
        """Validate + normalize admission arguments.

        Returns ``(tokens (b, S) int32, rkeys (b, 2) uint32, seeded (b,)
        bool)``.  ``seeds`` entries may be ``None`` (pool schedule), an
        int seed, or a raw (2,) uint32 key.
        """
        tokens = np.asarray(tokens)
        if tokens.ndim == 1:
            tokens = tokens[None]
        b, S = tokens.shape
        if metas is not None and len(metas) != b:
            raise ValueError(f"metas has {len(metas)} entries for {b} "
                             "prompt rows")
        if seeds is not None and len(seeds) != b:
            raise ValueError(f"seeds has {len(seeds)} entries for {b} "
                             "prompt rows")
        rkeys = np.zeros((b, 2), np.uint32)
        seeded = np.zeros((b,), bool)
        for i, s in enumerate(seeds or []):
            if s is None:
                continue
            rkeys[i] = np.asarray(s if hasattr(s, "shape") and
                                  np.shape(s) == (2,)
                                  else jax.random.PRNGKey(int(s)))
            seeded[i] = True
        return tokens, rkeys, seeded

    def _take_slots(self, b: int) -> list[int]:
        if b > len(self._free):
            raise RuntimeError(f"admit({b}) with {len(self._free)} free "
                               "slots")
        return [self._free.popleft() for _ in range(b)]

    def _restore_slots(self, slots: list[int]):
        """Failed admission: the slots go back to the FRONT in their
        original order, so a retry draws exactly what the failed call
        drew (FIFO order is load-bearing — see the class docstring)."""
        self._free.extendleft(reversed(slots))

    def _register(self, slots: list[int], prompt_len: int, max_new: int,
                  metas, first=None) -> list[Generation]:
        """Create one ``Generation`` per slot.  With ``first`` (the
        sampled first tokens) the rows go live; without it they are
        reserved-but-pending (chunked admission fills them later)."""
        gens = []
        for i, s in enumerate(slots):
            g = Generation(rid=self._rid, prompt_len=prompt_len,
                           max_new=max_new, slot=s,
                           meta=metas[i] if metas else None)
            self._rid += 1
            if first is not None:
                g.tokens.append(int(first[i]))
                self._live[s] = True
            self.slots[s] = g
            gens.append(g)
        return gens

    # ----------------------------------------------------------- retirement
    def _retire_done(self, gens: list[Generation]) -> list[Generation]:
        finished = []
        for g in gens:
            eos = (self.eos_id is not None and g.tokens
                   and g.tokens[-1] == self.eos_id)
            if len(g.tokens) >= g.max_new or eos:
                g.done = True
                self.slots[g.slot] = None
                self._live[g.slot] = False
                self._free.append(g.slot)
                finished.append(g)
        return finished

    def _salt_admit_key(self):
        """Advance the engine's admission key after an instant retire: a
        slot freed with no step in between (steps==1 / EOS at admission)
        must not hand a same-boundary re-admission the draw field the
        retiree already used.  The salt lives above 2^30, disjoint from
        the step/round folds (which use small ``t``)."""
        self.state = self.state._replace(key=jax.random.fold_in(
            self.state.key, (1 << 30) | int(self.state.t)))

    # ----------------------------------------------------------------- loop
    def drain(self, params=None) -> list[Generation]:
        """Step until the pool is empty; returns everything finished."""
        out = []
        while self.live_slots():
            out.extend(self.step(params))
        return out
