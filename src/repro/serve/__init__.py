from repro.serve.engine import ServingEngine
from repro.serve.switching import SwitchableServer, ServedModel
from repro.serve.scheduler import SwitchScheduler
