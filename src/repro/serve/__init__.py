from repro.serve.engine import ServingEngine
from repro.serve.switching import SwitchableServer, ServedModel
