from repro.serve.engine import ServingEngine, StepEngine
from repro.serve.switching import SwitchableServer, ServedModel
from repro.serve.scheduler import ContinuousScheduler, SwitchScheduler
