"""Mamba-style selective SSM block (jamba's non-attention layers).

Reference path: ``lax.scan`` over time (exact).  A chunked associative-scan
variant (``ssm_scan_assoc``) is the parallel form used for long prefill and is
what the Pallas kernel (`repro.kernels.ssm_scan`) implements on TPU.

State for decode: conv ring (B, d_in, d_conv-1) + ssm state (B, d_in, N) f32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import PSpec


class SSMState(NamedTuple):
    conv: jax.Array     # (B, d_in, d_conv-1) last inputs for the causal conv
    ssm: jax.Array      # (B, d_in, N) f32 recurrent state


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, s.d_state, s.d_conv


def ssm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in, dt_rank, N, K = _dims(cfg)
    return {
        "in_proj": PSpec((d, 2 * d_in), ("embed", "ssm_inner")),
        "conv_w": PSpec((K, d_in), ("conv_width", "ssm_inner"),
                        init="scaled", scale=0.1),
        "conv_b": PSpec((d_in,), ("ssm_inner",), init="zeros"),
        "x_proj": PSpec((d_in, dt_rank + 2 * N), ("ssm_inner", None)),
        "dt_proj": PSpec((dt_rank, d_in), (None, "ssm_inner")),
        "dt_bias": PSpec((d_in,), ("ssm_inner",), init="zeros"),
        "A_log": PSpec((d_in, N), ("ssm_inner", "ssm_state"), init="zeros"),
        "D": PSpec((d_in,), ("ssm_inner",), init="ones"),
        "out_proj": PSpec((d_in, d), ("ssm_inner", "embed")),
    }


def _conv1d_causal(x, w, b, state=None):
    """x: (B, L, d_in); w: (K, d_in) depthwise.  Optional carry-in state
    (B, d_in, K-1) of previous inputs; returns (y, new_state)."""
    B, L, D = x.shape
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, K - 1, D), x.dtype)
    else:
        pad = state.swapaxes(1, 2).astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, L+K-1, D)
    y = sum(xp[:, i:i + L] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):].swapaxes(1, 2)             # (B, D, K-1)
    return y, new_state


def _ssm_inputs(params, x, cfg: ArchConfig):
    """Shared front half: projections, conv, dt/B/C computation."""
    d_in, dt_rank, N, K = _dims(cfg)
    dt_bc = x @ params["x_proj"].astype(x.dtype)            # (B, L, R+2N)
    dt, Bm, Cm = jnp.split(dt_bc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(x.dtype)
                         + params["dt_bias"].astype(x.dtype))   # (B, L, d_in)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))       # (d_in, N)
    return dt.astype(jnp.float32), Bm.astype(jnp.float32), \
        Cm.astype(jnp.float32), A


def _selective_scan_ref(u, dt, Bm, Cm, A, D, init_state=None):
    """u: (B, L, d_in) f32; dt: (B, L, d_in); Bm/Cm: (B, L, N); A: (d_in, N).

    Exact sequential scan (the oracle).  Returns y (B, L, d_in) and the final
    state (B, d_in, N).
    """
    B, L, d_in = u.shape
    N = A.shape[1]
    s0 = jnp.zeros((B, d_in, N), jnp.float32) if init_state is None \
        else init_state

    def step(s, t):
        # discretize inside the body: per-step temps are (B, d_in, N) only
        u_t, dt_t, B_t, C_t = t
        dA_t = jnp.exp(dt_t[..., None] * A)                 # (B, d_in, N)
        dBu_t = dt_t[..., None] * B_t[:, None, :] * u_t[..., None]
        s = dA_t * s + dBu_t
        y = jnp.einsum("bdn,bn->bd", s, C_t)
        return s, y

    xs = (u.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = ys.swapaxes(0, 1) + u * D                           # (B, L, d_in)
    return y, s_fin


def ssm_scan_assoc(u, dt, Bm, Cm, A, D, init_state=None):
    """Parallel form via associative scan over (a, b): s_t = a_t s_{t-1} + b_t."""
    dA = jnp.exp(dt[..., None] * A)                         # (B, L, d, N)
    dBu = dt[..., None] * Bm[:, :, None, :] * u[..., None]
    if init_state is not None:
        dBu = dBu.at[:, 0].add(dA[:, 0] * init_state)

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a, b = jax.lax.associative_scan(comb, (dA, dBu), axis=1)
    y = jnp.einsum("bldn,bln->bld", b, Cm) + u * D
    return y, b[:, -1]


def mamba_forward(params, x, cfg: ArchConfig, mode: str = "scan",
                  state: SSMState | None = None):
    """x: (B, L, D) -> (y, final SSMState).  mode: scan | assoc."""
    d_in, dt_rank, N, K = _dims(cfg)
    xz = x @ params["in_proj"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)                        # (B, L, d_in) x2
    u, conv_state = _conv1d_causal(u, params["conv_w"].astype(x.dtype),
                                   params["conv_b"].astype(x.dtype),
                                   None if state is None else state.conv)
    u = jax.nn.silu(u)
    dt, Bm, Cm, A = _ssm_inputs(params, u, cfg)
    import repro.kernels as kernels
    if kernels.use_kernels() and x.shape[1] > 1:
        from repro.kernels.ssm_scan.ops import selective_scan
        interp = None if kernels.get_mode() == "auto" else True
        scan = lambda *a: selective_scan(*a, interpret=interp)
    else:
        scan = _selective_scan_ref if mode == "scan" else ssm_scan_assoc
    y, s_fin = scan(u.astype(jnp.float32), dt, Bm, Cm, A,
                    params["D"].astype(jnp.float32),
                    None if state is None else state.ssm)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = y @ params["out_proj"].astype(x.dtype)
    return out, SSMState(conv=conv_state, ssm=s_fin)


def mamba_decode(params, x, state: SSMState, cfg: ArchConfig):
    """One-token decode: x (B, 1, D) with carried state."""
    return mamba_forward(params, x, cfg, mode="scan", state=state)


def init_ssm_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> SSMState:
    d_in, _, N, K = _dims(cfg)
    return SSMState(conv=jnp.zeros((batch, d_in, K - 1), dtype),
                    ssm=jnp.zeros((batch, d_in, N), jnp.float32))


def ssm_state_abstract(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    d_in, _, N, K = _dims(cfg)
    return SSMState(conv=jax.ShapeDtypeStruct((batch, d_in, K - 1), dtype),
                    ssm=jax.ShapeDtypeStruct((batch, d_in, N), jnp.float32))


SSM_LOGICAL = SSMState(conv=("kv_batch", "ssm_inner", "conv_width"),
                       ssm=("kv_batch", "ssm_inner", "ssm_state"))
