from repro.models.model import LM, build_model
from repro.models.common import (
    PSpec, init_params, logical_tree, abstract_params, count_params,
)
