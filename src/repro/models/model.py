"""Unified LM assembly for all assigned architecture families.

Every architecture is a *period* of block types repeated ``num_layers /
period`` times (dense: period 1; jamba: period 8; xlstm: period 4).  The
repeat dimension is ``lax.scan``-ned with stacked params, which keeps the HLO
size independent of depth (critical for the 94-layer dry-runs).

Execution modes:
  * ``forward``      — training forward, logits over the full sequence
  * ``prefill``      — builds the decode cache, returns last-position logits
  * ``decode_step``  — one token against the cache (``serve_step`` lowers this)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.distributed.sharding import (ShardingRules, DEFAULT_RULES,
                                        constrain, spec_for)
from repro.models import layers, moe as moe_mod, ssm as ssm_mod, xlstm as xl
from repro.models.common import (
    PSpec, stacked, init_params, abstract_params, logical_tree, count_params,
)

Mixer = str   # attn | mamba | mlstm | slstm
Ffn = str     # mlp | moe | none


def block_pattern(cfg: ArchConfig) -> list[tuple[Mixer, Ffn]]:
    if cfg.family == "ssm" and cfg.xlstm is not None:
        p = cfg.xlstm.slstm_every
        return [("slstm", "none") if cfg.is_slstm_layer(i) else
                ("mlstm", "none") for i in range(p)]
    if cfg.family == "hybrid":
        period = cfg.attn_every
        if cfg.moe is not None:
            import math
            period = math.lcm(cfg.attn_every, cfg.moe.every)
        return [("attn" if cfg.is_attention_layer(i) else "mamba",
                 "moe" if cfg.is_moe_layer(i) else "mlp")
                for i in range(period)]
    ffn = "moe" if cfg.moe is not None else "mlp"
    return [("attn", ffn)]


def _block_specs(cfg: ArchConfig, typ: tuple[Mixer, Ffn]) -> dict:
    mixer, ffn = typ
    d = cfg.d_model
    out: dict[str, Any] = {"norm1": PSpec((d,), ("embed",), init="ones")}
    if mixer == "attn":
        out["attn"] = layers.attn_specs(cfg)
    elif mixer == "mamba":
        out["mamba"] = ssm_mod.ssm_specs(cfg)
    elif mixer == "mlstm":
        out["mlstm"] = xl.mlstm_specs(cfg)
    elif mixer == "slstm":
        out["slstm"] = xl.slstm_specs(cfg)
    if ffn != "none":
        out["norm2"] = PSpec((d,), ("embed",), init="ones")
        if ffn == "mlp":
            out["mlp"] = layers.mlp_specs(d, cfg.d_ff, cfg.mlp_gated)
        else:
            out["moe"] = moe_mod.moe_specs(cfg)
    return out


@dataclass
class LM:
    cfg: ArchConfig
    mesh: Mesh | None = None
    rules: ShardingRules = field(default_factory=lambda: DEFAULT_RULES)
    moe_strategy: str = "auto"
    mlstm_mode: str = "auto"          # auto | parallel | chunkwise
    cache_dtype: Any = jnp.bfloat16
    # One-hot matmul embedding lookup: with the table sharded vocab->model,
    # a gather forces GSPMD to rematerialize the full table per step (the
    # "involuntary full rematerialization" SPMD warning); the one-hot
    # contraction keeps the table sharded and reduces the partials with a
    # (B, S, D)-sized all-reduce instead.
    embed_onehot: bool = False
    # Metrics-isolation mode: attention mixers become identity.  The
    # dry-run's kernel-substituted roofline compiles the model twice
    # (normal / identity) — the difference isolates the attention region's
    # HLO cost exactly, which is then replaced by the Pallas flash kernel's
    # analytic HBM traffic (the XLA-visible jnp path materializes f32
    # score chains that the kernel keeps in VMEM).
    attn_identity: bool = False
    # Dry-run metrics mode: fully unroll the layer scan and query-chunk scans
    # so cost_analysis() counts every iteration (XLA visits a while body
    # once); see launch/dryrun.py's two-point depth extrapolation.
    scan_unroll: bool = False

    # ------------------------------------------------------------------ specs
    @property
    def pattern(self) -> list[tuple[Mixer, Ffn]]:
        return block_pattern(self.cfg)

    @property
    def repeats(self) -> int:
        period = len(self.pattern)
        assert self.cfg.num_layers % period == 0, (self.cfg.num_layers, period)
        return self.cfg.num_layers // period

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: dict[str, Any] = {
            "embed": PSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           init="scaled", scale=0.02),
            "final_norm": PSpec((cfg.d_model,), ("embed",), init="ones"),
            "blocks": {f"b{p}": stacked(self.repeats, _block_specs(cfg, t))
                       for p, t in enumerate(self.pattern)},
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = PSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"), init="scaled",
                                     scale=0.02)
        if cfg.frontend.kind == "vision_patches":
            specs["patch_proj"] = PSpec(
                (cfg.frontend.embed_dim, cfg.d_model), (None, "embed"))
        return specs

    def init(self, key, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return init_params(key, self.param_specs(), dtype)

    def abstract(self, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return abstract_params(self.param_specs(), dtype)

    def logical(self):
        return logical_tree(self.param_specs())

    def n_params(self, active_only: bool = False) -> int:
        if not active_only or self.cfg.moe is None:
            return count_params(self.param_specs())
        from repro.configs.base import override
        cfg_a = override(self.cfg,
                         moe=override(self.cfg.moe,
                                      num_experts=self.cfg.moe.top_k))
        return count_params(LM(cfg_a).param_specs())

    # ------------------------------------------------------------ embeddings
    def _embed_in(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        adt = jnp.dtype(cfg.dtype)
        if self.embed_onehot:
            oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=adt)
            x = oh @ params["embed"].astype(adt)
        else:
            x = jnp.take(params["embed"], tokens, axis=0).astype(adt)
        if cfg.frontend.kind == "vision_patches" and patch_embeds is not None:
            # decode steps after prefill are text-only: patches already cached
            p = (patch_embeds.astype(adt) @
                 params["patch_proj"].astype(adt))
            x = jnp.concatenate([p, x], axis=1)
        return x

    def _head(self, params, x):
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["lm_head"])
        return (x @ w.astype(x.dtype)).astype(jnp.float32)

    # --------------------------------------------------------------- blocks
    def _mlstm_train_mode(self, L: int) -> str:
        if self.mlstm_mode != "auto":
            return self.mlstm_mode
        c = self.cfg.xlstm.chunk_size
        return "chunkwise" if (L % c == 0 and L > c) else "parallel"

    def _apply_block(self, typ, p, x, positions, mode, pos, cache,
                     big=None, max_len=None, wmask=None, tables=None,
                     offsets=None, tree=None, shard=None):
        """One block.  Returns (x, new_cache, aux).

        ``max_len`` (prefill mode) and ``wmask`` (verify mode; see
        ``layers.attention_verify``) are threaded EXPLICITLY from the
        caller: they are trace-time inputs, and stashing them on ``self``
        (as an earlier revision did with ``_max_len``) lets one ``LM``
        shared by two pools with different cache sizes retrace against
        the other pool's value — silently building wrong-size caches.

        ``tables`` ((B, P) int32, decode/verify modes) switches the
        attention cache to the shared page pool: ``cache`` is then a
        ``layers.PagedKV`` bank addressed through the per-row page
        tables, and ``wmask`` gates writes for decode too (non-live rows
        park).  ``offsets``/``tree`` (paged verify only) select tree
        verification — per-node depth offsets and per-row ancestor
        bitmasks; see ``layers.attention_verify_pages``.  ``shard``
        (``(mesh, axis)``, paged modes only) shard_maps the paged
        attention so each mesh shard reads only its local slice of the
        page bank (see ``layers.attention_decode_pages_sharded``).
        """
        cfg = self.cfg
        mixer, ffn = typ
        h = layers.rmsnorm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        nc = cache
        if mixer == "attn" and self.attn_identity:
            a = h                       # metrics isolation; see attn_identity
        elif mixer == "attn" and big is not None:
            assert mode == "decode"
            a, nc = layers.attention_decode_paged(p["attn"], h, pos, big,
                                                  cache, cfg)
        elif mixer == "attn" and tables is not None:
            if mode == "verify":
                a, nc = layers.attention_verify_pages(p["attn"], h, pos,
                                                      cache, tables, cfg,
                                                      wmask=wmask,
                                                      offsets=offsets,
                                                      tree=tree,
                                                      shard=shard)
            else:
                assert mode == "decode", mode
                a, nc = layers.attention_decode_pages(p["attn"], h, pos,
                                                      cache, tables, cfg,
                                                      wmask=wmask,
                                                      shard=shard)
        elif mixer == "attn":
            if mode == "train":
                a = layers.attention(p["attn"], h, positions, cfg,
                                     self.scan_unroll, self.mesh, self.rules)
            elif mode == "prefill":
                a, nc = layers.attention_prefill(
                    p["attn"], h, positions, cfg, max_len,
                    self.cache_dtype, self.scan_unroll, self.mesh,
                    self.rules)
            elif mode == "verify":
                a, nc = layers.attention_verify(p["attn"], h, pos, cache,
                                                cfg, wmask=wmask)
            else:
                a, nc = layers.attention_decode(p["attn"], h, pos, cache, cfg)
        elif mixer == "mamba":
            # the recurrent decode path takes (B, L, D) with carried state,
            # so "verify" (L == K block tokens) is the same call as decode
            if mode in ("decode", "verify"):
                a, nc = ssm_mod.mamba_decode(p["mamba"], h, cache, cfg)
            else:
                a, st = ssm_mod.mamba_forward(p["mamba"], h, cfg, mode="scan")
                nc = st if mode == "prefill" else cache
        elif mixer == "mlstm":
            if mode in ("decode", "verify"):
                a, nc = xl.mlstm_block(p["mlstm"], h, cfg, mode="recurrent",
                                       state=cache)
            else:
                m = self._mlstm_train_mode(h.shape[1])
                a, st = xl.mlstm_block(p["mlstm"], h, cfg, mode=m)
                nc = st if mode == "prefill" else cache
        elif mixer == "slstm":
            a, st = xl.slstm_block(p["slstm"], h, cfg,
                                   state=cache if mode in ("decode", "verify")
                                   else None)
            nc = st if mode in ("prefill", "decode", "verify") else cache
        else:
            raise ValueError(mixer)
        x = x + a
        if ffn != "none":
            h2 = layers.rmsnorm(x, p["norm2"].astype(x.dtype), cfg.norm_eps)
            if ffn == "mlp":
                f = layers.mlp({k: v.astype(x.dtype)
                                for k, v in p["mlp"].items()}, h2)
            else:
                f, aux = moe_mod.moe_apply(p["moe"], h2, cfg, self.mesh,
                                           self.moe_strategy)
            x = x + f
        if self.mesh is not None:
            x = constrain(x, self.mesh, ("batch", "act_seq", "act_embed"),
                          self.rules)
        return x, nc, aux

    def _run_blocks(self, params, x, positions, mode, pos, caches,
                    remat: bool = False, max_len: int | None = None,
                    wmask=None, tables=None, offsets=None, tree=None,
                    shard=None):
        """Scan over repeats; python-unrolled period inside the body."""
        pattern = self.pattern

        def body(carry, xs):
            x, aux = carry
            params_r, cache_r = xs
            new_caches = {}
            for i, typ in enumerate(pattern):
                key = f"b{i}"
                c = None if cache_r is None else cache_r[key]
                x, nc, a = self._apply_block(typ, params_r[key], x,
                                             positions, mode, pos, c,
                                             max_len=max_len, wmask=wmask,
                                             tables=tables, offsets=offsets,
                                             tree=tree, shard=shard)
                new_caches[key] = nc
                aux = aux + a
            if mode == "train":
                new_caches = 0.0  # nothing to collect
            return (x, aux), new_caches

        if remat:
            body = jax.checkpoint(body)
        unroll = self.repeats if self.scan_unroll else 1
        # When there is no input cache (train/prefill) we scan over params
        # only; prefill *produces* caches as the scan outputs.
        if caches is None:
            (x, aux), ys = jax.lax.scan(
                lambda c, p: body(c, (p, None)),
                (x, jnp.zeros((), jnp.float32)), params["blocks"],
                unroll=unroll)
        else:
            (x, aux), ys = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (params["blocks"], caches), unroll=unroll)
        return x, aux, ys

    # ---------------------------------------------------------------- modes
    def forward(self, params, tokens, patch_embeds=None, remat: bool = False):
        """Training forward: logits (B, S_total, V) f32, aux loss scalar."""
        cfg = self.cfg
        x = self._embed_in(params, tokens, patch_embeds)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux, _ = self._run_blocks(params, x, positions, "train", None,
                                     None, remat)
        x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                           cfg.norm_eps)
        return self._head(params, x), aux

    def hidden(self, params, tokens, patch_embeds=None, remat: bool = False):
        """Final hidden states (pre-head); used by the chunked-loss path."""
        cfg = self.cfg
        x = self._embed_in(params, tokens, patch_embeds)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux, _ = self._run_blocks(params, x, positions, "train", None,
                                     None, remat)
        x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                           cfg.norm_eps)
        return x, aux

    def prefill(self, params, tokens, max_len: int, patch_embeds=None):
        """Populate the decode cache.  Returns (last-pos logits, caches)."""
        cfg = self.cfg
        x = self._embed_in(params, tokens, patch_embeds)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux, caches = self._run_blocks(params, x, positions, "prefill",
                                          None, None, max_len=max_len)
        x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                           cfg.norm_eps)
        logits = self._head(params, x[:, -1:])
        return logits, caches

    def decode_step(self, params, caches, tokens, pos):
        """One decode step.  tokens: (B, 1) int32; pos: scalar int32
        (whole batch at one position) or (B,) int32 (continuous batching:
        per-request positions).  Returns (logits (B,1,V), new caches)."""
        cfg = self.cfg
        x = self._embed_in(params, tokens)
        x, aux, caches = self._run_blocks(params, x, None, "decode", pos,
                                          caches)
        x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                           cfg.norm_eps)
        return self._head(params, x), caches

    def verify_step(self, params, caches, tokens, pos):
        """Multi-token verify: score K tokens per row in ONE pass
        (speculative decode's target pass).

        tokens: (B, K) int32 — the block tokens, at cache positions
        ``pos .. pos+K-1`` per row (pos: scalar or (B,) int32).  Returns
        (logits (B, K, V), new caches) where ``logits[:, i]`` is the
        distribution for position pos+i+1 — identical to K iterations of
        ``decode_step`` (tested), including ring-buffer caches: attention
        reads the pre-block cache plus an intra-block causal term, so
        token i sees exactly the window the i-th sequential step would
        have seen.  Recurrent mixers run their carried-state scan over the
        K tokens, which is the sequential computation itself.
        """
        cfg = self.cfg
        x = self._embed_in(params, tokens)
        x, aux, caches = self._run_blocks(params, x, None, "verify", pos,
                                          caches)
        x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                           cfg.norm_eps)
        return self._head(params, x), caches

    def prefill_chunk(self, params, caches, tokens, pos, slots,
                      wmask=None, need_logits: bool = True):
        """Chunked prefill: score a (b, C) prompt *chunk* at per-row cache
        offsets ``pos .. pos+C-1`` and write its k/v into batch rows
        ``slots`` of the pooled ``caches`` (leaves (R, B, ...)).

        This is the verify machinery pointed at admission: one fixed
        (b, C) program processes every chunk of every prompt (prompts pad
        to the chunk width; ``wmask`` keeps pad writes out of the cache),
        so admission stops compiling one prefill program per prompt
        length, and a long prompt streams into its slot across many calls
        interleaved with decode steps — the paper's hide-the-load
        principle applied to the prompt itself.  Rows at ``pos == 0``
        have their gathered cache/state zeroed first, so chunk 0 starts
        from the same blank state a fresh ``prefill`` does (a recycled
        slot's stale row must not leak into the new request).

        Only the named rows change — the same disturb-free invariant
        ``insert_cache_rows`` keeps.  Returns (logits (b, C, V) f32 or
        ``None`` when ``need_logits`` is False, new pooled caches).
        """
        cfg = self.cfg
        slots = jnp.asarray(slots, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)
        sub = jax.tree.map(lambda c: c[:, slots], caches)

        def _fresh(c):
            m = (pos == 0).reshape((1, -1) + (1,) * (c.ndim - 2))
            return jnp.where(m, jnp.zeros((), c.dtype), c)

        sub = jax.tree.map(_fresh, sub)
        x = self._embed_in(params, tokens)
        x, aux, sub = self._run_blocks(params, x, None, "verify", pos, sub,
                                       wmask=wmask)
        logits = None
        if need_logits:
            x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                               cfg.norm_eps)
            logits = self._head(params, x)
        caches = jax.tree.map(lambda c, r: c.at[:, slots].set(r), caches,
                              sub)
        return logits, caches

    # ------------------------------------------------------- paged slot pool
    def _require_paged_support(self):
        if any(mix != "attn" for mix, _ in self.pattern):
            raise ValueError(
                "the paged page pool needs an all-attention model "
                "(recurrent mixers keep per-row state, not pages)")
        if self.cfg.sliding_window:
            raise ValueError(
                "the paged page pool needs full (non-ring) attention: "
                "ring slots alias positions a page table cannot express")

    def init_page_pool(self, num_pages: int, page: int,
                       abstract: bool = False, quantized: bool = False):
        """Shared-page decode cache: one ``layers.PagedKV`` bank per
        block, leaves (R, NP, Hkv, page, hd).  Page 0 is the PARK page
        (see ``layers._page_write``); the page table is shared across
        layers — page id p is position range [j*page, (j+1)*page) of its
        owning row in EVERY layer's bank.  ``quantized`` stores the bank
        as int8 codes plus (R, NP, Hkv, page) f32 scale leaves — roughly
        half the bytes per page, so ~2x pages per HBM budget."""
        self._require_paged_support()
        out = {}
        for i in range(len(self.pattern)):
            one = layers.init_page_pool(self.cfg, num_pages, page,
                                        self.cache_dtype, abstract,
                                        quantized=quantized)
            out[f"b{i}"] = _stack_tree(one, self.repeats, abstract)
        return out

    def page_pool_logical(self):
        return {f"b{i}": jax.tree.map(
            lambda l: ("layers",) + tuple(l), layers.PAGED_LOGICAL,
            is_leaf=lambda q: isinstance(q, tuple) and
            all(isinstance(e, str) or e is None for e in q))
            for i in range(len(self.pattern))}

    def page_pool_shardings(self, caches, mesh, axis: str):
        """``NamedSharding`` per page-pool leaf: the page (NP) axis of
        every bank leaf splits over mesh axis ``axis`` (so shard s
        physically holds the local slice its kernel instance reads under
        local-read sharding), everything else replicated.  The returned
        tree matches ``caches`` leaf-for-leaf — feed it to
        ``jax.device_put``/``jax.tree.map``."""
        rules = self.rules if self.rules is not None else DEFAULT_RULES
        rules = rules.with_(kv_pages=axis)
        kv = ("layers", "kv_pages", "kv_heads", None, "head_dim")
        sc = ("layers", "kv_pages", "kv_heads", None)

        def one(bank):
            return layers.PagedKV(
                k=spec_for(mesh, kv, bank.k.shape, rules),
                v=spec_for(mesh, kv, bank.v.shape, rules),
                ks=(None if bank.ks is None
                    else spec_for(mesh, sc, bank.ks.shape, rules)),
                vs=(None if bank.vs is None
                    else spec_for(mesh, sc, bank.vs.shape, rules)))

        return {key: one(bank) for key, bank in caches.items()}

    def insert_cache_pages(self, caches, rows, tables):
        """Admission into the page pool: scatter prefilled cache rows
        (a pytree with ``KVCache`` leaves (R, b, Hkv, S, hd)) into the
        pooled ``caches`` through the admitted rows' (b, P) page tables.
        Only the named pages (plus the park page) change — the paged
        analogue of ``insert_cache_rows``."""
        tables = jnp.asarray(tables, jnp.int32)
        ins = jax.vmap(layers.insert_pages, in_axes=(0, 0, None))
        return {key: ins(c, rows[key], tables) for key, c in caches.items()}

    def copy_cache_pages(self, caches, src, dst):
        """Copy-on-write support: duplicate pool pages ``src[i]`` into
        ``dst[i]`` across every block and repeat of the paged ``caches``
        (all leaves — int8 codes and their scales move together).  The
        page table is layer-shared, so one (src, dst) pair names the same
        position range in every bank; everything outside ``dst`` is
        untouched."""
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        cp = jax.vmap(layers.copy_pages, in_axes=(0, None, None))
        return {key: cp(c, src, dst) for key, c in caches.items()}

    def decode_step_pages(self, params, caches, tokens, pos, tables,
                          live=None, shard=None):
        """One decode step against the shared page pool.  tokens: (B, 1)
        int32; pos: (B,) int32; tables: (B, P) int32 page tables;
        ``live`` ((B,) bool, optional) routes non-live rows' cache writes
        to the park page — a retired slot's per-step garbage write must
        not land in pages already recycled to a neighbor.  ``shard``
        (``(mesh, axis)``) switches attention to per-shard local bank
        reads; see ``_apply_block``.  Returns (logits (B, 1, V), new
        caches)."""
        cfg = self.cfg
        tables = jnp.asarray(tables, jnp.int32)
        x = self._embed_in(params, tokens)
        x, aux, caches = self._run_blocks(params, x, None, "decode", pos,
                                          caches, wmask=live,
                                          tables=tables, shard=shard)
        x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                           cfg.norm_eps)
        return self._head(params, x), caches

    def verify_step_pages(self, params, caches, tokens, pos, tables,
                          wmask=None, need_logits: bool = True,
                          offsets=None, tree=None, shard=None):
        """Multi-token verify against the shared page pool — one (b, K)
        block scored at per-row offsets ``pos .. pos+K-1`` through the
        rows' page tables, k/v written into the rows' own pages.  Serves
        both chunked prefill (the verify machinery pointed at admission;
        ``wmask`` gates pad writes, ``need_logits=False`` for streaming
        chunks) and a paged ``SpecEngine`` verify column.  Unlike the
        row-granular ``prefill_chunk`` there is no gather/scatter of
        whole cache rows and no fresh-row zeroing: writes touch exactly
        the block's positions (O(K), not O(max_len)), and a recycled
        page is always rewritten before any of its positions become
        readable (reads mask ``cols < pos``).

        Tree verification (``SpecEngine(tree_width > 1)``): ``offsets``
        ((K,) int32 per-node depths) and ``tree`` ((B, K) int32 ancestor
        bitmasks) verify several candidate branches in one pass — the
        caller parks all but one writer per depth via ``wmask``."""
        cfg = self.cfg
        tables = jnp.asarray(tables, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)
        x = self._embed_in(params, tokens)
        x, aux, caches = self._run_blocks(params, x, None, "verify", pos,
                                          caches, wmask=wmask,
                                          tables=tables, offsets=offsets,
                                          tree=tree, shard=shard)
        logits = None
        if need_logits:
            x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                               cfg.norm_eps)
            logits = self._head(params, x)
        return logits, caches

    # chunked admission is the verify machinery pointed at the page pool
    prefill_chunk_pages = verify_step_pages

    # ------------------------------------------------------ multi-step decode
    def _decode_multi(self, params, caches, tokens, pos, steps, sample_fn,
                      stop_fn, carry, live=None, pos_cap=None, tables=None,
                      shard=None):
        """Up to ``steps`` decode steps in ONE device loop (the host tick
        amortizes over every iteration; see ``StepEngine(multi_step=T)``).

        Each iteration runs the SAME ``decode_step`` /
        ``decode_step_pages`` body a single-step engine would, then:

          * ``nxt, carry = sample_fn(last_logits, pos, carry)`` — the
            engine supplies its exact sampling rule (keys advance inside
            ``carry``), which is what keeps the fused stream bitwise
            equal to iterated single steps;
          * ``stop = stop_fn(nxt, advanced_pos, i)`` — a () bool that is
            True the moment ANY slot changes occupancy (EOS, token
            budget, page exhaustion).  The loop commits this step and
            exits, handing control back to the host while every slot's
            membership is still exactly what the host last saw.

        ``pos_cap`` clamps the advanced positions (the single-step
        engine's run-off guard); ``stop_fn`` sees them UNCLAMPED so a
        budget bitmap can fire on the true value.  Returns
        ``(out (B, steps) int32, n_steps () int32, caches, tok, pos,
        carry)`` — only ``out[:, :n_steps]`` is meaningful.
        """
        B = tokens.shape[0]

        def cond(st):
            return (st[0] < steps) & ~st[1]

        def body(st):
            i, stop, caches, tok, pos, carry, out = st
            if tables is None:
                logits, caches = self.decode_step(params, caches, tok, pos)
            else:
                logits, caches = self.decode_step_pages(
                    params, caches, tok, pos, tables, live=live,
                    shard=shard)
            nxt, carry = sample_fn(logits[:, -1], pos, carry)
            posr = pos + 1 if live is None else jnp.where(live, pos + 1, pos)
            stop = stop_fn(nxt, posr, i)
            if pos_cap is not None:
                posr = jnp.minimum(posr, pos_cap)
            out = jax.lax.dynamic_update_index_in_dim(out, nxt, i, 1)
            return (i + 1, stop, caches, nxt[:, None], posr, carry, out)

        init = (jnp.zeros((), jnp.int32), jnp.zeros((), bool), caches,
                jnp.asarray(tokens, jnp.int32), jnp.asarray(pos, jnp.int32),
                carry, jnp.zeros((B, steps), jnp.int32))
        n, _, caches, tok, pos, carry, out = jax.lax.while_loop(
            cond, body, init)
        return out, n, caches, tok, pos, carry

    def decode_multi_step(self, params, caches, tokens, pos, steps,
                          sample_fn, stop_fn, carry, live=None,
                          pos_cap=None):
        """Row-cache multi-step decode; see ``_decode_multi``.  ``steps``
        must be static (it sizes the output buffer)."""
        return self._decode_multi(params, caches, tokens, pos, steps,
                                  sample_fn, stop_fn, carry, live=live,
                                  pos_cap=pos_cap)

    def decode_multi_step_pages(self, params, caches, tokens, pos, tables,
                                steps, sample_fn, stop_fn, carry,
                                live=None, pos_cap=None, shard=None):
        """Paged multi-step decode; see ``_decode_multi``.  ``tables``
        is loop-invariant by construction: the loop exits before any
        occupancy change, so no page moves while it runs."""
        return self._decode_multi(params, caches, tokens, pos, steps,
                                  sample_fn, stop_fn, carry, live=live,
                                  pos_cap=pos_cap,
                                  tables=jnp.asarray(tables, jnp.int32),
                                  shard=shard)

    def decode_step_paged(self, params, bigs, acts, tokens, pos):
        """One decode step against a paged cache (see layers: BigKV/ActKV).

        ``bigs`` is read-only (per-block stacked BigKV; None for non-attn
        mixers); ``acts`` carries the active page + recurrent states and is
        the only cache state the step writes — donate it.
        """
        cfg = self.cfg
        x = self._embed_in(params, tokens)
        pattern = self.pattern

        # `bigs` is closed over and dynamic-indexed per layer rather than
        # threaded as scan xs: xs get copied into while-loop state by
        # buffer assignment (~2x the read-only cache in temps); an
        # invariant capture is read in place.
        def body(carry, xs):
            x, aux, r = carry
            params_r, act_r = xs
            big_r = jax.tree.map(
                lambda b: jax.lax.dynamic_index_in_dim(b, r, 0,
                                                       keepdims=False),
                bigs)
            new_acts = {}
            for i, typ in enumerate(pattern):
                key = f"b{i}"
                big = None if big_r is None else big_r.get(key)
                x, nc, a = self._apply_block(typ, params_r[key], x, None,
                                             "decode", pos, act_r[key], big)
                new_acts[key] = nc
                aux = aux + a
            return (x, aux, r + 1), new_acts

        unroll = self.repeats if self.scan_unroll else 1
        (x, aux, _), acts_new = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
            (params["blocks"], acts), unroll=unroll)
        x = layers.rmsnorm(x, params["final_norm"].astype(x.dtype),
                           cfg.norm_eps)
        return self._head(params, x), acts_new

    # ---------------------------------------------------------------- cache
    def insert_cache_rows(self, caches, rows, slots):
        """Per-slot cache reset/admission for the continuous-batching step
        engine: write ``rows`` (a decode-cache pytree for b requests,
        leaves (R, b, ...)) into batch rows ``slots`` ((b,) int32) of
        ``caches`` (leaves (R, B, ...)).  Only the named rows change — a
        freed slot is recycled by overwriting it with a fresh prefill, so
        admission never disturbs in-flight requests."""
        slots = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(
            lambda c, r: c.at[:, slots].set(r.astype(c.dtype)),
            caches, rows)

    def init_paged_cache(self, batch: int, max_len: int,
                         page: int = layers.DEFAULT_PAGE,
                         abstract: bool = False):
        """(bigs, acts) pytrees for decode_step_paged.  Non-attention
        mixers keep their (small, per-step) state on the act side."""
        cfg = self.cfg
        bigs, acts = {}, {}
        for i, (mixer, _) in enumerate(self.pattern):
            key = f"b{i}"
            if mixer == "attn":
                big, act = layers.init_paged_cache(
                    cfg, batch, max_len, page, self.cache_dtype, abstract)
                bigs[key] = _stack_tree(big, self.repeats, abstract)
                acts[key] = _stack_tree(act, self.repeats, abstract)
                continue
            bigs[key] = None
            if mixer == "mamba":
                one = (ssm_mod.ssm_state_abstract(cfg, batch,
                                                  self.cache_dtype)
                       if abstract else
                       ssm_mod.init_ssm_state(cfg, batch, self.cache_dtype))
            elif mixer == "mlstm":
                one = (xl.mlstm_state_abstract(cfg, batch, self.cache_dtype)
                       if abstract else
                       xl.init_mlstm_state(cfg, batch, self.cache_dtype))
            else:
                one = (xl.slstm_state_abstract(cfg, batch) if abstract
                       else xl.init_slstm_state(cfg, batch))
            acts[key] = _stack_tree(one, self.repeats, abstract)
        return bigs, acts

    def paged_cache_logical(self):
        bigs, acts = {}, {}
        base = {"mamba": ssm_mod.SSM_LOGICAL, "mlstm": xl.MLSTM_LOGICAL,
                "slstm": xl.SLSTM_LOGICAL}

        def add_layers(tree):
            return jax.tree.map(
                lambda l: ("layers",) + tuple(l), tree,
                is_leaf=lambda q: isinstance(q, tuple) and
                all(isinstance(e, str) or e is None for e in q))

        for i, (mixer, _) in enumerate(self.pattern):
            key = f"b{i}"
            if mixer == "attn":
                bigs[key] = add_layers(layers.BIG_LOGICAL)
                acts[key] = add_layers(layers.ACT_LOGICAL)
            else:
                bigs[key] = None
                acts[key] = add_layers(base[mixer])
        return bigs, acts

    def init_cache(self, batch: int, max_len: int, abstract: bool = False):
        """Decode-cache pytree matching the scanned-block structure."""
        cfg = self.cfg
        out = {}
        for i, (mixer, _) in enumerate(self.pattern):
            if mixer == "attn":
                one = (layers.kv_cache_abstract(cfg, batch, max_len,
                                                self.cache_dtype) if abstract
                       else layers.init_kv_cache(cfg, batch, max_len,
                                                 self.cache_dtype))
            elif mixer == "mamba":
                one = (ssm_mod.ssm_state_abstract(cfg, batch, self.cache_dtype)
                       if abstract else
                       ssm_mod.init_ssm_state(cfg, batch, self.cache_dtype))
            elif mixer == "mlstm":
                one = (xl.mlstm_state_abstract(cfg, batch, self.cache_dtype)
                       if abstract else
                       xl.init_mlstm_state(cfg, batch, self.cache_dtype))
            else:
                one = (xl.slstm_state_abstract(cfg, batch) if abstract
                       else xl.init_slstm_state(cfg, batch))
            out[f"b{i}"] = _stack_tree(one, self.repeats, abstract)
        return out

    def cache_logical(self):
        out = {}
        for i, (mixer, _) in enumerate(self.pattern):
            base = {"attn": layers.KV_LOGICAL, "mamba": ssm_mod.SSM_LOGICAL,
                    "mlstm": xl.MLSTM_LOGICAL, "slstm": xl.SLSTM_LOGICAL}[mixer]
            out[f"b{i}"] = jax.tree.map(
                lambda l: ("layers",) + tuple(l), base,
                is_leaf=lambda q: isinstance(q, tuple) and
                all(isinstance(e, str) or e is None for e in q))
        return out


def _stack_tree(tree, n: int, abstract: bool):
    if abstract:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), tree)


def build_model(cfg: ArchConfig, mesh: Mesh | None = None,
                rules: ShardingRules = DEFAULT_RULES, **kw) -> LM:
    return LM(cfg, mesh=mesh, rules=rules, **kw)
