"""xLSTM blocks: mLSTM (matrix-memory, parallelizable) and sLSTM (scalar-
memory, strictly recurrent) per arXiv:2405.04517.

mLSTM has three numerically-equivalent forms (cross-validated in tests):
  * ``mlstm_recurrent`` — step recurrence (decode path; O(1) state/token)
  * ``mlstm_parallel``  — quadratic attention-like form (training, short seq)
  * ``mlstm_chunkwise`` — chunked: quadratic intra-chunk + recurrence across
    chunks (long prefill; what the Pallas kernel `mlstm_chunk` implements)

All use log-space gate stabilization (running max ``m``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import PSpec

NEG_INF = -1e30


class MLSTMState(NamedTuple):
    C: jax.Array    # (B, H, dk, dv) f32 matrix memory
    n: jax.Array    # (B, H, dk) f32 normalizer
    m: jax.Array    # (B, H) f32 stabilizer
    conv: jax.Array  # (B, Lc-1, d_in) causal-conv ring


class SLSTMState(NamedTuple):
    h: jax.Array    # (B, H, dh)
    c: jax.Array    # (B, H, dh) f32
    n: jax.Array    # (B, H, dh) f32
    m: jax.Array    # (B, H, dh) f32


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ArchConfig) -> dict:
    x = cfg.xlstm
    d = cfg.d_model
    d_in = x.mlstm_expand * d
    H = cfg.num_heads
    return {
        "up_proj": PSpec((d, 2 * d_in), ("embed", "ssm_inner")),
        "conv_w": PSpec((x.conv_width, d_in), ("conv_width", "ssm_inner"),
                        init="scaled", scale=0.1),
        "conv_b": PSpec((d_in,), ("ssm_inner",), init="zeros"),
        "wq": PSpec((d_in, d_in), ("ssm_inner", "ssm_inner")),
        "wk": PSpec((d_in, d_in), ("ssm_inner", "ssm_inner")),
        "wv": PSpec((d_in, d_in), ("ssm_inner", "ssm_inner")),
        "w_if": PSpec((d_in, 2 * H), ("ssm_inner", None),
                      init="scaled", scale=0.02),
        "b_if": PSpec((2 * H,), (None,), init="zeros"),
        "down_proj": PSpec((d_in, d), ("ssm_inner", "embed")),
        "skip_scale": PSpec((d_in,), ("ssm_inner",), init="ones"),
    }


def slstm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    dff = int(4 * d * 2 / 3)
    return {
        "w_gates": PSpec((d, 4 * d), ("embed", "ssm_inner")),   # i,f,z,o
        "r_gates": PSpec((4, H, dh, dh), (None, "act_heads", None, None),
                         init="scaled", scale=0.02),
        "b_gates": PSpec((4 * d,), ("ssm_inner",), init="zeros"),
        "ffn": {
            "w_gate": PSpec((d, dff), ("embed", "ffn")),
            "w_up": PSpec((d, dff), ("embed", "ffn")),
            "w_down": PSpec((dff, d), ("ffn", "embed")),
        },
    }


# ---------------------------------------------------------------------------
# mLSTM core math (all inputs per-head, f32)
#   q,k,v: (B, H, L, dh); li, lf: (B, H, L) log gates
# ---------------------------------------------------------------------------

def mlstm_parallel(q, k, v, li, lf):
    """Quadratic stabilized form.  Returns h (B,H,L,dv) and final state."""
    B, H, L, dk = q.shape
    F = jnp.cumsum(lf, axis=-1)                              # (B,H,L)
    # d_ts = F_t - F_s + li_s  for s <= t
    dmat = F[..., :, None] - F[..., None, :] + li[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    dmat = jnp.where(mask, dmat, NEG_INF)
    m = jnp.max(dmat, axis=-1)                               # (B,H,L)
    D = jnp.exp(dmat - m[..., None])                         # (B,H,L,L)
    scores = jnp.einsum("bhld,bhsd->bhls", q, k) / jnp.sqrt(dk)
    C = scores * D
    n = jnp.maximum(jnp.abs(jnp.sum(C, axis=-1)), jnp.exp(-m))  # (B,H,L)
    h = jnp.einsum("bhls,bhsd->bhld", C, v) / n[..., None]
    # final recurrent state (for chunk handoff / tests)
    g = F[..., -1:]                                          # (B,H,1) total
    m_fin = jnp.maximum(jnp.max(g[..., 0:1] - F + li, axis=-1), NEG_INF)
    w = jnp.exp(g - F + li - m_fin[..., None])               # (B,H,L)
    C_fin = jnp.einsum("bhs,bhsd,bhse->bhde", w, k / jnp.sqrt(dk), v)
    n_fin = jnp.einsum("bhs,bhsd->bhd", w, k / jnp.sqrt(dk))
    return h, (C_fin, n_fin, m_fin)


def mlstm_step(C, n, m, q, k, v, li, lf):
    """One recurrence step.  q,k,v: (B,H,dh); li,lf: (B,H)."""
    dk = q.shape[-1]
    m_new = jnp.maximum(lf + m, li)                          # (B,H)
    f_s = jnp.exp(lf + m - m_new)[..., None]
    i_s = jnp.exp(li - m_new)[..., None]
    k = k / jnp.sqrt(dk)
    C_new = f_s[..., None] * C + i_s[..., None] * k[..., :, None] * v[..., None, :]
    n_new = f_s * n + i_s * k
    num = jnp.einsum("bhde,bhd->bhe", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)),
                      jnp.exp(-m_new))
    return C_new, n_new, m_new, num / den[..., None]


def mlstm_recurrent(q, k, v, li, lf, state=None):
    """Sequential scan over L (oracle + decode).  Shapes as parallel form."""
    B, H, L, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), NEG_INF, jnp.float32)   # "no history"
    else:
        C0, n0, m0 = state

    def step(carry, t):
        C, n, m = carry
        q_t, k_t, v_t, li_t, lf_t = t
        C, n, m, h = mlstm_step(C, n, m, q_t, k_t, v_t, li_t, lf_t)
        return (C, n, m), h

    xs = tuple(a.swapaxes(0, 2).swapaxes(1, 2) if a.ndim == 4 else
               a.swapaxes(0, 2).swapaxes(1, 2)
               for a in (q, k, v))
    xs = xs + tuple(a.swapaxes(1, 2).swapaxes(0, 1) for a in (li, lf))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3), (C, n, m)


def mlstm_chunkwise(q, k, v, li, lf, chunk: int, state=None):
    """Chunked form: scan of parallel-intra-chunk + recurrent handoff."""
    B, H, L, dk = q.shape
    dv = v.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    if state is None:
        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), NEG_INF, jnp.float32)   # "no history"
    else:
        C0, n0, m0 = state

    def chunk_fn(carry, t):
        C_p, n_p, m_p = carry
        qc, kc, vc, lic, lfc = t                            # (B,H,c,*)
        g = jnp.cumsum(lfc, axis=-1)                         # (B,H,c)
        # intra-chunk decay matrix
        dmat = g[..., :, None] - g[..., None, :] + lic[..., None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(mask, dmat, NEG_INF)
        m_intra = jnp.max(dmat, axis=-1)                     # (B,H,c)
        m_inter = g + m_p[..., None]                         # (B,H,c)
        m_t = jnp.maximum(m_intra, m_inter)
        D = jnp.exp(dmat - m_t[..., None])
        scores = jnp.einsum("bhld,bhsd->bhls", qc, kc) / jnp.sqrt(dk)
        intra_num = jnp.einsum("bhls,bhse->bhle", scores * D, vc)
        intra_den = jnp.sum(scores * D, axis=-1)
        w_inter = jnp.exp(m_inter - m_t)[..., None]          # (B,H,c,1)
        inter_num = jnp.einsum("bhld,bhde->bhle", qc, C_p) * w_inter
        inter_den = jnp.einsum("bhld,bhd->bhl", qc, n_p) * w_inter[..., 0]
        num = intra_num + inter_num
        den = jnp.maximum(jnp.abs(intra_den + inter_den), jnp.exp(-m_t))
        h = num / den[..., None]
        # chunk-final state
        gT = g[..., -1:]                                     # (B,H,1)
        m_new = jnp.maximum(gT[..., 0] + m_p,
                            jnp.max(gT - g + lic, axis=-1))
        wk = jnp.exp(gT - g + lic - m_new[..., None])        # (B,H,c)
        ks = kc / jnp.sqrt(dk)
        C_new = jnp.exp(gT[..., 0] + m_p - m_new)[..., None, None] * C_p + \
            jnp.einsum("bhs,bhsd,bhse->bhde", wk, ks, vc)
        n_new = jnp.exp(gT[..., 0] + m_p - m_new)[..., None] * n_p + \
            jnp.einsum("bhs,bhsd->bhd", wk, ks)
        return (C_new, n_new, m_new), h

    def to_chunks(a):
        if a.ndim == 4:
            return a.reshape(B, H, nc, chunk, a.shape[-1]).transpose(2, 0, 1, 3, 4)
        return a.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)

    xs = tuple(to_chunks(a) for a in (q, k, v, li, lf))
    (C, n, m), hs = jax.lax.scan(chunk_fn, (C0, n0, m0), xs)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, L, dv)
    return h, (C, n, m)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _mlstm_qkv(params, x, cfg: ArchConfig, conv_state=None):
    from repro.models.ssm import _conv1d_causal  # shared depthwise conv
    xlcfg = cfg.xlstm
    H = cfg.num_heads
    xz = x @ params["up_proj"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)                        # (B,L,d_in)
    xc, conv_new = _conv1d_causal(
        xi, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype),
        conv_state)
    xc = jax.nn.silu(xc)
    B, L, d_in = xi.shape
    dh = d_in // H

    def heads(t):
        return t.reshape(B, L, H, dh).transpose(0, 2, 1, 3)
    q = heads(xc @ params["wq"].astype(x.dtype)).astype(jnp.float32)
    k = heads(xc @ params["wk"].astype(x.dtype)).astype(jnp.float32)
    v = heads(xi @ params["wv"].astype(x.dtype)).astype(jnp.float32)
    gates = (xc @ params["w_if"].astype(x.dtype) +
             params["b_if"].astype(x.dtype)).astype(jnp.float32)
    li, lf_raw = jnp.split(gates, 2, axis=-1)                # (B,L,H)
    li = li.transpose(0, 2, 1)
    lf = jax.nn.log_sigmoid(lf_raw).transpose(0, 2, 1)       # log f in (-inf,0)
    return q, k, v, li, lf, z, xi, conv_new


def mlstm_block(params, x, cfg: ArchConfig, mode: str = "parallel",
                state: MLSTMState | None = None):
    """x: (B, L, D) -> (y, MLSTMState)."""
    B, L, D = x.shape
    H = cfg.num_heads
    conv_state = None if state is None else state.conv
    q, k, v, li, lf, z, xi, conv_new = _mlstm_qkv(params, x, cfg, conv_state)
    inner = None if state is None else (state.C, state.n, state.m)
    import repro.kernels as kernels
    if mode == "parallel":
        assert state is None
        h, fin = mlstm_parallel(q, k, v, li, lf)
    elif mode == "chunkwise":
        assert state is None
        if kernels.use_kernels():
            from repro.kernels.mlstm_chunk.ops import mlstm_chunk
            interp = None if kernels.get_mode() == "auto" else True
            h, fin = mlstm_chunk(q, k, v, li, lf,
                                 chunk=cfg.xlstm.chunk_size,
                                 interpret=interp)
        else:
            h, fin = mlstm_chunkwise(q, k, v, li, lf, cfg.xlstm.chunk_size)
    else:
        h, fin = mlstm_recurrent(q, k, v, li, lf, inner)
    d_in = xi.shape[-1]
    h = h.transpose(0, 2, 1, 3).reshape(B, L, d_in).astype(x.dtype)
    h = h + params["skip_scale"].astype(x.dtype) * xi        # learnable skip
    y = (h * jax.nn.silu(z)) @ params["down_proj"].astype(x.dtype)
    return y, MLSTMState(C=fin[0], n=fin[1], m=fin[2], conv=conv_new)


def slstm_block(params, x, cfg: ArchConfig, state: SLSTMState | None = None):
    """Strictly recurrent sLSTM with exponential gating + post FFN."""
    B, L, D = x.shape
    H = cfg.num_heads
    dh = D // H
    gates_x = x @ params["w_gates"].astype(x.dtype) + \
        params["b_gates"].astype(x.dtype)                    # (B,L,4D)
    gates_x = gates_x.reshape(B, L, 4, H, dh).astype(jnp.float32)
    R = params["r_gates"].astype(jnp.float32)                # (4,H,dh,dh)

    if state is None:
        z = jnp.zeros((B, H, dh), jnp.float32)
        state = SLSTMState(h=z, c=z, n=z, m=z)

    def step(carry, gx):
        h, c, n, m = carry
        rec = jnp.einsum("ghde,bhd->gbhe", R, h)             # (4,B,H,dh)
        gi, gf, gz, go = (gx[:, i] + rec[i] for i in range(4))
        m_new = jnp.maximum(gf + m, gi)
        i_s = jnp.exp(gi - m_new)
        f_s = jnp.exp(gf + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(gz)
        n_new = f_s * n + i_s
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
        return SLSTMState(h_new, c_new, n_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, gates_x.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, L, D).astype(x.dtype)
    f = params["ffn"]
    y = y + (jax.nn.gelu(y @ f["w_gate"].astype(x.dtype)) *
             (y @ f["w_up"].astype(x.dtype))) @ f["w_down"].astype(x.dtype)
    return y, state


# ---------------------------------------------------------------------------
# state factories
# ---------------------------------------------------------------------------

def init_mlstm_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    d_in = cfg.xlstm.mlstm_expand * cfg.d_model
    H = cfg.num_heads
    dh = d_in // H
    K = cfg.xlstm.conv_width
    return MLSTMState(
        C=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
        m=jnp.full((batch, H), NEG_INF, jnp.float32),
        conv=jnp.zeros((batch, d_in, K - 1), dtype))


def mlstm_state_abstract(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    d_in = cfg.xlstm.mlstm_expand * cfg.d_model
    H = cfg.num_heads
    dh = d_in // H
    K = cfg.xlstm.conv_width
    sd = jax.ShapeDtypeStruct
    return MLSTMState(C=sd((batch, H, dh, dh), jnp.float32),
                      n=sd((batch, H, dh), jnp.float32),
                      m=sd((batch, H), jnp.float32),
                      conv=sd((batch, d_in, K - 1), dtype))


def init_slstm_state(cfg: ArchConfig, batch: int):
    H = cfg.num_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return SLSTMState(h=z, c=z, n=z, m=z)


def slstm_state_abstract(cfg: ArchConfig, batch: int):
    H = cfg.num_heads
    dh = cfg.d_model // H
    s = jax.ShapeDtypeStruct((batch, H, dh), jnp.float32)
    return SLSTMState(h=s, c=s, n=s, m=s)


MLSTM_LOGICAL = MLSTMState(C=("kv_batch", "act_heads", None, None),
                           n=("kv_batch", "act_heads", None),
                           m=("kv_batch", "act_heads"),
                           conv=("kv_batch", "ssm_inner", "conv_width"))
SLSTM_LOGICAL = SLSTMState(h=("kv_batch", "act_heads", None),
                           c=("kv_batch", "act_heads", None),
                           n=("kv_batch", "act_heads", None),
                           m=("kv_batch", "act_heads", None))
