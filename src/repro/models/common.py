"""Parameter-spec machinery.

Models declare a nested dict of ``PSpec`` (shape + logical axes + init kind).
From that single declaration we derive:
  * ``init_params``      — materialized, RNG-initialized pytree (tests/examples)
  * ``abstract_params``  — ShapeDtypeStruct pytree (dry-run: zero allocation)
  * ``logical_tree``     — logical-axis pytree (sharding rules -> NamedSharding)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | scaled
    scale: float | None = None  # stddev override for normal/scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def stacked(n: int, specs: Any) -> Any:
    """Prepend a scanned-layers axis to every PSpec in a subtree."""
    def one(s: PSpec) -> PSpec:
        return PSpec((n,) + s.shape, ("layers",) + s.logical, s.init, s.scale)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, PSpec))


def _is_spec(x) -> bool:
    return isinstance(x, PSpec)


def _init_one(key, s: PSpec, dtype) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, dtype)
    if s.init == "normal":
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        std = s.scale if s.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(dtype)
    if s.init == "scaled":
        std = s.scale if s.scale is not None else 0.02
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(s.init)


def init_params(key, specs: Any, dtype=jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        specs, is_leaf=_is_spec)


def logical_tree(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.logical, specs, is_leaf=_is_spec)


def count_params(specs: Any) -> int:
    return sum(math.prod(s.shape)
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))
