"""Mixture-of-Experts FFN.

Three execution strategies (selected by ``MoELayer`` callers):

* ``moe_dense_ref``   — exact top-k reference: every token visits its top-k
                        experts via dense per-expert einsum over a mask.
                        O(E x tokens) compute; used as the test oracle and
                        for smoke-scale runs.
* ``moe_tp``          — tensor-parallel experts: expert FFN hidden dim is
                        sharded over `model`; tokens are not moved.  Used when
                        num_experts < model-axis size (mixtral: 8e vs 16-wide
                        axis).  XLA inserts the standard TP all-reduce.
* ``moe_ep``          — expert-parallel: experts sharded over `model`;
                        capacity-padded scatter dispatch + all_to_all inside
                        shard_map (production path for qwen3 128e / jamba 16e).

Capacity semantics match across ep/ref when capacity_factor is large enough
that nothing drops (tested); with drops, overflow tokens pass through with
their residual only (standard dropping MoE).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.compat import shard_map

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.common import PSpec


def moe_specs(cfg: ArchConfig) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    return {
        "w_router": PSpec((d, e), ("embed", None), init="scaled", scale=0.02),
        "w_gate": PSpec((e, d, f), ("experts", "embed", "expert_ffn")),
        "w_up": PSpec((e, d, f), ("experts", "embed", "expert_ffn")),
        "w_down": PSpec((e, f, d), ("experts", "expert_ffn", "embed")),
    }


def router(params, x, m: MoEConfig):
    """x: (T, D) -> top-k probs (T, k), indices (T, k), aux loss scalar."""
    logits = (x.astype(jnp.float32) @ params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize
    # Switch-style load-balancing aux loss
    density = jnp.mean(jax.nn.one_hot(top_i[:, 0], m.num_experts), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * m.num_experts
    return top_p.astype(x.dtype), top_i, aux


def _expert_mlp(w_gate, w_up, w_down, x):
    """x: (E, C, D) grouped tokens; weights (E, D, F)/(E, F, D)."""
    import repro.kernels as kernels
    if kernels.use_kernels():
        from repro.kernels.gmm.ops import expert_mlp
        interp = None if kernels.get_mode() == "auto" else True
        return expert_mlp(x, w_gate, w_up, w_down, interpret=interp)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


# ---------------------------------------------------------------------------
# reference: exact top-k via masked dense dispatch (oracle)
# ---------------------------------------------------------------------------

def moe_dense_ref(params, x, cfg: ArchConfig):
    """x: (B, S, D).  Every token through every expert, masked to top-k."""
    m = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    top_p, top_i, aux = router(params, xt, m)
    out = jnp.zeros_like(xt)
    dt = x.dtype
    for e in range(m.num_experts):                    # unrolled: oracle only
        w = jnp.where(top_i == e, top_p, 0).sum(axis=-1)      # (T,)
        h = jax.nn.silu(xt @ params["w_gate"][e].astype(dt))
        h = h * (xt @ params["w_up"][e].astype(dt))
        y = h @ params["w_down"][e].astype(dt)
        out = out + w[:, None].astype(dt) * y
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# TP strategy: experts replicated across devices, FFN dim sharded (E < axis)
# ---------------------------------------------------------------------------

def moe_tp(params, x, cfg: ArchConfig):
    """Dense capacity-free top-k via one-hot combine; expert hidden dim is TP-
    sharded through the logical rules (expert_ffn -> model override).

    The router combine weights are folded into the FFN activations *before*
    the down-projection, so the contraction collapses (e, f) at once and the
    TP partial-sum all-reduce carries (T, D) — not (T, E, D).  (Measured on
    mixtral train_4k: 8x less all-reduce traffic; EXPERIMENTS.md §Perf.)"""
    m = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    top_p, top_i, aux = router(params, xt, m)
    comb = jnp.zeros((xt.shape[0], m.num_experts), x.dtype)
    comb = jax.vmap(lambda c, i, p: c.at[i].add(p))(comb, top_i, top_p)
    # (T, E) x experts: compute all experts on all tokens, combine.
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("td,edf->tef", xt, params["w_up"].astype(x.dtype))
    h = h * comb[:, :, None]
    out = jnp.einsum("tef,efd->td", h, params["w_down"].astype(x.dtype))
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# EP strategy: capacity-padded scatter + all_to_all inside shard_map
# ---------------------------------------------------------------------------

def _dispatch_local(xt, top_p, top_i, num_experts: int, capacity: int):
    """Scatter local tokens into per-expert capacity buffers.

    Returns (buf (E, C, D), slot (T, k), kept (T, k)); slot is the position a
    (token, choice) landed at, kept=False means dropped by capacity.
    """
    T, D = xt.shape
    k = top_i.shape[1]
    flat_e = top_i.reshape(-1)                                  # (T*k,)
    # position of each (token, choice) within its expert's buffer
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1                   # (T*k, E)
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    kept = slot < capacity
    dst = jnp.where(kept, flat_e * capacity + slot, num_experts * capacity)
    buf = jnp.zeros((num_experts * capacity + 1, D), xt.dtype)
    src = jnp.repeat(xt, k, axis=0)                             # (T*k, D)
    buf = buf.at[dst].set(src, mode="drop")
    return (buf[:-1].reshape(num_experts, capacity, D),
            slot.reshape(T, k), kept.reshape(T, k))


def _combine_local(y_buf, top_p, top_i, slot, kept, capacity: int):
    """Gather expert outputs back to token order, weighted by router probs."""
    T, k = top_i.shape
    E = y_buf.shape[0]
    flat = y_buf.reshape(E * capacity, -1)
    idx = jnp.where(kept, top_i * capacity + slot, 0)           # (T, k)
    y = flat[idx.reshape(-1)].reshape(T, k, -1)
    w = jnp.where(kept, top_p, 0)
    return jnp.einsum("tkd,tk->td", y, w.astype(y.dtype))


def moe_ep(params, x, cfg: ArchConfig, mesh: Mesh,
           ep_axis: str = "model", fsdp_axis: str | None = "data",
           capacity_factor: float | None = None):
    """Expert-parallel MoE: shard_map over the whole mesh.

    In-specs: tokens are sharded batch->('pod','data') and seq->model
    (sequence parallelism for the MoE region); expert weights are sharded
    experts->model (+ FSDP over data on the embed dim, all-gathered here).
    """
    m = cfg.moe
    ep = mesh.shape[ep_axis]
    assert m.num_experts % ep == 0, (m.num_experts, ep)
    cf = capacity_factor or m.capacity_factor
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    tok_spec = P(data_axes, ep_axis, None)          # (B, S, D) local tokens
    wr_spec = P(None, None)
    we_spec = P(ep_axis, fsdp_axis if fsdp_axis in mesh.shape else None, None)
    wd_spec = P(ep_axis, None, fsdp_axis if fsdp_axis in mesh.shape else None)

    def body(x_loc, w_router, w_gate, w_up, w_down):
        if fsdp_axis and fsdp_axis in mesh.shape and mesh.shape[fsdp_axis] > 1:
            w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
            w_up = jax.lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, fsdp_axis, axis=2, tiled=True)
        B, S, D = x_loc.shape
        xt = x_loc.reshape(-1, D)
        T = xt.shape[0]
        top_p, top_i, aux = router({"w_router": w_router}, xt, m)
        capacity = max(int(math.ceil(T * m.top_k / m.num_experts * cf)), 1)
        buf, slot, kept = _dispatch_local(xt, top_p, top_i,
                                          m.num_experts, capacity)
        # deliver: (E, C, D) -> every device keeps its E/ep experts, gathering
        # the C-slices contributed by all ep peers along axis 1.
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)        # (E/ep, C*ep, D)
        y = _expert_mlp(w_gate.astype(xt.dtype), w_up.astype(xt.dtype),
                        w_down.astype(xt.dtype), buf)
        y = jax.lax.all_to_all(y, ep_axis, split_axis=1, concat_axis=0,
                               tiled=True)          # (E, C, D) back home
        out = _combine_local(y, top_p, top_i, slot, kept, capacity)
        aux = jax.lax.pmean(aux, data_axes + (ep_axis,))
        return out.reshape(B, S, D), aux

    fn = shard_map(body, mesh=mesh,
                   in_specs=(tok_spec, wr_spec, we_spec, we_spec, wd_spec),
                   out_specs=(tok_spec, P()), check_vma=False)
    return fn(x, params["w_router"], params["w_gate"], params["w_up"],
              params["w_down"])


def moe_apply(params, x, cfg: ArchConfig, mesh: Mesh | None = None,
              strategy: str = "auto"):
    """Entry point used by the model zoo."""
    m = cfg.moe
    if strategy == "auto":
        tp = mesh.shape.get("model", 1) if mesh is not None else 1
        # EP's all_to_all dispatch shards the seq dim over `model`; decode
        # steps (S == 1) and ragged seqs fall back to expert-sharded dense
        # dispatch (XLA partitions the expert dim + all-reduces the combine).
        if tp > 1 and m.num_experts % tp == 0 and x.shape[1] % tp == 0:
            strategy = "ep"
        elif tp > 1:
            strategy = "tp"
        else:
            strategy = "ref"
    if strategy == "ep":
        return moe_ep(params, x, cfg, mesh)
    if strategy == "tp":
        return moe_tp(params, x, cfg)
    return moe_dense_ref(params, x, cfg)
