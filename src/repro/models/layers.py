"""Core transformer layers: RMSNorm, RoPE, GQA attention (full + sliding
window), gated MLP.  Pure-jnp reference path; on TPU the attention ops
dispatch to the Pallas kernels via ``repro.kernels``.

All functions are functional: ``params`` in, arrays out.  Attention exposes
three entry points matching the framework's execution modes:
  * ``attention``          — training forward (no cache)
  * ``attention_prefill``  — returns the populated KV cache
  * ``attention_decode``   — one token against the cache
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import PSpec

NEG_INF = -1e30  # bf16-safe large negative


# ---------------------------------------------------------------------------
# norms / mlp
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def mlp_specs(d: int, f: int, gated: bool = True) -> dict:
    out = {
        "w_up": PSpec((d, f), ("embed", "ffn")),
        "w_down": PSpec((f, d), ("ffn", "embed")),
    }
    if gated:
        out["w_gate"] = PSpec((d, f), ("embed", "ffn"))
    return out


def mlp(params, x):
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                    # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Contiguous KV cache; for sliding-window archs S == window (ring)."""
    k: jax.Array          # (B, Hkv, S, hd)
    v: jax.Array          # (B, Hkv, S, hd)


def attn_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "wq": PSpec((d, cfg.num_heads, cfg.head_dim),
                    ("embed", "heads", "head_dim")),
        "wk": PSpec((d, cfg.num_kv_heads, cfg.head_dim),
                    ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, cfg.num_kv_heads, cfg.head_dim),
                    ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((cfg.num_heads, cfg.head_dim, d),
                    ("heads", "head_dim", "embed")),
    }


def _qkv(params, x, positions, cfg: ArchConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _constrain(x, mesh, rules, logical):
    if mesh is None:
        return x
    from repro.distributed.sharding import constrain as _c
    from repro.distributed.sharding import DEFAULT_RULES
    return _c(x, mesh, logical, rules if rules is not None else DEFAULT_RULES)


def _sdpa(q, k, v, mask, cfg: ArchConfig, mesh=None, rules=None):
    """q: (B,S,H,hd), k/v: (B,T,Hkv,hd), mask: (S,T) or (B,S,T) bool.

    KV heads are expanded to H so the (B,H,S,T) scores shard cleanly over
    the full `model` axis even when Hkv < axis size (GQA kv=4 archs on a
    16-wide axis).  On TPU the flash kernel does GQA natively; this is the
    XLA-visible formulation whose sharding GSPMD propagates.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    scores = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = _constrain(scores, mesh, rules,
                        ("batch", "act_heads", "act_attn_q", None))
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, v)
    return out


ATTN_CHUNK_THRESHOLD = 2_048   # at/above this, use query-chunked attention
ATTN_CHUNK = 1_024


def _sdpa_chunked(q, k, v, cfg: ArchConfig, chunk: int = ATTN_CHUNK,
                  unroll: bool = False, mesh=None, rules=None):
    """Query-chunked SDPA: O(chunk * S) live scores instead of O(S^2).

    Baseline keeps full-K per chunk with masking (the causal/window FLOP
    waste is visible in the roofline utilization ratio; the Pallas flash
    kernel removes it on TPU).  ``unroll`` is the dry-run metrics mode.
    """
    B, S, H, hd = q.shape
    if S % chunk != 0:
        return _sdpa(q, k, v, causal_mask(S, cfg.sliding_window), cfg,
                     mesh, rules)
    n = S // chunk
    w = cfg.sliding_window

    def body(_, qc_i):
        qc, i = qc_i
        rows = i * chunk + jnp.arange(chunk)[:, None]
        cols = jnp.arange(S)[None, :]
        mask = cols <= rows
        if w > 0:
            mask &= (rows - cols) < w
        return 0, _sdpa(qc, k, v, mask, cfg, mesh, rules)

    qs = q.reshape(B, n, chunk, H, hd).swapaxes(0, 1)
    _, outs = jax.lax.scan(body, 0, (qs, jnp.arange(n)),
                           unroll=n if unroll else 1)
    return outs.swapaxes(0, 1).reshape(B, S, H, hd)


def causal_mask(S: int, window: int = 0) -> jax.Array:
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window > 0:
        m &= (i - j) < window
    return m


def _sdpa_auto(q, k, v, cfg: ArchConfig, unroll: bool = False,
               mesh=None, rules=None):
    import repro.kernels as kernels
    S = q.shape[1]
    if kernels.use_kernels() and S == k.shape[1]:
        from repro.kernels.flash_attention.ops import flash_attention
        interp = None if kernels.get_mode() == "auto" else True
        out = flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                              v.swapaxes(1, 2), causal=True,
                              window=cfg.sliding_window, interpret=interp)
        return out.swapaxes(1, 2)
    if S >= ATTN_CHUNK_THRESHOLD:
        return _sdpa_chunked(q, k, v, cfg, unroll=unroll, mesh=mesh,
                             rules=rules)
    return _sdpa(q, k, v, causal_mask(S, cfg.sliding_window), cfg, mesh,
                 rules)


def attention(params, x, positions, cfg: ArchConfig, unroll: bool = False,
              mesh=None, rules=None):
    """Training forward (no cache)."""
    q, k, v = _qkv(params, x, positions, cfg)
    out = _sdpa_auto(q, k, v, cfg, unroll, mesh, rules)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def attention_prefill(params, x, positions, cfg: ArchConfig, max_len: int,
                      cache_dtype=jnp.bfloat16, unroll: bool = False,
                      mesh=None, rules=None):
    """Prefill from position 0: returns output and a fixed-size cache.

    Full attention: cache length == max_len.  Sliding window: cache length ==
    window, laid out as a ring (slot = position % window).
    """
    q, k, v = _qkv(params, x, positions, cfg)
    out = _sdpa_auto(q, k, v, cfg, unroll, mesh, rules)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))

    S, W = x.shape[1], cfg.sliding_window
    kT, vT = k.swapaxes(1, 2), v.swapaxes(1, 2)         # (B, Hkv, S, hd)
    if W > 0 and S > W:
        # keep the last `window` tokens, ring-aligned: token t -> slot t % W
        kT = jnp.roll(kT[:, :, -W:], S % W, axis=2)
        vT = jnp.roll(vT[:, :, -W:], S % W, axis=2)
    cache = init_kv_cache(cfg, x.shape[0], max_len, cache_dtype)
    ck = jax.lax.dynamic_update_slice(cache.k, kT.astype(cache_dtype),
                                      (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, vT.astype(cache_dtype),
                                      (0, 0, 0, 0))
    return out, KVCache(k=ck, v=cv)


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> KVCache:
    S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, cfg.num_kv_heads, S, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def kv_cache_abstract(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> KVCache:
    S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, cfg.num_kv_heads, S, cfg.head_dim)
    return KVCache(k=jax.ShapeDtypeStruct(shape, dtype),
                   v=jax.ShapeDtypeStruct(shape, dtype))


KV_LOGICAL = KVCache(k=("kv_batch", "kv_heads", "kv_seq", "head_dim"),
                     v=("kv_batch", "kv_heads", "kv_seq", "head_dim"))


# ---------------------------------------------------------------------------
# paged decode cache (vLLM-style, XLA-native)
#
# The contiguous decode cache costs ~2 full-cache copies per step on top of
# the read (the per-layer dynamic-update-slice chain double-buffers through
# the scan).  Paged layout removes the write path entirely:
#   big: (B, Hkv, NP, page, hd)  — read-only pages; never an output
#   act: (B, Hkv, page, hd)      — the one page being written (donated)
# The step writes one token into `act`; every `page` steps the serving
# engine commits `act` into `big` with one amortized DUS.
# ---------------------------------------------------------------------------

class BigKV(NamedTuple):
    k: jax.Array          # (B, Hkv, NP, page, hd)
    v: jax.Array


class ActKV(NamedTuple):
    k: jax.Array          # (B, Hkv, page, hd)
    v: jax.Array


DEFAULT_PAGE = 512

BIG_LOGICAL = BigKV(k=("kv_batch", "kv_heads", "kv_pages", None, "head_dim"),
                    v=("kv_batch", "kv_heads", "kv_pages", None, "head_dim"))
ACT_LOGICAL = ActKV(k=("kv_batch", "kv_heads", None, "head_dim"),
                    v=("kv_batch", "kv_heads", None, "head_dim"))


def paged_cache_shapes(cfg: ArchConfig, batch: int, max_len: int,
                       page: int = DEFAULT_PAGE):
    S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    page = min(page, S)
    npages = -(-S // page)
    big = (batch, cfg.num_kv_heads, npages, page, cfg.head_dim)
    act = (batch, cfg.num_kv_heads, page, cfg.head_dim)
    return big, act


def init_paged_cache(cfg: ArchConfig, batch: int, max_len: int,
                     page: int = DEFAULT_PAGE, dtype=jnp.bfloat16,
                     abstract: bool = False):
    big, act = paged_cache_shapes(cfg, batch, max_len, page)
    mk = (lambda s: jax.ShapeDtypeStruct(s, dtype)) if abstract else \
        (lambda s: jnp.zeros(s, dtype))
    return (BigKV(k=mk(big), v=mk(big)), ActKV(k=mk(act), v=mk(act)))


def attention_decode_paged(params, x, pos, big: BigKV, act: ActKV,
                           cfg: ArchConfig):
    """One-step decode against a paged cache.  Returns (out, new act).

    `big` is read-only (pages < pos//page are valid); the new token's k/v
    land in `act` at slot pos % page.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(params, x, positions, cfg)     # q: (B,1,H,hd)
    page = act.k.shape[2]
    slot = pos % page
    a_k = jax.lax.dynamic_update_slice(
        act.k, k.swapaxes(1, 2).astype(act.k.dtype), (0, 0, slot, 0))
    a_v = jax.lax.dynamic_update_slice(
        act.v, v.swapaxes(1, 2).astype(act.v.dtype), (0, 0, slot, 0))

    Bq, Hkv, NP, pg, hd = big.k.shape
    page_start = (pos // page) * page

    H = q.shape[2]
    G = H // Hkv
    qh = q.reshape(B, Hkv, G, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    # pages stay an explicit einsum dim: the big cache may be sharded on
    # its page axis (seq-sharded decode) and a (NP, pg) -> S reshape would
    # force GSPMD to re-layout the whole cache every step.
    s_big = jnp.einsum("bngk,bnpsk->bngps", qh,
                       big.k.astype(qh.dtype)).astype(jnp.float32) * scale
    s_act = jnp.einsum("bngk,bnsk->bngs", qh,
                       a_k.astype(qh.dtype)).astype(jnp.float32) * scale
    pos_big = (jnp.arange(NP)[:, None] * pg + jnp.arange(pg)[None, :])
    s_big = jnp.where(pos_big[None, None, None] < page_start, s_big,
                      NEG_INF)
    s_act = jnp.where(jnp.arange(pg)[None, None, None] <=
                      (pos - page_start), s_act, NEG_INF)
    # joint softmax across pages + active page (flash-decode combine)
    m_big = jnp.max(s_big, axis=(-2, -1))
    m = jnp.maximum(jnp.max(s_act, axis=-1), m_big)           # (B,N,G)
    e_big = jnp.exp(s_big - m[..., None, None])
    e_act = jnp.exp(s_act - m[..., None])
    denom = (jnp.sum(e_big, axis=(-2, -1)) + jnp.sum(e_act, axis=-1))
    num = (jnp.einsum("bngps,bnpsk->bngk", e_big.astype(q.dtype),
                      big.v.astype(q.dtype)) +
           jnp.einsum("bngs,bnsk->bngk", e_act.astype(q.dtype),
                      a_v.astype(q.dtype)))
    out = num / denom[..., None].astype(q.dtype)
    out = out.reshape(B, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, ActKV(k=a_k, v=a_v)


def commit_page(big: BigKV, act: ActKV, pos) -> BigKV:
    """Write the filled active page into the big cache (amortized: called
    once every `page` steps by the serving engine; donate both)."""
    page = act.k.shape[2]
    pidx = pos // page
    return BigKV(
        k=jax.lax.dynamic_update_slice(
            big.k, act.k[:, :, None].astype(big.k.dtype), (0, 0, pidx, 0, 0)),
        v=jax.lax.dynamic_update_slice(
            big.v, act.v[:, :, None].astype(big.v.dtype), (0, 0, pidx, 0, 0)))


# ---------------------------------------------------------------------------
# paged slot pool (vLLM-style): per-row page tables over ONE shared pool
#
# The slot-pooled decode cache above still reserves a full max_len row per
# slot, so pool capacity is provisioned for the worst-case sequence.  The
# paged pool drops that: the cache is one shared bank of fixed-size pages
# (PagedKV), each request owns only the pages its own length needs, and a
# host-side page table maps a row's virtual positions onto pool pages.
# Page 0 is the PARK page: never allocated to a request and never read —
# dead table entries point at it (every table entry must be a valid pool
# index), and non-live rows' per-step writes are routed into it, which is
# what keeps a retired slot's stale writes from disturbing pages already
# recycled to a neighbor (the dual-port disturb-free invariant at page
# granularity).
# ---------------------------------------------------------------------------

class PagedKV(NamedTuple):
    """Shared page pool: virtual row position j*page+s of a request lives
    at ``pool[table[j], :, s]`` for that request's page table.

    ``ks``/``vs`` are the int8 bank's scale leaves ((NP, Hkv, page) f32,
    ``None`` for full-precision pools): when present, ``k``/``v`` hold
    symmetric-absmax int8 codes and the real value of pool entry
    ``[p, h, s, :]`` is ``k[p, h, s, :] * ks[p, h, s]`` — one scale per
    token per kv head, riding the same page table as the codes, so a
    single decoded token quantizes independently without rescaling its
    page."""
    k: jax.Array          # (NP, Hkv, page, hd) — cache dtype, or int8
    v: jax.Array
    ks: Any = None        # (NP, Hkv, page) f32 scales (int8 pools only)
    vs: Any = None


PARK_PAGE = 0

KV_QMAX = 127.0           # symmetric int8: codes in [-127, 127]

PAGED_LOGICAL = PagedKV(k=("kv_pages", "kv_heads", None, "head_dim"),
                        v=("kv_pages", "kv_heads", None, "head_dim"))


def init_page_pool(cfg: ArchConfig, num_pages: int, page: int,
                   dtype=jnp.bfloat16, abstract: bool = False,
                   quantized: bool = False) -> PagedKV:
    shape = (num_pages, cfg.num_kv_heads, page, cfg.head_dim)
    if quantized:
        sshape = shape[:-1]
        if abstract:
            return PagedKV(k=jax.ShapeDtypeStruct(shape, jnp.int8),
                           v=jax.ShapeDtypeStruct(shape, jnp.int8),
                           ks=jax.ShapeDtypeStruct(sshape, jnp.float32),
                           vs=jax.ShapeDtypeStruct(sshape, jnp.float32))
        return PagedKV(k=jnp.zeros(shape, jnp.int8),
                       v=jnp.zeros(shape, jnp.int8),
                       ks=jnp.zeros(sshape, jnp.float32),
                       vs=jnp.zeros(sshape, jnp.float32))
    if abstract:
        return PagedKV(k=jax.ShapeDtypeStruct(shape, dtype),
                       v=jax.ShapeDtypeStruct(shape, dtype))
    return PagedKV(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def quantize_kv(x):
    """Symmetric absmax int8 over the last axis: ``x (..., hd)`` ->
    ``(codes int8 (..., hd), scale f32 (...,))`` with
    ``x ~= codes * scale``.  One scale per token per head — the grain a
    token-at-a-time decode write can produce without touching the rest
    of its page."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / KV_QMAX
    q = jnp.clip(jnp.round(xf / scale[..., None]), -KV_QMAX, KV_QMAX)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# The contiguous per-row view of a paged bank: (NP, Hkv, page, hd) pool +
# (B, P) tables -> (B, Hkv, P*page, hd).  ONE definition, shared with the
# kernel package's oracle — the gathered values are elementwise what the
# row-cache layout holds at every written position, so the row attention
# math downstream is bitwise the row engine's (unwritten positions differ
# only in masked garbage).
from repro.kernels.paged_attention.ref import (  # noqa: E402
    gather_pages as _gather_pages, gather_scales as _gather_scales)


def _page_write(cache: PagedKV, k, v, tables, positions, wmask=None):
    """Scatter (B, K) token k/v into the shared pool.

    k/v: (B, K, Hkv, hd); tables: (B, P) int32; positions: (B, K) int32
    virtual positions; ``wmask`` ((B, K) bool, optional) routes False
    tokens' writes to the PARK page instead — pad tokens in a chunk, and
    non-live rows' per-step decode writes, land in garbage space without
    touching any request's pages.

    int8 pools (``cache.ks is not None``) quantize on write: each token's
    (Hkv, hd) k/v rows become int8 codes plus a per-head scale scattered
    into the parallel scale leaf at the same (page, head, slot)."""
    P = tables.shape[1]
    page = cache.k.shape[2]
    positions = jnp.asarray(positions, jnp.int32)
    pidx = jnp.minimum(positions // page, P - 1)    # clamp: parked rows
    pids = jnp.take_along_axis(tables, pidx, axis=1)
    if wmask is not None:
        pids = jnp.where(wmask, pids, PARK_PAGE)
    slots = positions % page
    if cache.ks is not None:
        kq, ksc = quantize_kv(k)                    # (B, K, Hkv, hd/)
        vq, vsc = quantize_kv(v)
        return PagedKV(k=cache.k.at[pids, :, slots, :].set(kq),
                       v=cache.v.at[pids, :, slots, :].set(vq),
                       ks=cache.ks.at[pids, :, slots].set(ksc),
                       vs=cache.vs.at[pids, :, slots].set(vsc))
    k_new = cache.k.at[pids, :, slots, :].set(k.astype(cache.k.dtype))
    v_new = cache.v.at[pids, :, slots, :].set(v.astype(cache.v.dtype))
    return PagedKV(k=k_new, v=v_new)


def _gather_dequant(cache: PagedKV, tables, dtype):
    """Reference read of an int8 bank: gather codes and scales through
    the tables, dequantize to ``dtype`` -> (kg, vg) (B, Hkv, P*page, hd).
    Unwritten positions hold code 0 (dequantizes to exact 0.0 — same
    masked-garbage story as the full-precision pool)."""
    kg = dequantize_kv(_gather_pages(cache.k, tables),
                       _gather_scales(cache.ks, tables), dtype)
    vg = dequantize_kv(_gather_pages(cache.v, tables),
                       _gather_scales(cache.vs, tables), dtype)
    return kg, vg


def attention_decode_pages(params, x, pos, cache: PagedKV, tables,
                           cfg: ArchConfig, wmask=None, shard=None):
    """One-step decode against the shared page pool.  x: (B, 1, D);
    pos: (B,) int32 (or scalar, broadcast); tables: (B, P) int32;
    ``wmask`` ((B,) bool, optional): False rows write to the park page
    (non-live slots must not disturb recycled pages).

    Write-then-read in the same order as ``attention_decode`` — the new
    token's k/v land in its page first, then attention reads the gathered
    pages under the same ``idx <= pos`` mask, so live rows' outputs are
    bitwise the row engine's.

    ``shard`` (``(mesh, axis)``, optional) switches to the shard_mapped
    local-read path: see ``attention_decode_pages_sharded``."""
    if shard is not None:
        return attention_decode_pages_sharded(params, x, pos, cache,
                                              tables, cfg, shard,
                                              wmask=wmask)
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None]
    q, k, v = _qkv(params, x, positions, cfg)     # q: (B,1,H,hd)
    cache = _page_write(cache, k, v, tables, positions,
                        wmask=None if wmask is None else wmask[:, None])

    import repro.kernels as kernels
    if kernels.use_kernels():
        from repro.kernels.paged_attention.ops import paged_decode_attention
        interp = None if kernels.get_mode() == "auto" else True
        out = paged_decode_attention(q[:, 0], cache.k, cache.v, tables,
                                     pos, k_scale=cache.ks,
                                     v_scale=cache.vs,
                                     interpret=interp)[:, None]
    elif cache.ks is not None:
        kg, vg = _gather_dequant(cache, tables, x.dtype)
        valid = jnp.arange(kg.shape[2])[None, :] <= pos[:, None]
        out = decode_sdpa(q, kg, vg, valid, cfg)
    else:
        kg = _gather_pages(cache.k, tables)
        vg = _gather_pages(cache.v, tables)
        valid = jnp.arange(kg.shape[2])[None, :] <= pos[:, None]
        out = decode_sdpa(q, kg, vg, valid, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, cache


def attention_verify_pages(params, x, pos, cache: PagedKV, tables,
                           cfg: ArchConfig, wmask=None, offsets=None,
                           tree=None, shard=None):
    """Multi-token verify/chunk decode against the shared page pool.

    x: (B, K, D) block tokens at positions ``pos[b] .. pos[b]+K-1``;
    attention reads the pool as it stood BEFORE the block (through the
    page table) plus the block's own k/v under an intra-block causal
    mask — the same cache-plus-block split as ``attention_verify`` — then
    all K tokens' k/v are scattered into the row's pages (``wmask`` pads
    route to the park page).  No fresh-row zeroing is needed: a page is
    written by its owner before any of its positions become readable
    (reads mask ``cols < pos``), so a recycled page's stale content can
    never leak into a new request.

    Tree verification: ``offsets`` ((K,) int32, optional) replaces the
    default ``arange(K)`` position offsets with per-node tree depths
    (RoPE and write slots), and ``tree`` ((B, K) int32 ancestor
    bitmasks) replaces the intra-block causal mask — bit j of
    ``tree[b, i]`` makes block token j visible to block query i.
    Sibling branches share a depth, so the caller MUST park all but one
    writer per depth through ``wmask`` (the scatter has one slot per
    position).

    ``shard`` (``(mesh, axis)``, optional) switches to the shard_mapped
    local-read path: see ``attention_verify_pages_sharded``."""
    if shard is not None:
        return attention_verify_pages_sharded(params, x, pos, cache,
                                              tables, cfg, shard,
                                              wmask=wmask, offsets=offsets,
                                              tree=tree)
    B, K, _ = x.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    if offsets is None:
        offsets = jnp.arange(K, dtype=jnp.int32)
    positions = pos[:, None] + jnp.asarray(offsets, jnp.int32)[None]
    q, k, v = _qkv(params, x, positions, cfg)     # q: (B,K,H,hd)

    import repro.kernels as kernels
    if kernels.use_kernels():
        from repro.kernels.paged_attention.ops import paged_verify_attention
        interp = None if kernels.get_mode() == "auto" else True
        out = paged_verify_attention(q, cache.k, cache.v, k, v, tables,
                                     pos, k_scale=cache.ks,
                                     v_scale=cache.vs, tree=tree,
                                     interpret=interp)
    elif cache.ks is not None:
        from repro.kernels.verify_attention.ref import verify_reference
        kg, vg = _gather_dequant(cache, tables, x.dtype)
        out = verify_reference(q, kg, vg, k, v, pos, ring=False, tree=tree)
    else:
        from repro.kernels.verify_attention.ref import verify_reference
        kg = _gather_pages(cache.k, tables)
        vg = _gather_pages(cache.v, tables)
        out = verify_reference(q, kg, vg, k, v, pos, ring=False, tree=tree)

    cache = _page_write(cache, k, v, tables, positions, wmask=wmask)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, cache


# ---------------------------------------------------------------------------
# sharded page bank: per-shard LOCAL reads under shard_map
#
# The functions above gather the WHOLE bank through the page table — under
# a mesh that is an all-gather of every shard's slice per step.  The
# sharded paths below shard_map attention instead: each mesh shard holds
# local pages [s*L, (s+1)*L) of the bank (L = NP/num_shards), recovers its
# local index as ``table - s*L``, reads/writes ONLY entries it owns, and
# the per-shard unnormalized flash partials (acc, m, l) merge with one
# pmax/psum.  The merged softmax is mathematically the global one, but the
# reduction ORDER differs from the single-gather path, so local-read
# outputs are allclose-, not bitwise-, equivalent (the engine keeps the
# global-gather path as its bitwise default).  Out-of-slice writes land in
# the shard's own reserved local page 0 (``ShardedPagePool`` never
# allocates any shard's local page 0), so no write crosses shards either —
# the paper's dual-port disturb-free argument at rack scale.
# ---------------------------------------------------------------------------

def _local_pages(tables, num_local: int, axis: str):
    """This shard's view of the (B, P) page table, inside shard_map:
    -> (local_table, owned) where ``owned`` marks entries whose page
    lives on this shard and ``local_table`` holds their local indices
    (everything else points at the shard's local park page 0)."""
    base = jax.lax.axis_index(axis) * num_local
    lt = tables - base
    owned = (lt >= 0) & (lt < num_local)
    return jnp.where(owned, lt, PARK_PAGE), owned


def _paged_partial(q, kg, vg, valid, scale):
    """Unnormalized flash partial over ONE gathered bank slice.

    q: (B, K, H, hd); kg/vg: (B, Hkv, S, hd); valid: (B, K, S) bool (a
    broadcastable (B, 1, S) is fine).  -> (acc (B, Hkv, K, G, hd) f32,
    m, l (B, Hkv, K, G) f32).  ``NEG_INF`` is finite, so a fully-masked
    row has ``m == NEG_INF`` and ``exp(s - m) == 1`` there — the
    explicit re-mask of ``p`` (not just ``s``) is what keeps that row's
    l/acc at exact 0.0 so the cross-shard combine ignores it."""
    B, K, H, hd = q.shape
    Hkv = kg.shape[1]
    G = H // Hkv
    qh = (q.reshape(B, K, Hkv, G, hd).transpose(0, 2, 1, 3, 4)
          .astype(jnp.float32))
    s = jnp.einsum("bnigd,bnsd->bnigs", qh, kg.astype(jnp.float32)) * scale
    vmask = valid[:, None, :, None, :]
    s = jnp.where(vmask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(vmask, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bnigs,bnsd->bnigd", p, vg.astype(jnp.float32))
    return acc, m, l


def _psum_partials(acc, m, l, axis: str):
    """Merge per-shard flash partials across mesh axis ``axis`` —
    rescale every shard's (acc, l) to the global running max, then sum.
    Returns the still-unnormalized (acc, m, l), replicated."""
    mg = jax.lax.pmax(m, axis)
    w = jnp.exp(m - mg)
    return (jax.lax.psum(acc * w[..., None], axis), mg,
            jax.lax.psum(l * w, axis))


def _fold_block(acc, m, l, qh, kb, vb, scale, tree):
    """Fold the verify block's own K keys/values — replicated, identical
    on every shard — into a combined cache partial, then normalize.
    qh: (B, Hkv, K, G, hd) f32; kb/vb: (B, K, Hkv, hd); ``tree``
    ((B, K) int32 ancestor bitmasks) replaces the intra-block causal
    mask.  Exact flash fold: together with ``_psum_partials`` this is
    ``verify_reference``'s joint softmax in a different reduction
    order."""
    kbh = kb.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B, Hkv, K, hd)
    vbh = vb.astype(jnp.float32).transpose(0, 2, 1, 3)
    K = kbh.shape[2]
    s = jnp.einsum("bnigd,bnjd->bnigj", qh, kbh) * scale
    if tree is None:
        ii = jnp.arange(K, dtype=jnp.int32)
        keep = (ii[None, :] <= ii[:, None])[None, None, :, None, :]
    else:
        t = jnp.asarray(tree, jnp.int32)
        keep = (((t[:, :, None] >> jnp.arange(K, dtype=jnp.int32)) & 1)
                == 1)[:, None, :, None, :]
    s = jnp.where(keep, s, NEG_INF)
    m2 = jnp.maximum(m, jnp.max(s, axis=-1))
    pb = jnp.where(keep, jnp.exp(s - m2[..., None]), 0.0)
    l2 = l * jnp.exp(m - m2) + jnp.sum(pb, axis=-1)
    acc2 = (acc * jnp.exp(m - m2)[..., None]
            + jnp.einsum("bnigj,bnjd->bnigd", pb, vbh))
    return acc2 / jnp.maximum(l2, 1e-30)[..., None]


def _heads_out(out, dt):
    """(B, Hkv, K, G, hd) f32 merged partial -> (B, K, H, hd) in the
    activation dtype."""
    out = out.transpose(0, 2, 1, 3, 4)
    return out.reshape(out.shape[0], out.shape[1], -1,
                       out.shape[-1]).astype(dt)


def attention_decode_pages_sharded(params, x, pos, cache: PagedKV, tables,
                                   cfg: ArchConfig, shard, wmask=None):
    """``attention_decode_pages`` with the bank sharded over mesh axis
    ``shard = (mesh, axis)``: each shard writes/reads only its local
    slice (local Pallas partial kernel when kernels are on, jnp partial
    otherwise) and the per-shard flash partials merge with one
    pmax/psum.  Allclose — not bitwise — to the global-gather path (the
    merge changes the softmax reduction order)."""
    mesh, axis = shard
    from jax.sharding import PartitionSpec as Ps
    from repro.distributed.compat import shard_map

    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None]
    q, k, v = _qkv(params, x, positions, cfg)     # q: (B,1,H,hd)
    quant = cache.ks is not None
    bank = ((cache.k, cache.v, cache.ks, cache.vs) if quant
            else (cache.k, cache.v))
    tables = jnp.asarray(tables, jnp.int32)
    P = tables.shape[1]
    page = cache.k.shape[2]
    scale = 1.0 / (cfg.head_dim ** 0.5)
    dt = x.dtype
    wm = (jnp.ones((B, 1), bool) if wmask is None
          else jnp.asarray(wmask, bool)[:, None])

    def local(bank, q, k, v, tables, pos, wm):
        lc = PagedKV(*bank)
        lt, owned = _local_pages(tables, lc.k.shape[0], axis)
        positions = pos[:, None]
        pidx = jnp.minimum(positions // page, P - 1)
        own_tok = jnp.take_along_axis(owned, pidx, axis=1)   # (B, 1)
        # write first (same order as the unsharded path); out-of-slice
        # tokens park into THIS shard's reserved local page 0
        lc = _page_write(lc, k, v, lt, positions, wmask=own_tok & wm)

        import repro.kernels as kernels
        if kernels.use_kernels():
            from repro.kernels.paged_attention.ops import (
                paged_decode_partial)
            interp = None if kernels.get_mode() == "auto" else True
            base = jax.lax.axis_index(axis) * lc.k.shape[0]
            acc, m, l = paged_decode_partial(
                q[:, 0], lc.k, lc.v, tables, pos, base,
                k_scale=lc.ks, v_scale=lc.vs, interpret=interp)
            acc, m, l = acc[:, :, None], m[:, :, None], l[:, :, None]
        else:
            if lc.ks is not None:
                kg, vg = _gather_dequant(lc, lt, dt)
            else:
                kg = _gather_pages(lc.k, lt)
                vg = _gather_pages(lc.v, lt)
            own_pos = jnp.repeat(owned, page, axis=1)        # (B, S)
            valid = ((jnp.arange(kg.shape[2])[None, :] <= pos[:, None])
                     & own_pos)[:, None, :]                  # (B, 1, S)
            acc, m, l = _paged_partial(q, kg, vg, valid, scale)
        accg, mg, lg = _psum_partials(acc, m, l, axis)
        out = accg / jnp.maximum(lg, 1e-30)[..., None]
        return out, tuple(lc)[:len(bank)]

    bank_specs = tuple(Ps(axis) for _ in bank)
    f = shard_map(local, mesh=mesh,
                  in_specs=(bank_specs, Ps(), Ps(), Ps(), Ps(), Ps(),
                            Ps()),
                  out_specs=(Ps(), bank_specs), check_vma=False)
    out, bank = f(bank, q, k, v, tables, pos, wm)
    cache = PagedKV(*bank)
    out = _heads_out(out, dt)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return out, cache


def attention_verify_pages_sharded(params, x, pos, cache: PagedKV, tables,
                                   cfg: ArchConfig, shard, wmask=None,
                                   offsets=None, tree=None):
    """``attention_verify_pages`` with per-shard local bank reads (see
    ``attention_decode_pages_sharded``).  The cache side of the
    cache-plus-block split runs as per-shard partials merged with
    pmax/psum; the block's own K keys/values are replicated, so their
    fold — and the intra-block causal/tree mask — happens once outside
    the shard_map.  Allclose, not bitwise, to the global-gather path."""
    mesh, axis = shard
    from jax.sharding import PartitionSpec as Ps
    from repro.distributed.compat import shard_map

    B, K, _ = x.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    if offsets is None:
        offsets = jnp.arange(K, dtype=jnp.int32)
    positions = pos[:, None] + jnp.asarray(offsets, jnp.int32)[None]
    q, k, v = _qkv(params, x, positions, cfg)     # q: (B,K,H,hd)
    quant = cache.ks is not None
    bank = ((cache.k, cache.v, cache.ks, cache.vs) if quant
            else (cache.k, cache.v))
    tables = jnp.asarray(tables, jnp.int32)
    P = tables.shape[1]
    page = cache.k.shape[2]
    scale = 1.0 / (cfg.head_dim ** 0.5)
    dt = x.dtype
    wm = (jnp.ones((B, K), bool) if wmask is None
          else jnp.asarray(wmask, bool))

    def local(bank, q, k, v, tables, positions, pos, wm):
        lc = PagedKV(*bank)
        lt, owned = _local_pages(tables, lc.k.shape[0], axis)
        # cache side reads the pool as it stood BEFORE the block
        if lc.ks is not None:
            kg, vg = _gather_dequant(lc, lt, dt)
        else:
            kg = _gather_pages(lc.k, lt)
            vg = _gather_pages(lc.v, lt)
        own_pos = jnp.repeat(owned, page, axis=1)
        valid = ((jnp.arange(kg.shape[2])[None, :] < pos[:, None])
                 & own_pos)[:, None, :]                      # (B, 1, S)
        acc, m, l = _paged_partial(q, kg, vg, valid, scale)
        parts = _psum_partials(acc, m, l, axis)
        pidx = jnp.minimum(positions // page, P - 1)
        own_tok = jnp.take_along_axis(owned, pidx, axis=1)   # (B, K)
        lc = _page_write(lc, k, v, lt, positions, wmask=own_tok & wm)
        return parts, tuple(lc)[:len(bank)]

    bank_specs = tuple(Ps(axis) for _ in bank)
    f = shard_map(local, mesh=mesh,
                  in_specs=(bank_specs, Ps(), Ps(), Ps(), Ps(), Ps(),
                            Ps(), Ps()),
                  out_specs=((Ps(), Ps(), Ps()), bank_specs),
                  check_vma=False)
    (accg, mg, lg), bank = f(bank, q, k, v, tables, positions, pos, wm)
    cache = PagedKV(*bank)
    Hkv = cfg.num_kv_heads
    hd = cfg.head_dim
    qh = (q.reshape(B, K, Hkv, -1, hd).transpose(0, 2, 1, 3, 4)
          .astype(jnp.float32)) * scale
    out = _fold_block(accg, mg, lg, qh, k, v, 1.0, tree)
    out = _heads_out(out, dt)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return out, cache


def insert_pages(cache: PagedKV, rows: KVCache, tables) -> PagedKV:
    """Admission: scatter freshly prefilled cache rows (B, Hkv, S, hd)
    into the shared pool through (B, P) page tables (S == P*page).  Dead
    table entries (past a row's allocation) point at the park page, so
    the unconditional all-P scatter parks the rows' zero tails instead of
    touching anyone's pages.  Only the named pages change — the same
    disturb-free contract as ``LM.insert_cache_rows``."""
    B, Hkv, S, hd = rows.k.shape
    P = tables.shape[1]
    page = cache.k.shape[2]
    assert S == P * page, (S, P, page)

    def paged_view(r):
        return (r.reshape(B, Hkv, P, page, hd)
                .transpose(0, 2, 1, 3, 4))          # (B, P, Hkv, page, hd)

    if cache.ks is not None:                        # quantize on insert
        kq, ksc = quantize_kv(paged_view(rows.k))
        vq, vsc = quantize_kv(paged_view(rows.v))
        return PagedKV(k=cache.k.at[tables].set(kq),
                       v=cache.v.at[tables].set(vq),
                       ks=cache.ks.at[tables].set(ksc),
                       vs=cache.vs.at[tables].set(vsc))

    def scatter(pool, r):
        return pool.at[tables].set(paged_view(r).astype(pool.dtype))

    return PagedKV(k=scatter(cache.k, rows.k), v=scatter(cache.v, rows.v))


def copy_pages(cache: PagedKV, src, dst) -> PagedKV:
    """Device-side page copy: ``pool[dst[i]] = pool[src[i]]`` for every
    leaf of the bank (codes AND scales for an int8 pool — the copy is a
    byte copy, never a re-quantization).  src/dst: (n,) int32 page ids.

    This is the copy-on-write primitive of prefix sharing: a request
    that diverges mid-page gets a private copy of the shared boundary
    page BEFORE its first write, so shared pages are never mutated and
    every reader keeps seeing bitwise the values its cold admission
    would have produced."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cp(pool):
        return None if pool is None else pool.at[dst].set(pool[src])

    return PagedKV(k=cp(cache.k), v=cp(cache.v),
                   ks=cp(cache.ks), vs=cp(cache.vs))


def attention_decode(params, x, pos, cache: KVCache, cfg: ArchConfig):
    """One-step decode.  x: (B, 1, D); pos: scalar int32 (whole batch at
    one position — the run-to-completion loop) or (B,) int32 (continuous
    batching: every row is at its own position).

    Full-attention: cache length == max_len, slot = pos.
    Sliding-window: cache length == window (ring), slot = pos % window.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(params, x, positions, cfg)     # q: (B,1,H,hd)
    S = cache.k.shape[2]
    slot = pos % S if cfg.sliding_window > 0 else pos
    kT = k.swapaxes(1, 2).astype(cache.k.dtype)   # (B, Hkv, 1, hd)
    vT = v.swapaxes(1, 2).astype(cache.v.dtype)
    if per_row:
        # per-row write slot: scatter one token into each row's cache line
        rows = jnp.arange(B)
        slot = jnp.minimum(slot, S - 1)           # freed slots park at S-1
        k_new = cache.k.at[rows, :, slot, :].set(kT[:, :, 0, :])
        v_new = cache.v.at[rows, :, slot, :].set(vT[:, :, 0, :])
    else:
        k_new = jax.lax.dynamic_update_slice(cache.k, kT, (0, 0, slot, 0))
        v_new = jax.lax.dynamic_update_slice(cache.v, vT, (0, 0, slot, 0))

    import repro.kernels as kernels
    if kernels.use_kernels():
        from repro.kernels.decode_attention.ops import decode_attention
        interp = None if kernels.get_mode() == "auto" else True
        ring = cfg.sliding_window > 0
        out = decode_attention(q[:, 0], k_new, v_new, pos, ring=ring,
                               interpret=interp)[:, None]
    else:
        idx = jnp.arange(S)
        pv = pos[:, None] if per_row else pos     # broadcast -> (B,S) / (S,)
        if cfg.sliding_window > 0:
            valid = (idx <= pv % S) | (pv >= S)   # ring not yet full -> mask
        else:
            valid = idx <= pv
        out = decode_sdpa(q, k_new, v_new, valid, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, KVCache(k=k_new, v=v_new)


def attention_verify(params, x, pos, cache: KVCache, cfg: ArchConfig,
                     wmask=None):
    """Multi-token verify decode (speculative decode's target pass).

    x: (B, K, D) — the K block tokens per row, at positions
    ``pos[b] .. pos[b]+K-1`` (``pos``: scalar or (B,) int32).  Attention
    reads the cache as it stood BEFORE this block plus the block's own
    keys/values under an intra-block causal mask, so token i sees exactly
    the state the i-th sequential ``attention_decode`` step would have
    seen — loop-exact even across a ring wraparound (where write-then-mask
    is not: a later token's write lands on a slot an earlier query must
    still read).  All K tokens' k/v are then written.  Returns
    (out (B, K, D), new cache).

    ``wmask`` ((B, K) bool, optional) gates the cache WRITES only: a
    False token computes normally but leaves its cache slot untouched.
    Chunked prefill pads its last chunk to a fixed width with trailing
    tokens — pads sit at the block's end, so no real token attends to
    them, and the write mask keeps their k/v out of the cache.
    """
    B, K, _ = x.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(K, dtype=jnp.int32)[None]
    q, k, v = _qkv(params, x, positions, cfg)     # q: (B,K,H,hd)
    S = cache.k.shape[2]
    ring = cfg.sliding_window > 0

    import repro.kernels as kernels
    if kernels.use_kernels():
        from repro.kernels.verify_attention.ops import verify_attention
        interp = None if kernels.get_mode() == "auto" else True
        out = verify_attention(q, cache.k, cache.v, k, v, pos, ring=ring,
                               interpret=interp)
    else:
        from repro.kernels.verify_attention.ref import verify_reference
        out = verify_reference(q, cache.k, cache.v, k, v, pos, ring=ring)

    # write the block: slot = position (% S for rings); parked/retired rows
    # clamp at S-1 — their rows are dead and fully rewritten at the next
    # admission, so the duplicate clamped writes are harmless
    slots = positions % S if ring else jnp.minimum(positions, S - 1)
    rows = jnp.arange(B)[:, None]
    kw, vw = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
    if wmask is not None:
        # masked tokens write back what the slot already holds
        m = wmask[:, :, None, None]
        kw = jnp.where(m, kw, cache.k[rows, :, slots])
        vw = jnp.where(m, vw, cache.v[rows, :, slots])
    k_new = cache.k.at[rows, :, slots].set(kw)
    v_new = cache.v.at[rows, :, slots].set(vw)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, KVCache(k=k_new, v=v_new)


def decode_sdpa(q, k_cache, v_cache, valid, cfg: ArchConfig):
    """q: (B,1,H,hd); caches: (B,Hkv,S,hd); valid: (S,) or (B,S) bool."""
    B, _, H, hd = q.shape
    Hkv = k_cache.shape[1]
    G = H // Hkv
    qh = q.reshape(B, Hkv, G, hd)
    scores = jnp.einsum("bngk,bnsk->bngs", qh,
                        k_cache.astype(qh.dtype)).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if valid.ndim == 1:
        valid = valid[None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngs,bnsk->bngk", probs, v_cache.astype(q.dtype))
    return out.reshape(B, 1, H, hd)
