"""Gradient compression for cross-pod (DCN) all-reduce.

int8 quantization with a **shared scale** + **error feedback**:

  1. ``pmax`` the per-tensor absmax over the pod axis (scalar collective)
  2. quantize locally to int8 with the shared scale
  3. ``psum`` the int8 payload in int16 lanes (exact for <= 256 pods:
     |sum| <= 127 * 256 < 2^15) — 2x wire bytes vs f32; the quantization
     itself is 8-bit so a packed transport would reach 4x, noted in
     DESIGN.md
  4. dequantize once; the local quantization residual is carried into the
     next step's gradient (error feedback — keeps SGD convergence, cf.
     Karimireddy et al. 2019)

Must run inside shard_map with the reduction axis manual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_shared(x: jax.Array, axis: str) -> tuple[jax.Array, jax.Array]:
    """int8 quantization with an axis-shared symmetric scale."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(tree, axis: str, ef_tree):
    """Mean-allreduce `tree` over `axis` with int8 EF compression.

    Returns (reduced_tree, new_ef_tree); dtypes of `tree` preserved.
    """
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)

    def one(g, ef):
        g32 = g.astype(jnp.float32) + ef
        q, scale = quantize_shared(g32, axis)
        new_ef = g32 - q.astype(jnp.float32) * scale   # residual stays local
        total = jax.lax.psum(q.astype(jnp.int16), axis)  # compressed wire
        red = total.astype(jnp.float32) * scale / n
        return red.astype(g.dtype), new_ef

    flat_g, treedef = jax.tree.flatten(tree)
    flat_e = treedef.flatten_up_to(ef_tree)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def wire_bytes_saved(tree) -> tuple[int, int]:
    """(f32 bytes, compressed bytes) for reporting."""
    f32 = sum(x.size * 4 for x in jax.tree.leaves(tree))
    comp = sum(x.size * 2 for x in jax.tree.leaves(tree))
    return f32, comp
