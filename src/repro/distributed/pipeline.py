"""Pipeline parallelism: GPipe-style microbatch pipeline over a `pipe` mesh
axis using shard_map + collective_permute.

Not used by the assignment's production mesh (which is (pod, data, model)),
but required for 1000+-node scale where a model no longer fits a single
model-parallel group; tested on small CPU meshes.

The schedule is the classic "loop over (microbatches + stages - 1) ticks"
pipeline: at tick t, stage s processes microbatch t - s; activations hop
stage->stage+1 with ppermute.  Bubble fraction = (S-1)/(M+S-1).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map


def pipeline_apply(stage_fn: Callable, mesh: Mesh, params_stacked, x,
                   num_microbatches: int, axis: str = "pipe"):
    """Run ``y = stage_fn(params_s, x)`` through S pipeline stages.

    params_stacked: pytree with leading stage axis (sharded over `axis`).
    x: (B, ...) batch; B must divide by num_microbatches.
    """
    S = mesh.shape[axis]
    M = num_microbatches
    B = x.shape[0]
    assert B % M == 0

    def body(params_local, x_local):
        # params_local: stage params (leading axis 1 after sharding) on this
        # stage; x_local: full microbatch set (replicated batch).
        params_me = jax.tree.map(lambda p: p[0], params_local)
        idx = jax.lax.axis_index(axis)
        mbs = x_local.reshape((M, B // M) + x_local.shape[1:])
        buf = jnp.zeros_like(mbs[0])            # stage input register
        outs = jnp.zeros_like(mbs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t; others take the permuted value
            take = jnp.clip(t, 0, M - 1)
            buf = jnp.where(idx == 0, mbs[take], buf)
            y = stage_fn(params_me, buf)
            # last stage records its output for microbatch t - (S-1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            record = jnp.logical_and(idx == S - 1, t >= S - 1)
            outs = jax.lax.cond(
                record,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0),
                lambda o: o, outs)
            # hop: stage s -> s+1 (ring permute; stage S-1 -> 0 discarded)
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(M + S - 1))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape((B,) + x_local.shape[1:])

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P()),
                   out_specs=P(), axis_names={axis}, check_vma=False)
    return fn(params_stacked, x)
