from repro.distributed.mesh import (
    AXIS_POD, AXIS_DATA, AXIS_MODEL, make_mesh, mesh_axis_size, batch_spec,
)
from repro.distributed.sharding import (
    ShardingRules, DEFAULT_RULES, logical_to_spec, spec_for, shard_params_tree,
)
