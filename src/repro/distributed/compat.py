"""JAX version compatibility shims (hermetic images pin older releases).

``shard_map`` graduated from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to ``jax.shard_map`` (kwargs ``axis_names`` /
``check_vma``).  This adapter exposes the new-style signature on both.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh=None, in_specs=None, out_specs=None,
              axis_names=None, check_vma=None):
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = bool(check_vma)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
