"""Mesh construction and axis conventions.

Axis semantics (assignment-fixed production mesh):
  pod   — data parallelism across pods (DCN-connected; gradient all-reduce
          only, optionally int8-compressed)
  data  — within-pod data parallelism + FSDP param sharding
  model — tensor / expert / sequence(-kv) parallelism over ICI
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """A mesh over however many devices are available (tests/dev)."""
    ndev = math.prod(shape)
    devices = np.asarray(jax.devices()[:ndev]).reshape(shape)
    return Mesh(devices, axes)


def mesh_axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 1


def batch_spec(mesh: Mesh) -> P:
    """Activation batch axis spec: ('pod','data') when a pod axis exists."""
    if AXIS_POD in mesh.axis_names:
        return P((AXIS_POD, AXIS_DATA))
    return P(AXIS_DATA)


def data_axis_names(mesh: Mesh) -> tuple[str, ...]:
    return ((AXIS_POD, AXIS_DATA) if AXIS_POD in mesh.axis_names
            else (AXIS_DATA,))
