"""Logical-axis sharding rules (MaxText-style).

Every parameter in the model zoo is annotated with a tuple of *logical* axis
names (one per dim).  ``ShardingRules`` maps logical axes to mesh axes; the
mapping is arch/run-overridable, which is how the perf hillclimbs change
sharding without touching model code.

A logical axis maps to: a mesh axis name, a tuple of mesh axes, or None
(replicated).  ``logical_to_spec`` drops mappings whose mesh axis does not
exist in the current mesh or does not divide the dim size — so the same model
code runs on a 1-device CPU test mesh and the 512-chip production mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD

Axis = Optional[Union[str, tuple[str, ...]]]


@dataclass(frozen=True)
class ShardingRules:
    rules: dict[str, Axis] = field(default_factory=dict)

    def with_(self, **kw) -> "ShardingRules":
        r = dict(self.rules)
        r.update(kw)
        return ShardingRules(r)

    def __getitem__(self, k: str) -> Axis:
        return self.rules.get(k)


# Default production rules: TP over `model`, FSDP over `data`, batch over
# ('pod','data'), EP over `model`.
DEFAULT_RULES = ShardingRules({
    # activations
    "batch": (AXIS_POD, AXIS_DATA),
    "act_seq": None,
    "act_heads": AXIS_MODEL,
    "act_embed": None,
    "act_ffn": AXIS_MODEL,
    # attention-score q dim: fallback shard when the head count does not
    # divide the model axis (starcoder2: 36 heads on a 16-wide axis) —
    # _axis_ok's used-set keeps it a no-op whenever act_heads applied.
    "act_attn_q": AXIS_MODEL,
    # params — attention
    "embed": AXIS_DATA,            # FSDP axis on the d_model dim
    "heads": AXIS_MODEL,           # TP on the (q|kv) head dim
    "kv_heads": AXIS_MODEL,
    "head_dim": None,
    # params — mlp
    "ffn": AXIS_MODEL,
    # params — embedding table / lm head
    "vocab": AXIS_MODEL,
    # params — moe
    "experts": AXIS_MODEL,         # EP
    "expert_ffn": None,
    # params — ssm / xlstm inner dims
    "ssm_inner": AXIS_MODEL,
    "ssm_state": None,
    "conv_width": None,
    # scanned-layer leading axis is never sharded
    "layers": None,
    # KV-cache decode sharding
    "kv_batch": (AXIS_POD, AXIS_DATA),
    "kv_seq": None,                # flipped to `model` for seq-sharded decode
    "kv_pages": None,              # paged cache: page-axis analogue of kv_seq
})


def _axis_ok(mesh: Mesh, axis: Axis, dim: int, used: set[str]) -> Axis:
    """Keep only mesh axes that exist, are unused in this spec, and divide."""
    if axis is None:
        return None
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    keep = []
    size = 1
    for a in axes:
        if a not in mesh.shape or a in used:
            continue
        if dim % (size * mesh.shape[a]) != 0:
            continue
        keep.append(a)
        size *= mesh.shape[a]
    if not keep:
        return None
    if isinstance(axis, str):
        return keep[0]
    return tuple(keep)     # tuple rule stays a tuple (P equality on old jax)


def logical_to_spec(mesh: Mesh, logical: tuple[str, ...],
                    shape: tuple[int, ...],
                    rules: ShardingRules = DEFAULT_RULES) -> P:
    assert len(logical) == len(shape), (logical, shape)
    used: set[str] = set()
    out = []
    for name, dim in zip(logical, shape):
        ax = _axis_ok(mesh, rules[name], dim, used)
        if ax is not None:
            used.update((ax,) if isinstance(ax, str) else ax)
        out.append(ax)
    return P(*out)


def spec_for(mesh: Mesh, logical: tuple[str, ...], shape: tuple[int, ...],
             rules: ShardingRules = DEFAULT_RULES) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(mesh, logical, shape, rules))


def shard_params_tree(mesh: Mesh, params: Any, logical_tree: Any,
                      rules: ShardingRules = DEFAULT_RULES) -> Any:
    """NamedSharding pytree matching `params` from its logical-axis pytree.

    `params` may contain jax.Arrays or ShapeDtypeStructs.
    """
    def one(p, l):
        return spec_for(mesh, tuple(l), tuple(p.shape), rules)
    return jax.tree.map(one, params, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, str) or e is None for e in x))


def constrain(x, mesh: Mesh, logical: tuple[str, ...],
              rules: ShardingRules = DEFAULT_RULES):
    """Activation sharding constraint by logical axes (no-op off-mesh dims)."""
    spec = logical_to_spec(mesh, logical, x.shape, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
