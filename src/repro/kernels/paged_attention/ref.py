"""Pure-jnp oracle for paged attention: the KV cache rows live as pages
of one shared pool, addressed through a per-row page table.

Layout:
  * ``k_pages``/``v_pages`` — (NP, Hkv, page, hd): the shared pool.
    Page 0 is conventionally the PARK page (never read; dead page-table
    entries point at it so every table entry is a valid pool index).
  * ``page_table`` — (B, P) int32: row b's virtual positions
    ``[j*page, (j+1)*page)`` live in pool page ``page_table[b, j]``.
  * ``pos`` — (B,) int32 (or scalar, broadcast).

The oracle simply *gathers* each row's pages back into a contiguous
(B, Hkv, P*page, hd) row bank and defers to the proven row oracles —
``decode_reference`` for the one-token case and ``verify_reference``
(ring=False; paged pools are full-attention only) for the K-token
verify/chunk case.  Gathering makes the equivalence the tests assert
literal: a paged cache read through its table IS the row cache.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_reference
from repro.kernels.verify_attention.ref import verify_reference


def gather_pages(pages, page_table):
    """(NP, Hkv, page, hd) pool + (B, P) table -> (B, Hkv, P*page, hd)
    contiguous per-row cache (virtual position j*page+s = page slot s of
    table entry j)."""
    g = pages[jnp.asarray(page_table, jnp.int32)]   # (B, P, Hkv, page, hd)
    B, P, Hkv, page, hd = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, P * page, hd)


def gather_scales(scales, page_table):
    """(NP, Hkv, page) int8-bank scale leaf + (B, P) table ->
    (B, Hkv, P*page) per-position scales — ``gather_pages`` minus the
    head-dim axis, so a gathered int8 row dequantizes elementwise as
    ``codes * scales[..., None]``."""
    g = scales[jnp.asarray(page_table, jnp.int32)]  # (B, P, Hkv, page)
    B, P, Hkv, page = g.shape
    return g.transpose(0, 2, 1, 3).reshape(B, Hkv, P * page)


def _dequant(pages, scales, page_table):
    codes = gather_pages(pages, page_table)
    s = gather_scales(scales, page_table)
    return codes.astype(jnp.float32) * s[..., None]


def paged_decode_reference(q, k_pages, v_pages, page_table, pos, *,
                           scale: float | None = None,
                           k_scale=None, v_scale=None):
    """q: (B, H, hd) -> (B, H, hd); see module docstring for layouts.
    ``k_scale``/``v_scale`` ((NP, Hkv, page) f32) mark an int8 bank:
    codes are dequantized after the gather, then the row oracle runs
    unchanged."""
    if k_scale is not None:
        k = _dequant(k_pages, k_scale, page_table)
        v = _dequant(v_pages, v_scale, page_table)
    else:
        k = gather_pages(k_pages, page_table)
        v = gather_pages(v_pages, page_table)
    return decode_reference(q, k, v, pos, ring=False, scale=scale)


def paged_verify_reference(q, k_pages, v_pages, blk_k, blk_v, page_table,
                           pos, *, scale: float | None = None,
                           k_scale=None, v_scale=None, tree=None):
    """q: (B, K, H, hd); blk_k/blk_v: (B, K, Hkv, hd) block keys/values;
    the pool holds the cache BEFORE the block's writes -> (B, K, H, hd).
    ``k_scale``/``v_scale`` dequantize an int8 bank (the block k/v stay
    full precision — they have not been written yet).  ``tree``
    ((B, K) int32 ancestor bitmasks) selects per-row tree visibility in
    place of the intra-block causal mask."""
    if k_scale is not None:
        k = _dequant(k_pages, k_scale, page_table)
        v = _dequant(v_pages, v_scale, page_table)
    else:
        k = gather_pages(k_pages, page_table)
        v = gather_pages(v_pages, page_table)
    return verify_reference(q, k, v, blk_k, blk_v, pos, ring=False,
                            scale=scale, tree=tree)


def paged_decode_partial_reference(q, k_pages, v_pages, page_table, pos,
                                   base, *, scale: float | None = None,
                                   k_scale=None, v_scale=None):
    """Oracle for ``paged_decode_partial``: one shard's unnormalized
    flash state.  ``k_pages``/``v_pages`` are the shard's LOCAL
    (L, Hkv, page, hd) slice, ``page_table`` holds GLOBAL ids and
    ``base`` is the shard's first global id.  q: (B, H, hd) ->
    (acc (B, Hkv, G, hd) f32, m (B, Hkv, G) f32, l (B, Hkv, G) f32),
    with rows that own no valid page at exactly (0, -1e30, 0)."""
    NEG_INF = -1e30
    B, H, hd = q.shape
    L, Hkv, page, _ = k_pages.shape
    G = H // Hkv
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    table = jnp.asarray(page_table, jnp.int32)
    lt = table - jnp.asarray(base, jnp.int32)
    owned = (lt >= 0) & (lt < L)
    lt = jnp.where(owned, lt, 0)
    if k_scale is not None:
        k = _dequant(k_pages, k_scale, lt)
        v = _dequant(v_pages, v_scale, lt)
    else:
        k = gather_pages(k_pages, lt)
        v = gather_pages(v_pages, lt)
    S = k.shape[2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    own_pos = jnp.repeat(owned, page, axis=1)            # (B, S)
    valid = ((jnp.arange(S)[None, :] <= pos[:, None])
             & own_pos)[:, None, None, :]                # (B, 1, 1, S)
    qh = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bngd,bnsd->bngs", qh,
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(valid, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bngs,bnsd->bngd", p, v.astype(jnp.float32))
    return acc, m, l
