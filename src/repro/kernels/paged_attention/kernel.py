"""Paged flash-decode / flash-verify, TPU Pallas: attention over a KV
cache whose rows live as PAGES of one shared pool.

Extends ``decode_attention``'s design along the axis the paged slot pool
needs: the per-request ``(B,)`` position vector in SMEM grows a per-row
``(B, P)`` *page table*, also scalar-prefetched.  The cache operand is no
longer a ``(B, Hkv, S, hd)`` row bank but the shared page pool
``(NP, Hkv, page, hd)``, and the kernel's BlockSpec index map reads the
page table to decide which pool page each grid step DMAs:

    lambda b, h, j, pos, pt: (pt[b, j], h, 0, 0)

so row b's j-th cache tile is *its own* j-th page, wherever the host
allocator placed it — pages of one request need not be contiguous, and
pages of different requests interleave freely in the pool.

Everything else is the proven flash-decode structure:

  * grid = (B, Hkv, P) with the page-scan axis innermost/"arbitrary";
    (m, l, acc) running-softmax state persists in VMEM scratch.
  * GQA: the G = H/Hkv query heads of one kv head are batched into a
    single (G, hd) x (hd, page) matmul per page (K*G rows for verify).
  * tiles past a row's valid length are skipped before their DMA is
    issued (``pos`` gates the page index map too: dead entries point at
    the pool's park page, a always-valid index that is never read).
  * the verify variant reads the cache PRE-block and folds the block's
    own K keys/values in after the last page under an intra-block causal
    mask — the same cache-plus-block split that makes ``verify_attention``
    sequentially exact.  Paged pools are full-attention only (the paged
    engine gates rings out), so there is no ring path here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import pltpu

NEG_INF = -1e30


def _paged_decode_kernel(pos_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         page: int, np_row: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = j * page

    @pl.when(k_start <= pos)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (page, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                  # (page, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(j == np_row - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_decode_kernel_q(pos_ref, pt_ref, q_ref, k_ref, v_ref, ks_ref,
                           vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                           scale: float, page: int, np_row: int):
    """int8-bank variant: k/v tiles are int8 codes and two extra
    (1, 1, page) scale tiles ride the SAME page-table index map, so the
    per-position scale arrives with its page and the dequantize happens
    in VMEM right before the matmul."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = j * page

    @pl.when(k_start <= pos)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = (k_ref[0, 0].astype(jnp.float32)
             * ks_ref[0, 0][:, None])                     # (page, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = (v_ref[0, 0].astype(jnp.float32)
             * vs_ref[0, 0][:, None])                     # (page, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(j == np_row - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pages, v_pages, page_table, pos, *,
                                  scale: float | None = None,
                                  k_scale=None, v_scale=None,
                                  interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, hd); k_pages/v_pages: (NP, Hkv, page, hd) shared
    pool; page_table: (B, P) int32 pool-page ids (dead entries must hold
    a valid index — the park page); pos: (B,) int32 valid length per
    row.  ``k_scale``/``v_scale`` ((NP, Hkv, page) f32) select the int8
    bank path: codes dequantize inside the kernel."""
    B, Hkv, G, hd = q.shape
    NP, _, page, _ = k_pages.shape
    P = page_table.shape[1]
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    quantized = k_scale is not None

    page_spec = pl.BlockSpec((1, 1, page, hd),
                             lambda b, h, j, pos, pt: (pt[b, j], h, 0, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, hd),
                     lambda b, h, j, pos, pt: (b, h, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        kernel = functools.partial(_paged_decode_kernel_q, scale=scale,
                                   page=page, np_row=P)
        scale_spec = pl.BlockSpec(
            (1, 1, page), lambda b, h, j, pos, pt: (pt[b, j], h, 0))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    else:
        kernel = functools.partial(_paged_decode_kernel, scale=scale,
                                   page=page, np_row=P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, pos, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_decode_attention",
    )(jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,)),
      jnp.asarray(page_table, jnp.int32), *operands)


def _paged_verify_kernel(pos_ref, pt_ref, anc_ref, q_ref, k_ref, v_ref,
                         kb_ref, vb_ref, o_ref, m_scr, l_scr, acc_scr, *,
                         scale: float, tree: bool, page: int, np_row: int,
                         K: int, G: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _fold(s, v):
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    k_start = j * page
    # pre-block cache: valid positions are <= pos-1, so a page is dead
    # once it starts at/after pos — one query-block tighter than decode.

    @pl.when(k_start < pos)
    def _cache_page():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (K*G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (page, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _fold(jnp.where(cols < pos, s, NEG_INF),
              v_ref[0, 0].astype(jnp.float32))

    @pl.when(j == np_row - 1)
    def _block_and_finalize():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (K*G, hd)
        kb = kb_ref[0, 0].astype(jnp.float32)             # (K, hd)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        jj = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if tree:
            # tree verify: per-row ancestor bitmask from SMEM replaces
            # the intra-block causal mask (see verify_attention/kernel.py)
            anc_q = jnp.zeros_like(jj)
            for i in range(K):
                anc_q = jnp.where(qi == i, anc_ref[b, i], anc_q)
            keep = jax.lax.shift_right_logical(anc_q, jj) & 1
            _fold(jnp.where(keep == 1, s, NEG_INF),
                  vb_ref[0, 0].astype(jnp.float32))
        else:
            _fold(jnp.where(jj <= qi, s, NEG_INF),
                  vb_ref[0, 0].astype(jnp.float32))
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_verify_kernel_q(pos_ref, pt_ref, anc_ref, q_ref, k_ref, v_ref,
                           ks_ref, vs_ref, kb_ref, vb_ref, o_ref, m_scr,
                           l_scr, acc_scr, *, scale: float, tree: bool,
                           page: int, np_row: int, K: int, G: int):
    """int8-bank verify: cache pages dequantize in VMEM via the
    co-travelling (1, 1, page) scale tiles; the block's own K keys/values
    stay full precision (they have not been written to the pool yet)."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _fold(s, v):
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    k_start = j * page

    @pl.when(k_start < pos)
    def _cache_page():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (K*G, hd)
        k = (k_ref[0, 0].astype(jnp.float32)
             * ks_ref[0, 0][:, None])                     # (page, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _fold(jnp.where(cols < pos, s, NEG_INF),
              v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None])

    @pl.when(j == np_row - 1)
    def _block_and_finalize():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (K*G, hd)
        kb = kb_ref[0, 0].astype(jnp.float32)             # (K, hd)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        jj = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if tree:
            anc_q = jnp.zeros_like(jj)
            for i in range(K):
                anc_q = jnp.where(qi == i, anc_ref[b, i], anc_q)
            keep = jax.lax.shift_right_logical(anc_q, jj) & 1
            _fold(jnp.where(keep == 1, s, NEG_INF),
                  vb_ref[0, 0].astype(jnp.float32))
        else:
            _fold(jnp.where(jj <= qi, s, NEG_INF),
                  vb_ref[0, 0].astype(jnp.float32))
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_verify_attention_kernel(q, k_pages, v_pages, kb, vb, page_table,
                                  pos, *, scale: float | None = None,
                                  k_scale=None, v_scale=None, tree=None,
                                  interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, K*G, hd) — row r is query r//G of kv head h;
    k_pages/v_pages: (NP, Hkv, page, hd) shared pool BEFORE the block's
    writes; kb/vb: (B, Hkv, K, hd) block keys/values; page_table: (B, P)
    int32; pos: (B,) int32 base positions.  ``k_scale``/``v_scale``
    ((NP, Hkv, page) f32) select the int8 bank path.  ``tree``
    ((B, K) int32 ancestor bitmasks) replaces the intra-block causal
    mask with per-row tree visibility (bit j of ``tree[b, i]`` = block
    token j visible to block query i)."""
    B, Hkv, KG, hd = q.shape
    K = kb.shape[2]
    assert KG % K == 0, (KG, K)
    G = KG // K
    NP, _, page, _ = k_pages.shape
    P = page_table.shape[1]
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    quantized = k_scale is not None
    if tree is None:
        anc = jnp.zeros((B, 1), jnp.int32)
        is_tree = False
    else:
        assert K <= 31, K  # bitmask lives in a non-negative int32
        anc = jnp.asarray(tree, jnp.int32)
        assert anc.shape == (B, K), (anc.shape, B, K)
        is_tree = True

    page_spec = pl.BlockSpec(
        (1, 1, page, hd),
        lambda b, h, j, pos, pt, anc: (pt[b, j], h, 0, 0))
    blk_spec = pl.BlockSpec((1, 1, K, hd),
                            lambda b, h, j, pos, pt, anc: (b, h, 0, 0))
    in_specs = [
        pl.BlockSpec((1, 1, KG, hd),
                     lambda b, h, j, pos, pt, anc: (b, h, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        kernel = functools.partial(_paged_verify_kernel_q, scale=scale,
                                   tree=is_tree, page=page, np_row=P,
                                   K=K, G=G)
        scale_spec = pl.BlockSpec(
            (1, 1, page), lambda b, h, j, pos, pt, anc: (pt[b, j], h, 0))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    else:
        kernel = functools.partial(_paged_verify_kernel, scale=scale,
                                   tree=is_tree, page=page, np_row=P,
                                   K=K, G=G)
    in_specs += [blk_spec, blk_spec]
    operands += [kb, vb]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, KG, hd),
                               lambda b, h, j, pos, pt, anc: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KG, 1), jnp.float32),
            pltpu.VMEM((KG, 1), jnp.float32),
            pltpu.VMEM((KG, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_verify_attention",
    )(jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,)),
      jnp.asarray(page_table, jnp.int32), anc, *operands)


def _paged_decode_partial_kernel(pos_ref, pt_ref, base_ref, q_ref, k_ref,
                                 v_ref, acc_ref, m_ref, l_ref, m_scr,
                                 l_scr, acc_scr, *, scale: float,
                                 page: int, np_row: int, num_local: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]
    pid = pt_ref[b, j]
    base = base_ref[0]
    owned = (pid >= base) & (pid < base + num_local)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = j * page

    @pl.when(owned & (k_start <= pos))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (page, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                  # (page, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(j == np_row - 1)
    def _finalize():
        acc_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


def _paged_decode_partial_kernel_q(pos_ref, pt_ref, base_ref, q_ref,
                                   k_ref, v_ref, ks_ref, vs_ref, acc_ref,
                                   m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                                   scale: float, page: int, np_row: int,
                                   num_local: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]
    pid = pt_ref[b, j]
    base = base_ref[0]
    owned = (pid >= base) & (pid < base + num_local)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = j * page

    @pl.when(owned & (k_start <= pos))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = (k_ref[0, 0].astype(jnp.float32)
             * ks_ref[0, 0][:, None])                     # (page, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = (v_ref[0, 0].astype(jnp.float32)
             * vs_ref[0, 0][:, None])                     # (page, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(j == np_row - 1)
    def _finalize():
        acc_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


def paged_decode_partial_kernel(q, k_pages, v_pages, page_table, pos,
                                base, *, scale: float | None = None,
                                k_scale=None, v_scale=None,
                                interpret: bool = False):
    """Per-shard HALF of flash decode over a sharded page bank.

    ``k_pages``/``v_pages`` here are one shard's (L, Hkv, page, hd)
    LOCAL slice; ``page_table`` still holds GLOBAL page ids and ``base``
    ((1,) int32, scalar-prefetched) is the shard's first global id, so
    the index map clamps ``pt[b, j] - base`` into [0, L) and the body
    additionally gates each fold on ownership — a foreign page's tile
    may be DMA'd (clamped to local park page 0) but never folded.

    Returns the UNNORMALIZED running-softmax state instead of an
    output: (acc (B, Hkv, G, hd) f32, m (B, Hkv, G, 1) f32,
    l (B, Hkv, G, 1) f32).  A row with no owned valid page yields
    (0, NEG_INF, 0), which a cross-shard ``exp(m - pmax(m))`` rescale +
    psum combine weighs to exactly zero."""
    B, Hkv, G, hd = q.shape
    L, _, page, _ = k_pages.shape
    P = page_table.shape[1]
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    quantized = k_scale is not None

    def _page_idx(b, h, j, pos, pt, base):
        return (jnp.clip(pt[b, j] - base[0], 0, L - 1), h, 0, 0)

    page_spec = pl.BlockSpec((1, 1, page, hd), _page_idx)
    in_specs = [
        pl.BlockSpec((1, 1, G, hd),
                     lambda b, h, j, pos, pt, base: (b, h, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        kernel = functools.partial(_paged_decode_partial_kernel_q,
                                   scale=scale, page=page, np_row=P,
                                   num_local=L)
        scale_spec = pl.BlockSpec(
            (1, 1, page),
            lambda b, h, j, pos, pt, base:
                (jnp.clip(pt[b, j] - base[0], 0, L - 1), h, 0))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    else:
        kernel = functools.partial(_paged_decode_partial_kernel,
                                   scale=scale, page=page, np_row=P,
                                   num_local=L)
    out_idx = lambda b, h, j, pos, pt, base: (b, h, 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, P),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), out_idx),
            pl.BlockSpec((1, 1, G, 1), out_idx),
            pl.BlockSpec((1, 1, G, 1), out_idx),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_decode_partial",
    )(jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,)),
      jnp.asarray(page_table, jnp.int32),
      jnp.broadcast_to(jnp.asarray(base, jnp.int32), (1,)), *operands)
