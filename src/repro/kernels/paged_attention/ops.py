"""jit'd public wrappers for paged decode / paged verify attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import (
    paged_decode_attention_kernel, paged_decode_partial_kernel,
    paged_verify_attention_kernel)
from repro.kernels.paged_attention.ref import (
    gather_pages, gather_scales, paged_decode_partial_reference,
    paged_decode_reference, paged_verify_reference)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_table, pos, *,
                           scale: float | None = None,
                           k_scale=None, v_scale=None,
                           interpret: bool | None = None) -> jax.Array:
    """q: (B, H, hd); k_pages/v_pages: (NP, Hkv, page, hd) shared pool;
    page_table: (B, P) int32; pos: () or (B,) int32 -> (B, H, hd).

    The paged analogue of ``decode_attention``: the same per-request
    position masking and tile skipping, with the cache tile for grid
    step j of row b resolved through the scalar-prefetched page table
    instead of a contiguous row.  Dead table entries (past a row's
    allocation) must hold a valid pool index — the engine points them at
    the park page; they are masked by ``pos`` regardless.  An int8 pool
    passes its (NP, Hkv, page) f32 ``k_scale``/``v_scale`` leaves and
    the kernel dequantizes in VMEM."""
    if interpret is None:
        interpret = not _on_tpu()
    B, H, hd = q.shape
    Hkv = k_pages.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    out = paged_decode_attention_kernel(qg, k_pages, v_pages, page_table,
                                        pos, scale=scale,
                                        k_scale=k_scale, v_scale=v_scale,
                                        interpret=interpret)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_verify_attention(q, k_pages, v_pages, blk_k, blk_v, page_table,
                           pos, *, scale: float | None = None,
                           k_scale=None, v_scale=None, tree=None,
                           interpret: bool | None = None) -> jax.Array:
    """q: (B, K, H, hd); pool holds the cache BEFORE the block's writes;
    blk_k/blk_v: (B, K, Hkv, hd); page_table: (B, P); pos: () or (B,)
    int32 base positions -> (B, K, H, hd).

    Query i of row b sits at position ``pos[b] + i``; it attends to the
    paged cache (positions <= pos[b]-1, resolved through the page table)
    plus block tokens j <= i — the same cache-plus-block split as
    ``verify_attention``, which keeps the pass loop-exact.  Full
    attention only (the paged engine gates ring caches out).  ``tree``
    ((B, K) int32 ancestor bitmasks) swaps the intra-block causal mask
    for per-row tree visibility so several candidate branches verify in
    one pass."""
    if interpret is None:
        interpret = not _on_tpu()
    B, K, H, hd = q.shape
    Hkv = k_pages.shape[1]
    G = H // Hkv
    qg = (q.reshape(B, K, Hkv, G, hd).transpose(0, 2, 1, 3, 4)
          .reshape(B, Hkv, K * G, hd))
    kb = blk_k.swapaxes(1, 2)                       # (B, Hkv, K, hd)
    vb = blk_v.swapaxes(1, 2)
    out = paged_verify_attention_kernel(qg, k_pages, v_pages, kb, vb,
                                        page_table, pos, scale=scale,
                                        k_scale=k_scale, v_scale=v_scale,
                                        tree=tree, interpret=interpret)
    return (out.reshape(B, Hkv, K, G, hd).transpose(0, 2, 1, 3, 4)
            .reshape(B, K, H, hd))


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_partial(q, k_pages, v_pages, page_table, pos, base, *,
                         scale: float | None = None,
                         k_scale=None, v_scale=None,
                         interpret: bool | None = None):
    """One shard's unnormalized flash-decode state over its LOCAL bank
    slice.  q: (B, H, hd); k_pages/v_pages: (L, Hkv, page, hd) local
    slice; page_table: (B, P) GLOBAL page ids; base: scalar int32 first
    global id of this shard -> (acc (B, Hkv, G, hd) f32, m (B, Hkv, G)
    f32, l (B, Hkv, G) f32).  Pages outside [base, base+L) are skipped;
    a row owning no valid page comes back as (0, -1e30, 0), which the
    caller's pmax/psum combine weighs to zero.  Runs inside shard_map —
    every shard's kernel instance reads only its own slice."""
    if interpret is None:
        interpret = not _on_tpu()
    B, H, hd = q.shape
    Hkv = k_pages.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    acc, m, l = paged_decode_partial_kernel(
        qg, k_pages, v_pages, page_table, pos, base, scale=scale,
        k_scale=k_scale, v_scale=v_scale, interpret=interpret)
    return acc, m[..., 0], l[..., 0]


__all__ = ["gather_pages", "gather_scales", "paged_decode_attention",
           "paged_decode_partial", "paged_decode_partial_reference",
           "paged_decode_reference", "paged_verify_attention",
           "paged_verify_reference"]
