"""Pallas-TPU version compatibility: ``pltpu.CompilerParams`` was named
``TPUCompilerParams`` before jax 0.5 (same fields — ``dimension_semantics``
et al.).  Kernels import ``pltpu`` from here to run on either release."""
from __future__ import annotations

from jax.experimental import pallas as pl                     # noqa: F401
from jax.experimental.pallas import tpu as _tpu

_COMPILER_PARAMS = getattr(_tpu, "CompilerParams",
                           getattr(_tpu, "TPUCompilerParams", None))


class _PltpuShim:
    def __getattr__(self, name):
        if name == "CompilerParams":
            if _COMPILER_PARAMS is None:           # fail fast + diagnosable
                raise AttributeError(
                    "this jax release exposes neither "
                    "pallas.tpu.CompilerParams nor TPUCompilerParams")
            return _COMPILER_PARAMS
        return getattr(_tpu, name)


pltpu = _PltpuShim()
