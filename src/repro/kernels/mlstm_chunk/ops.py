"""jit'd public wrapper for the chunkwise-mLSTM kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mlstm_chunk.kernel import DEFAULT_CHUNK, mlstm_chunk_kernel
from repro.kernels.mlstm_chunk.ref import (
    mlstm_chunk_reference, mlstm_recurrent_reference)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, li, lf, *, chunk: int = DEFAULT_CHUNK,
                interpret: bool | None = None):
    """q/k/v: (B, H, L, dh); li/lf: (B, H, L) -> (h, (C, n, m)).

    Auto-shrinks the chunk to a divisor of L."""
    if interpret is None:
        interpret = not _on_tpu()
    L = q.shape[2]
    c = min(chunk, L)
    while L % c:
        c //= 2
    f32 = lambda x: x.astype(jnp.float32)
    return mlstm_chunk_kernel(f32(q), f32(k), f32(v), f32(li), f32(lf),
                              chunk=c, interpret=interpret)


__all__ = ["mlstm_chunk", "mlstm_chunk_reference",
           "mlstm_recurrent_reference"]
