"""Chunkwise-parallel mLSTM (xLSTM), TPU Pallas.

TPU-native design:
  * grid = (B, H, L/c): chunks are the innermost "arbitrary" axis; the
    matrix memory (C: dh x dh), normalizer (n: dh) and stabilizer (m: scalar)
    persist in VMEM scratch across chunks — the O(L) recurrence never leaves
    VMEM, while the O(c^2) intra-chunk part runs on the MXU as dense
    (c x dh)(dh x c) matmuls.
  * c = 128/256 keeps the decay matrix (c x c f32) and the q/k/v tiles
    inside VMEM with dh up to 384 (xlstm-125m: dh = 1536/4 = 384).
  * All gate algebra is log-space with a running max (numerical parity with
    the reference recurrent form is asserted in tests, not just the
    chunkwise oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import pltpu

NEG_INF = -1e30
DEFAULT_CHUNK = 128


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref,
                  h_ref, cfin_ref, nfin_ref, mfin_ref,
                  c_scr, n_scr, m_scr, *, c: int, nc: int, dh: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    qc = q_ref[0, 0].astype(jnp.float32)                  # (c, dh)
    kc = k_ref[0, 0].astype(jnp.float32)
    vc = v_ref[0, 0].astype(jnp.float32)
    lic = li_ref[0, 0].astype(jnp.float32)                # (c,)
    lfc = lf_ref[0, 0].astype(jnp.float32)
    C_p = c_scr[...]                                      # (dh, dh)
    n_p = n_scr[...]                                      # (dh, 1)
    m_p = m_scr[0, 0]                                     # scalar

    scale = 1.0 / (dh ** 0.5)
    g = jnp.cumsum(lfc)                                   # (c,)
    dmat = g[:, None] - g[None, :] + lic[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    dmat = jnp.where(cols <= rows, dmat, NEG_INF)
    m_intra = jnp.max(dmat, axis=-1)                      # (c,)
    m_inter = g + m_p
    m_t = jnp.maximum(m_intra, m_inter)
    D = jnp.exp(dmat - m_t[:, None])
    scores = jax.lax.dot_general(qc, kc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    sD = scores * D
    intra_num = jax.lax.dot_general(sD, vc, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    intra_den = jnp.sum(sD, axis=-1)                      # (c,)
    w_inter = jnp.exp(m_inter - m_t)                      # (c,)
    qC = jax.lax.dot_general(qc, C_p, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    inter_num = qC * w_inter[:, None]
    inter_den = (qc @ n_p)[:, 0] * w_inter                # (c,)
    num = intra_num + inter_num
    den = jnp.maximum(jnp.abs(intra_den + inter_den), jnp.exp(-m_t))
    h_ref[0, 0] = (num / den[:, None]).astype(h_ref.dtype)

    # ---- chunk-final state handoff ------------------------------------
    gT = g[c - 1]
    m_new = jnp.maximum(gT + m_p, jnp.max(gT - g + lic))
    wk = jnp.exp(gT - g + lic - m_new)                    # (c,)
    ks = kc * scale
    decay = jnp.exp(gT + m_p - m_new)
    wkv = wk[:, None] * vc                                # (c, dh)
    c_scr[...] = decay * C_p + jax.lax.dot_general(
        ks, wkv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_scr[...] = decay * n_p + jax.lax.dot_general(
        ks, wk[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[0, 0] = m_new

    @pl.when(t == nc - 1)
    def _fin():
        cfin_ref[0, 0] = c_scr[...]
        nfin_ref[0, 0] = n_scr[...][:, 0]
        mfin_ref[0, 0] = m_scr[0, 0]


def mlstm_chunk_kernel(q, k, v, li, lf, *, chunk: int = DEFAULT_CHUNK,
                       interpret: bool = False):
    """q/k/v: (B, H, L, dh) f32; li/lf: (B, H, L) f32.  L % chunk == 0.

    Returns h (B, H, L, dh) and the final state (C, n, m)."""
    B, H, L, dh = q.shape
    c = min(chunk, L)
    assert L % c == 0, (L, c)
    nc = L // c

    kernel = functools.partial(_mlstm_kernel, c=c, nc=nc, dh=dh)
    grid = (B, H, nc)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c, dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c, dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c), lambda b, h, t: (b, h, t)),
            pl.BlockSpec((1, 1, c), lambda b, h, t: (b, h, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, h, t: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, t: (b, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, L, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="mlstm_chunk",
    )(q, k, v, li, lf)
    return h, (C, n, m)
