"""Pure-jnp oracle for the chunkwise-mLSTM kernel: re-exports the model's
chunkwise and fully-recurrent forms (the recurrent form is the ground truth;
chunkwise is algebraically identical and is what the kernel implements)."""
from __future__ import annotations

from repro.models.xlstm import mlstm_chunkwise, mlstm_recurrent


def mlstm_chunk_reference(q, k, v, li, lf, chunk: int, state=None):
    """q/k/v: (B, H, L, dh) f32; li/lf: (B, H, L) f32 log gates."""
    return mlstm_chunkwise(q, k, v, li, lf, chunk, state)


def mlstm_recurrent_reference(q, k, v, li, lf, state=None):
    return mlstm_recurrent(q, k, v, li, lf, state)
