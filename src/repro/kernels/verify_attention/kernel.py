"""Multi-token verify attention, TPU Pallas: the speculative-decode verify
step's K query tokens per row against a long KV cache in one kernel.

Extends ``decode_attention``'s design from one query token to a (B, K)
query block:

  * the ``(B,)`` per-request position vector still arrives via scalar
    prefetch (SMEM); each row's K queries sit at ``pos[b] .. pos[b]+K-1``
    with *per-row causal offsets* computed inside the kernel (query index
    i = score-row // G), so one program serves rows at wildly different
    positions — the continuous-batching invariant, now a block wide.
  * the K*G query rows of one kv head are batched into a single
    (K*G, hd) x (hd, bk) matmul per KV tile — the same MXU-occupancy trick
    as decode's G-row batching, K times taller.
  * the cache is read PRE-block (positions <= pos-1); the block's own K
    keys/values arrive as a separate (K, hd) operand folded into the
    running softmax after the last cache tile with an intra-block causal
    mask.  This split is what makes the result sequentially exact — for
    ring caches a later token's write lands on a slot an earlier query
    must still read, so write-then-mask cannot reproduce the one-token
    decode loop; cache-plus-block can, and does (tested).
  * grid = (B, Hkv, S/bk), cache axis innermost/"arbitrary"; (m, l, acc)
    running-softmax state in VMEM scratch; tiles past a row's valid
    length are skipped before their DMA is issued.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import pltpu

NEG_INF = -1e30
DEFAULT_BK = 512


def _verify_kernel(pos_ref, anc_ref, q_ref, k_ref, v_ref, kb_ref, vb_ref,
                   o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                   ring: bool, tree: bool, bk: int, nk: int, S: int,
                   K: int, G: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _fold(s, v):
        """Fold one masked score tile into the running softmax state."""
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    k_start = j * bk
    # pre-block cache: valid slots hold positions <= pos-1, so a tile is
    # dead when it starts at/after pos (non-ring) — one query-block tighter
    # than decode's k_start <= pos.  A wrapped ring keeps every tile live.
    live = jnp.logical_or(k_start < pos, jnp.bool_(ring) & (pos >= S))

    @pl.when(live)
    def _cache_tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (K*G, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if ring:
            p = (pos - 1) - jnp.mod(pos - 1 - cols, S)
            valid = (p >= 0) & (p > pos + qi - S)
        else:
            valid = cols < pos
        _fold(jnp.where(valid, s, NEG_INF), v_ref[0, 0].astype(jnp.float32))

    @pl.when(j == nk - 1)
    def _block_and_finalize():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (K*G, hd)
        kb = kb_ref[0, 0].astype(jnp.float32)             # (K, hd)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        jj = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if tree:
            # per-row ancestor bitmask: block column j is visible to block
            # query qi iff bit j of anc[b, qi] is set.  The bitmask rides
            # scalar prefetch (SMEM) like pos; the unroll over the K block
            # queries turns it into a per-score-row int32 whose bits the
            # iota extracts — no extra VMEM operand, no layout change.
            anc_q = jnp.zeros_like(jj)
            for i in range(K):
                anc_q = jnp.where(qi == i, anc_ref[b, i], anc_q)
            keep = jax.lax.shift_right_logical(anc_q, jj) & 1
            _fold(jnp.where(keep == 1, s, NEG_INF),
                  vb_ref[0, 0].astype(jnp.float32))
        else:
            _fold(jnp.where(jj <= qi, s, NEG_INF),
                  vb_ref[0, 0].astype(jnp.float32))
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def verify_attention_kernel(q, k, v, kb, vb, pos, *, ring: bool = False,
                            scale: float | None = None,
                            block_k: int = DEFAULT_BK,
                            tree=None,
                            interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, K*G, hd) — row r is query r//G of kv head h; k/v:
    (B, Hkv, S, hd) cache BEFORE the block's writes; kb/vb:
    (B, Hkv, K, hd) block keys/values; pos: (B,) int32 base positions.
    ``tree`` ((B, K) int32 ancestor bitmasks, bit j of row i = block
    token j visible to block query i) replaces the intra-block causal
    mask so several candidate branches verify in one pass; the cache
    side is unchanged (every tree node descends from position pos-1)."""
    B, Hkv, KG, hd = q.shape
    S = k.shape[2]
    K = kb.shape[2]
    assert KG % K == 0, (KG, K)
    G = KG // K
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    if tree is None:
        anc = jnp.zeros((B, 1), jnp.int32)
        is_tree = False
    else:
        assert not ring, "tree verify is full-attention only"
        assert K <= 31, K  # bitmask lives in a non-negative int32
        anc = jnp.asarray(tree, jnp.int32)
        assert anc.shape == (B, K), (anc.shape, B, K)
        is_tree = True

    kernel = functools.partial(_verify_kernel, scale=scale, ring=ring,
                               tree=is_tree, bk=bk, nk=nk, S=S, K=K, G=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, KG, hd),
                         lambda b, h, j, pos, anc: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, pos, anc: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, pos, anc: (b, h, j, 0)),
            pl.BlockSpec((1, 1, K, hd),
                         lambda b, h, j, pos, anc: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, K, hd),
                         lambda b, h, j, pos, anc: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, KG, hd),
                               lambda b, h, j, pos, anc: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KG, 1), jnp.float32),
            pltpu.VMEM((KG, 1), jnp.float32),
            pltpu.VMEM((KG, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="verify_attention",
    )(jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,)), anc,
      q, k, v, kb, vb)
