"""jit'd public wrapper for multi-token verify attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.verify_attention.kernel import (
    DEFAULT_BK, verify_attention_kernel)
from repro.kernels.verify_attention.ref import verify_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("ring", "scale", "block_k",
                                             "interpret"))
def verify_attention(q, k, v, blk_k, blk_v, pos, *, ring: bool = False,
                     scale: float | None = None, block_k: int = DEFAULT_BK,
                     tree=None,
                     interpret: bool | None = None) -> jax.Array:
    """q: (B, K, H, hd); k/v: (B, Hkv, S, hd) cache BEFORE the block's
    writes; blk_k/blk_v: (B, K, Hkv, hd) block keys/values; pos: () or
    (B,) int32 base positions -> (B, K, H, hd).

    Query i of row b sits at position ``pos[b] + i``; it attends to the
    cache (positions <= pos[b]-1, window-masked for rings) plus block
    tokens j <= i — exactly what the i-th sequential ``decode_attention``
    step would see, which makes the verify pass loop-exact even across a
    ring wraparound.

    Like ``decode_attention``, the cache length is kept block-aligned by
    shrinking the block rather than padding (ring caches must not pad).

    ``tree`` ((B, K) int32 ancestor bitmasks) swaps the intra-block
    causal mask for per-row tree visibility: bit j of ``tree[b, i]``
    makes block token j visible to block query i, so several candidate
    branches verify in one pass (full attention only).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, K, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    bk = min(block_k, S)
    while S % bk != 0:
        bk //= 2
    # (B, K, H, hd) -> (B, Hkv, K*G, hd): score-row r = query (r//G) of
    # head group g = r % G — the layout the kernel's causal offsets assume
    qg = (q.reshape(B, K, Hkv, G, hd).transpose(0, 2, 1, 3, 4)
          .reshape(B, Hkv, K * G, hd))
    kb = blk_k.swapaxes(1, 2)                       # (B, Hkv, K, hd)
    vb = blk_v.swapaxes(1, 2)
    out = verify_attention_kernel(qg, k, v, kb, vb, pos, ring=ring,
                                  scale=scale, block_k=bk, tree=tree,
                                  interpret=interpret)
    return (out.reshape(B, Hkv, K, G, hd).transpose(0, 2, 1, 3, 4)
            .reshape(B, K, H, hd))


__all__ = ["verify_attention", "verify_reference"]
