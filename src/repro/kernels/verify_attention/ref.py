"""Pure-jnp oracle for multi-token verify attention: K query tokens per
row scored against a KV cache in one pass (speculative decode's verify
step).

Layout: q (B, K, H, hd) — the K block tokens of each row, at positions
``pos[b] .. pos[b]+K-1``; k/v cache (B, Hkv, S, hd) as it stood BEFORE the
block (positions <= pos-1); blk_k/blk_v (B, K, Hkv, hd) the block's own
keys/values.  ``pos`` is a scalar or per-request (B,) vector (continuous
batching: every row at its own position).

Splitting cache vs block is what makes the result *sequentially exact*:
query i sees cache entries valid at step i plus block tokens j <= i —
identical to running the one-token decode path i times.  A write-then-mask
formulation cannot be exact for ring caches (a later block token's write
lands on a slot an earlier query should still read); here the overwritten
token is still in the cache side, masked per query by its stored position.

Validity for query i (position pos+i):
  * full cache — cache slots [0, pos-1]; block tokens j <= i.
  * ring cache — (sliding window, cache length == window): cache slot s
    holds position p(s) = (pos-1) - ((pos-1-s) mod S); valid iff
    p(s) >= 0 (written) and p(s) > pos+i-S (inside query i's window).
    Block tokens j <= i are always in-window (i - j < S for K <= S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def verify_reference(q, k, v, blk_k, blk_v, pos, *, ring: bool = False,
                     scale: float | None = None, tree=None) -> jax.Array:
    """``tree`` ((B, K) int32, optional): per-row ancestor bitmasks for
    tree verification — bit j of ``tree[b, i]`` makes block token j
    visible to block query i, replacing the intra-block causal mask.
    The cache side is unchanged (every tree node descends from position
    pos-1, so all of them see the full cache < pos)."""
    B, K, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    assert blk_k.shape == (B, K, Hkv, hd), blk_k.shape
    if ring:
        assert K <= S, (K, S)
        assert tree is None, "tree verify is full-attention only"
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    G = H // Hkv
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    qh = (q.reshape(B, K, Hkv, G, hd).astype(jnp.float32)
          .transpose(0, 2, 1, 3, 4))                       # (B, Hkv, K, G, hd)

    # cache side: per-query validity mask (B, K, S)
    s_c = jnp.einsum("bnigd,bnsd->bnigs", qh, k.astype(jnp.float32)) * scale
    cols = jnp.arange(S)[None, None, :]                     # (1, 1, S)
    i = jnp.arange(K)[None, :, None]                        # (1, K, 1)
    pb = pos[:, None, None]                                 # (B, 1, 1)
    if ring:
        p = (pb - 1) - jnp.mod(pb - 1 - cols, S)
        valid = (p >= 0) & (p > pb + i - S)
    else:
        valid = cols < pb
    s_c = jnp.where(valid[:, None, :, None, :], s_c, NEG_INF)

    # block side: intra-block causal (j <= i)
    kb = blk_k.transpose(0, 2, 1, 3).astype(jnp.float32)    # (B, Hkv, K, hd)
    vb = blk_v.transpose(0, 2, 1, 3).astype(jnp.float32)
    s_b = jnp.einsum("bnigd,bnjd->bnigj", qh, kb) * scale
    if tree is None:
        causal = jnp.arange(K)[None, :] <= jnp.arange(K)[:, None]  # j <= i
        s_b = jnp.where(causal[None, None, :, None, :], s_b, NEG_INF)
    else:
        t = jnp.broadcast_to(jnp.asarray(tree, jnp.int32), (B, K))
        vis = ((t[:, :, None] >> jnp.arange(K)[None, None, :]) & 1) == 1
        s_b = jnp.where(vis[:, None, :, None, :], s_b, NEG_INF)

    # joint softmax across cache + block (flash-decode combine)
    s = jnp.concatenate([s_c, s_b], axis=-1)                # (B,Hkv,K,G,S+K)
    p_all = jax.nn.softmax(s, axis=-1)
    v_all = jnp.concatenate([v.astype(jnp.float32), vb], axis=2)
    out = jnp.einsum("bnigt,bntd->bnigd", p_all, v_all)     # (B,Hkv,K,G,hd)
    out = out.transpose(0, 2, 1, 3, 4).reshape(B, K, H, hd)
    return out.astype(q.dtype)
