"""jit'd public wrapper for the selective-scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import (
    DEFAULT_BD, DEFAULT_BL, ssm_scan_kernel)
from repro.kernels.ssm_scan.ref import selective_scan_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_d", "block_l",
                                             "interpret"))
def selective_scan(u, dt, Bm, Cm, A, D, init_state=None, *,
                   block_d: int = DEFAULT_BD, block_l: int = DEFAULT_BL,
                   interpret: bool | None = None):
    """Selective scan: returns (y (B, L, d_in) f32, state (B, d_in, N) f32).

    Shapes follow the model's mamba block; block sizes auto-shrink to
    divisors of (d_in, L)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, L, d_in = u.shape
    N = A.shape[1]
    bd = min(block_d, d_in)
    while d_in % bd:
        bd //= 2
    bl = min(block_l, L)
    while L % bl:
        bl //= 2
    if init_state is None:
        init_state = jnp.zeros((B, d_in, N), jnp.float32)
    f32 = lambda x: x.astype(jnp.float32)
    y, s = ssm_scan_kernel(f32(u), f32(dt), f32(Bm), f32(Cm), f32(A),
                           f32(D).reshape(1, d_in), f32(init_state),
                           block_d=bd, block_l=bl, interpret=interpret)
    return y, s


__all__ = ["selective_scan", "selective_scan_reference"]
