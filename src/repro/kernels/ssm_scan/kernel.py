"""Selective scan (Mamba SSM), TPU Pallas.

TPU-native design:
  * The channel dim d_in (8192 for jamba) is the *parallel* grid axis — each
    program owns a (bd, N) state slab in VMEM; channels are independent, so
    no cross-program communication.
  * Time is tiled (bl-step chunks) as the innermost "arbitrary" axis; the
    recurrent state persists in VMEM scratch across time tiles, so HBM
    traffic is one read of u/dt/B/C + one write of y — the recurrence never
    round-trips HBM (the CUDA version's shared-memory trick, mapped to the
    VMEM hierarchy).
  * The inner fori_loop is a true sequential recurrence over the time tile
    but each step is a (bd, N) VPU-wide elementwise op — lane-parallel
    across channels, exactly how the VPU wants it (8x128 vregs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import pltpu

DEFAULT_BD = 256     # channels per program
DEFAULT_BL = 128     # time steps per tile


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, s0_ref,
                y_ref, sfin_ref, s_scr, *, bl: int, bd: int, nl: int):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)        # (bd, N)

    A = a_ref[...].astype(jnp.float32)                    # (bd, N)
    u = u_ref[0].astype(jnp.float32)                      # (bl, bd)
    dt = dt_ref[0].astype(jnp.float32)                    # (bl, bd)
    Bm = b_ref[0].astype(jnp.float32)                     # (bl, N)
    Cm = c_ref[0].astype(jnp.float32)                     # (bl, N)
    Dg = d_ref[...].astype(jnp.float32)                   # (1, bd)

    def step(t, s):
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)  # (1, bd)
        u_t = jax.lax.dynamic_slice_in_dim(u, t, 1, 0)
        B_t = jax.lax.dynamic_slice_in_dim(Bm, t, 1, 0)   # (1, N)
        C_t = jax.lax.dynamic_slice_in_dim(Cm, t, 1, 0)
        dA = jnp.exp(dt_t.T * A)                          # (bd, N)
        dBu = (dt_t * u_t).T * B_t                        # (bd, N)
        s = dA * s + dBu
        y_t = jnp.sum(s * C_t, axis=-1)[None] + u_t * Dg  # (1, bd)
        pl.store(y_ref, (pl.ds(0, 1), pl.ds(t, 1), slice(None)), y_t[None])
        return s

    s = jax.lax.fori_loop(0, bl, step, s_scr[...])
    s_scr[...] = s

    @pl.when(l == nl - 1)
    def _fin():
        sfin_ref[0] = s.astype(sfin_ref.dtype)


def ssm_scan_kernel(u, dt, Bm, Cm, A, D, init_state, *,
                    block_d: int = DEFAULT_BD, block_l: int = DEFAULT_BL,
                    interpret: bool = False):
    """u/dt: (B, L, d_in); Bm/Cm: (B, L, N); A: (d_in, N); D: (1, d_in);
    init_state: (B, d_in, N).  L % block_l == 0, d_in % block_d == 0."""
    B, L, d_in = u.shape
    N = A.shape[1]
    bd = min(block_d, d_in)
    bl = min(block_l, L)
    assert d_in % bd == 0 and L % bl == 0, (d_in, bd, L, bl)
    nd, nl = d_in // bd, L // bl

    kernel = functools.partial(_ssm_kernel, bl=bl, bd=bd, nl=nl)
    grid = (B, nd, nl)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bl, bd), lambda b, d, l: (b, l, d)),   # u
            pl.BlockSpec((1, bl, bd), lambda b, d, l: (b, l, d)),   # dt
            pl.BlockSpec((1, bl, N), lambda b, d, l: (b, l, 0)),    # B
            pl.BlockSpec((1, bl, N), lambda b, d, l: (b, l, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d, l: (d, 0)),          # A
            pl.BlockSpec((1, bd), lambda b, d, l: (0, d)),          # D
            pl.BlockSpec((1, bd, N), lambda b, d, l: (b, d, 0)),    # s0
        ],
        out_specs=[
            pl.BlockSpec((1, bl, bd), lambda b, d, l: (b, l, d)),   # y
            pl.BlockSpec((1, bd, N), lambda b, d, l: (b, d, 0)),    # s_fin
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, d_in), jnp.float32),
            jax.ShapeDtypeStruct((B, d_in, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="ssm_scan",
    )(u, dt, Bm, Cm, A, D, init_state)
