"""Pure-jnp oracle for the selective-scan kernel (re-exports the model's
exact sequential scan so kernel tests validate against the single source of
truth used by the Jamba blocks)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import _selective_scan_ref


def selective_scan_reference(u, dt, Bm, Cm, A, D, init_state=None):
    """u/dt: (B, L, d_in) f32; Bm/Cm: (B, L, N); A: (d_in, N); D: (d_in,).

    Returns y (B, L, d_in) and the final state (B, d_in, N)."""
    return _selective_scan_ref(u.astype(jnp.float32), dt.astype(jnp.float32),
                               Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                               A.astype(jnp.float32), D.astype(jnp.float32),
                               init_state)
