"""Pure-jnp oracle for the flash-attention kernel.

Layout: q (B, H, S, hd); k/v (B, Hkv, S, hd) with H % Hkv == 0 (GQA).
Semantics: causal self-attention over a common position range [0, S),
optionally banded to a sliding window of width ``window`` (token t attends
to (t-window, t]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jax.Array:
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=1)
        v = jnp.repeat(v, H // Hkv, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window > 0:
        mask &= (i - j) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)) \
        .astype(q.dtype)
