"""jit'd public wrapper for the flash-attention kernel.

Dispatch: Pallas TPU kernel on TPU; interpret-mode execution of the same
kernel body on CPU (correctness path); padding to block multiples handled
here so the kernel sees aligned shapes only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BK, DEFAULT_BQ, flash_attention_kernel)
from repro.kernels.flash_attention.ref import mha_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = DEFAULT_BQ,
                    block_k: int = DEFAULT_BK,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, H, S, hd); k/v: (B, Hkv, S, hd) -> (B, H, S, hd).

    Pads S up to a block multiple; padded key columns sit above the causal
    diagonal of every real query row, so they are masked for free.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, H, S, hd = q.shape
    bq = min(block_q, max(S, 8))
    bk = min(block_k, max(S, 8))
    pad = (-S) % max(bq, bk)
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v = zp(q), zp(k), zp(v)
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 scale=scale, block_q=bq, block_k=bk,
                                 interpret=interpret)
    return out[:, :, :S] if pad else out


def attention_reference(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jax.Array:
    return mha_reference(q, k, v, causal=causal, window=window, scale=scale)
