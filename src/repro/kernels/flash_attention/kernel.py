"""Flash attention, TPU Pallas.

TPU-native design (not a CUDA port):
  * grid = (B, H, S/bq, S/bk); the kv dimension is the innermost
    ("arbitrary") axis so the f32 running-softmax state (m, l, acc) lives in
    VMEM scratch across kv steps — the HBM->VMEM pipeline streams K/V tiles
    while the MXU consumes the previous tile.
  * bq x bk tiles are MXU-aligned (128 x 128 default); scores never leave
    VMEM — HBM traffic is Q + K + V + O only (the memory-roofline win over
    the XLA-visible reference path).
  * GQA is native: the k/v BlockSpec index-maps q-head h to kv-head
    h // (H/Hkv); no materialized head repeat.
  * causal/window block skipping: fully-masked tiles are skipped via
    pl.when, halving compute for causal and bounding it for sliding window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * bq
    k_start = j * bk
    # tile-level skip: tile is live unless entirely above the diagonal
    # (causal) or entirely behind the window
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window > 0:
        live &= k_start + bk - 1 > q_start - window

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (bq, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None,
                           block_q: int = DEFAULT_BQ,
                           block_k: int = DEFAULT_BK,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, S, hd); k/v: (B, Hkv, S, hd).  S % block == 0."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    group = H // Hkv

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
