"""Pure-jnp oracle for the grouped (per-expert) matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_reference(x, w):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F) in f32 accumulation."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def expert_mlp_reference(x, w_gate, w_up, w_down):
    """The fused expert FFN the MoE layer runs per expert group."""
    import jax
    h = jax.nn.silu(gmm_reference(x, w_gate).astype(jnp.float32))
    h = h * gmm_reference(x, w_up).astype(jnp.float32)
    return gmm_reference(h.astype(x.dtype), w_down)
