"""jit'd public wrapper for the grouped-matmul kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gmm.kernel import (
    DEFAULT_BC, DEFAULT_BD, DEFAULT_BF, gmm_kernel)
from repro.kernels.gmm.ref import expert_mlp_reference, gmm_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _shrink(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def gmm(x, w, *, block_c: int = DEFAULT_BC, block_f: int = DEFAULT_BF,
        block_d: int = DEFAULT_BD, interpret: bool | None = None):
    """Grouped matmul x (E, C, D) @ w (E, D, F) -> (E, C, F)."""
    if interpret is None:
        interpret = not _on_tpu()
    E, C, D = x.shape
    F = w.shape[2]
    return gmm_kernel(x, w, block_c=_shrink(block_c, C),
                      block_f=_shrink(block_f, F),
                      block_d=_shrink(block_d, D), interpret=interpret)


def expert_mlp(x, w_gate, w_up, w_down, **kw):
    """Per-expert gated FFN using three grouped matmuls."""
    h = jax.nn.silu(gmm(x, w_gate, **kw).astype(jnp.float32))
    h = h * gmm(x, w_up, **kw).astype(jnp.float32)
    return gmm(h.astype(x.dtype), w_down, **kw)


__all__ = ["gmm", "expert_mlp", "gmm_reference", "expert_mlp_reference"]
