"""Grouped (per-expert) matmul, TPU Pallas — the MoE expert-compute hot-spot.

TPU-native design:
  * grid = (E, C/bc, F/bf, D/bd): one expert per outer step; the contraction
    axis D is innermost/"arbitrary" with an f32 VMEM accumulator, so each
    (bc x bf) output tile is written to HBM exactly once.
  * 128-aligned (bc, bf, bd) tiles feed the MXU at its native shape; the
    per-expert weight tiles stream HBM->VMEM while the previous tile is in
    the MXU (double buffering comes from the sequential grid pipeline).
  * This is the dense-capacity formulation (tokens pre-gathered per expert
    by the dispatch scatter); ragged group sizes are handled one level up
    by capacity padding, keeping the kernel shape-static for the compiler.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import pltpu

DEFAULT_BC = 128
DEFAULT_BF = 128
DEFAULT_BD = 256


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr, *, nd: int):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                                          # (bc, bd)
    w = w_ref[0]                                          # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def gmm_kernel(x, w, *, block_c: int = DEFAULT_BC, block_f: int = DEFAULT_BF,
               block_d: int = DEFAULT_BD, interpret: bool = False):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0, (C, F, D, bc, bf, bd)
    nd = D // bd

    kernel = functools.partial(_gmm_kernel, nd=nd)
    grid = (E, C // bc, F // bf, nd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, d: (e, i, d)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, d: (e, d, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, d: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="gmm",
    )(x, w)
