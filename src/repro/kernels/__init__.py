"""Pallas TPU kernels for the model zoo's compute hot-spots.

Each kernel package has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (auto interpret=True off-TPU)
  ref.py    — pure-jnp oracle the tests sweep against

Runtime dispatch: the model layers call ``use_kernels()``; modes
  auto      — Pallas on TPU, jnp reference on CPU (default)
  interpret — Pallas interpreter everywhere (CPU integration tests)
  off       — always the jnp reference
set via ``set_mode`` or env ``REPRO_PALLAS``.
"""
from __future__ import annotations

import os

import jax

_MODE = os.environ.get("REPRO_PALLAS", "auto")


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in ("auto", "interpret", "off"), mode
    _MODE = mode


def get_mode() -> str:
    return _MODE


def use_kernels() -> bool:
    if _MODE == "off":
        return False
    if _MODE == "interpret":
        return True
    return jax.default_backend() == "tpu"
