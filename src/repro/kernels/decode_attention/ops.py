"""jit'd public wrapper for flash-decode."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import (
    DEFAULT_BK, decode_attention_kernel)
from repro.kernels.decode_attention.ref import decode_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("ring", "scale", "block_k",
                                             "interpret"))
def decode_attention(q, k, v, pos, *, ring: bool = False,
                     scale: float | None = None, block_k: int = DEFAULT_BK,
                     interpret: bool | None = None) -> jax.Array:
    """q: (B, H, hd); k/v: (B, Hkv, S, hd); pos: () or (B,) int32
    -> (B, H, hd).

    ``pos`` may be a scalar (whole batch at one position — the classic
    run-to-completion decode loop) or a per-request vector (continuous
    batching: every row is at its own position; masking and tile skipping
    are per row).

    Pads the cache length to a block multiple; padded slots have index
    > pos for the non-ring case and are excluded by an explicit bound for
    the ring case (the ring wraps at the true S, so we keep S aligned by
    choosing bk | S instead when possible).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    bk = min(block_k, S)
    while S % bk != 0:          # ring caches must not pad: shrink the block
        bk //= 2
    qg = q.reshape(B, Hkv, G, hd)
    out = decode_attention_kernel(qg, k, v, pos, ring=ring, scale=scale,
                                  block_k=bk, interpret=interpret)
    return out.reshape(B, H, hd)


__all__ = ["decode_attention", "decode_reference"]
