"""Flash-decode, TPU Pallas: one token's attention over a long KV cache.

TPU-native design:
  * GQA is exploited for MXU occupancy: the G = H/Hkv query heads of one kv
    head are batched into a single (G, hd) x (hd, bk) matmul per KV tile —
    the decode analogue of grouping queries, instead of CUDA's
    one-warp-per-head pattern.
  * grid = (B, Hkv, S/bk): the cache-scan axis is innermost/"arbitrary";
    the running-softmax state (m, l, acc) persists in VMEM scratch, so HBM
    traffic is exactly one read of the K/V cache + one vector write.
  * ``pos`` arrives via scalar prefetch (SMEM) as a per-request ``(B,)``
    vector: tiles beyond a request's valid length are skipped *before*
    their DMA is issued — the bandwidth saving that makes early-decode
    steps cheap, now per batch row (continuous batching mixes requests at
    very different positions in one step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import pltpu

NEG_INF = -1e30
DEFAULT_BK = 512


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, ring: bool,
                   bk: int, nk: int, S: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = j * bk
    live = jnp.logical_or(k_start <= pos, jnp.bool_(ring) & (pos >= S))

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if ring:
            valid = (cols <= pos % S) | (pos >= S)
        else:
            valid = cols <= pos
        s = jnp.where(valid, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, pos, *, ring: bool = False,
                            scale: float | None = None,
                            block_k: int = DEFAULT_BK,
                            interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, hd); k/v: (B, Hkv, S, hd); pos: (B,) int32 — the
    valid length per batch row (scalars are broadcast by the wrapper)."""
    B, Hkv, G, hd = q.shape
    S = k.shape[2]
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    if scale is None:
        scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_decode_kernel, scale=scale, ring=ring,
                               bk=bk, nk=nk, S=S)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, pos: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, pos: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, pos: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="decode_attention",
    )(jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,)), q, k, v)
