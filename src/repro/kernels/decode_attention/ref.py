"""Pure-jnp oracle for flash-decode: one query token vs a KV cache.

Layout: q (B, H, hd); k/v cache (B, Hkv, S, hd); ``pos`` is the position of
the current token (its k/v already written at its slot) — a scalar, or a
per-request (B,) vector when rows sit at different positions (continuous
batching).

Validity:
  * full cache   — slots [0, pos] are valid.
  * ring cache   — (sliding window, cache length == window): every slot is
    valid once the ring has wrapped (pos >= S), else slots [0, pos].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_reference(q, k, v, pos, *, ring: bool = False,
                     scale: float | None = None) -> jax.Array:
    B, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    G = H // Hkv
    qh = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bngd,bnsd->bngs", qh, k.astype(jnp.float32)) * scale
    idx = jnp.arange(S)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))[
        :, None, None, None]                               # (B,1,1,1)
    if ring:
        valid = (idx <= pos % S) | (pos >= S)
    else:
        valid = idx <= pos
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngs,bnsd->bngd", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
