"""Data pipeline: determinism, hierarchy structure, cursor semantics."""
import jax.numpy as jnp
import numpy as np

from repro.train.data import HierarchicalTask, SyntheticTokens


def test_batches_deterministic_by_step():
    src = SyntheticTokens(vocab=128, seq_len=16, batch=4, seed=3)
    a = src.batch_at(7)["tokens"]
    b = src.batch_at(7)["tokens"]
    c = src.batch_at(8)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_any_worker_recomputes_any_batch():
    """Stateless source: resume/elastic rebalancing needs batch_at(step) to
    be a pure function."""
    s1 = SyntheticTokens(vocab=64, seq_len=8, batch=2, seed=0)
    s2 = SyntheticTokens(vocab=64, seq_len=8, batch=2, seed=0)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(
            np.asarray(s1.batch_at(step)["tokens"]),
            np.asarray(s2.batch_at(step)["tokens"]))


def test_hierarchical_task_structure():
    t = HierarchicalTask(num_super=5, subs_per_super=4, vocab=32,
                         seq_len=16)
    x, sub, sup = t.sample(64, seed=1)
    assert x.shape == (64, 16)
    np.testing.assert_array_equal(np.asarray(sup),
                                  np.asarray(sub) // 4)
    # distributions are valid
    assert np.allclose(t.dists.sum(-1), 1.0)


def test_hierarchical_subclass_filter():
    t = HierarchicalTask(num_super=3, subs_per_super=2, vocab=16, seq_len=8)
    x, sub, sup = t.sample(32, seed=0, subclasses=np.array([0, 1]))
    assert set(np.asarray(sub)) <= {0, 1}
    assert set(np.asarray(sup)) == {0}


def test_patch_spec_included():
    src = SyntheticTokens(vocab=64, seq_len=8, batch=2, patch_spec=(4, 16))
    b = src.batch_at(0)
    assert b["patch_embeds"].shape == (2, 4, 16)
    assert b["patch_embeds"].dtype == jnp.bfloat16
