"""Sharded page bank: multi-shard paged slot pools with locality-routed
admission.

Covers the ShardedPagePool allocator contract (per-shard free-lists,
least-loaded routing, spanning allocation, shard-aware blocked
reasons), the bitwise token-identity matrix against the single-shard
paged engine (greedy + seeded temperature, one-shot + chunked, with
prefix-cache hits), per-shard leak freedom under randomized
admit/retire/fail traffic with deterministic replay, prefix-index
persistence across engine reset, and the scheduler's blocked-admission
attribution counters.  Mesh placement / shard_map local reads run in a
subprocess with forced host devices — see ``_sharded_worker.py``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.engine import EngineKey, StepEngine
from repro.serve.pool import PagePool, ShardedPagePool


@pytest.fixture(scope="module")
def f32_lm():
    cfg = reduced_arch("tinyllama-1.1b", dtype="float32",
                       param_dtype="float32")
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(0))


def _drain(eng, p):
    while eng.live_slots():
        eng.step(p)


# ---------------------------------------------------------------------------
# ShardedPagePool allocator contract
# ---------------------------------------------------------------------------

def test_sharded_page_pool_contract():
    pool = ShardedPagePool(12, 4)          # 3 pages/shard, local 0 reserved
    assert pool.allocatable == 8
    assert pool.per_shard_allocatable == 2
    assert pool.free_pages() == 8
    # page-id encoding: global id == shard * pages_per_shard + local
    assert [pool.shard_of(p) for p in (1, 3, 7, 11)] == [0, 1, 2, 3]
    # cold admissions route least-loaded, ties to the lowest shard index
    assert pool.route(1) == 0
    a = pool.take(2)
    assert a == [1, 2]                      # whole request on shard 0
    assert pool.route(1) == 1               # 0 is now the fullest
    b = pool.take(1)
    assert b == [4]                         # shard 1's first local page
    # local page 0 of every shard is reserved — never allocated
    reserved = {s * pool.pages_per_shard for s in range(4)}
    taken = set(a) | set(b)
    assert not (taken & reserved)
    # spanning: > per-shard capacity draws most-free first
    big = pool.take(5)
    assert len(big) == 5 and not (set(big) & reserved)
    assert pool.free_pages() == 0
    # blocked distinguishes global from shard-local shortage
    pool.release(a)                         # shard 0 has 2 free again
    assert pool.blocked(2) is None
    assert pool.blocked(1, shard=1) == "shard_pages"   # room, wrong shard
    assert pool.blocked(3) == "pages"       # 3 > per-shard -> spans; 2 free
    pool.release(big[:2])                   # one page back on two shards
    # rows: first takes shard 0 whole; second routes to a 1-free shard
    # needing 2 — pages exist pool-wide, not where the row must land
    assert pool.blocked_rows(2, 2) == "shard_pages"
    assert pool.blocked_rows(1, 5) == "pages"   # spans; only 4 free total
    # release returns a page to its OWNING shard's list
    pool.release([b[0]])
    assert pool.shard_free(1) == 1
    # adopt pulls one specific free page (prefix-index restore)
    assert pool.adopt(b[0])
    assert not pool.adopt(b[0])             # already allocated
    assert pool.refcount(b[0]) == 1
    pool.reset()
    assert pool.free_pages() == 8
    with pytest.raises(ValueError):
        ShardedPagePool(10, 4)              # must divide
    with pytest.raises(ValueError):
        ShardedPagePool(4, 4)               # 1 page/shard: park only


def test_sharded_pool_restore_front_order():
    pool = ShardedPagePool(8, 2)
    a = pool.take(3)                        # shard 0's 3 pages
    assert a == [1, 2, 3]
    pool.restore(a)                         # failed admit: FRONT, in order
    assert pool.take(3) == a


# ---------------------------------------------------------------------------
# token identity: sharded engine vs single-shard paged engine (bitwise)
# ---------------------------------------------------------------------------

def _run_stream(eng, p, prompts, steps, seeds):
    gens = [eng.admit(p, prompts[0], max_new=steps, seeds=[seeds[0]])[0]]
    for _ in range(2):
        eng.step(p)
    gens.append(eng.admit(p, prompts[1], max_new=steps,
                          seeds=[seeds[1]])[0])
    _drain(eng, p)
    return [g.tokens for g in gens]


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("chunk", [None, 8])
def test_sharded_streams_bitwise_identical(f32_lm, temperature, chunk):
    """Sharding the page bank only changes WHICH pool pages a request's
    tables point at; the gather through the table is permutation-
    invariant in page ids, so streams stay bitwise-identical to the
    single-shard paged engine — greedy and seeded temperature, one-shot
    and chunked admission."""
    cfg, m, p = f32_lm
    steps = 5
    prompts = [np.asarray(tokens_for(cfg, 1, 12, seed=3)),
               np.asarray(tokens_for(cfg, 1, 40, seed=4))]
    seeds = [7, 9] if temperature > 0 else [None, None]

    one = StepEngine(m, batch_size=2, max_len=256, temperature=temperature,
                     paged=True, page_size=64, prefill_chunk=chunk)
    ref = _run_stream(one, p, prompts, steps, seeds)
    eng = StepEngine(m, batch_size=2, max_len=256, temperature=temperature,
                     paged=True, page_size=64, prefill_chunk=chunk,
                     shards=4)
    got = _run_stream(eng, p, prompts, steps, seeds)
    assert got == ref
    assert eng.free_pages() == eng._pages.allocatable
    assert eng._pages.num_shards == 4


def test_sharded_prefix_hit_bitwise_and_routed(f32_lm):
    """Prefix-cache hits on a sharded bank: the resubmission maps the
    cached pages read-only (same stream bitwise), and its fresh pages
    land on the shard already holding the cached run — locality routing,
    observed through the pool's shard ownership."""
    cfg, m, p = f32_lm
    prompt = np.asarray(tokens_for(cfg, 1, 24, seed=5))

    def run(eng):
        out = [eng.admit(p, prompt, max_new=4)[0]]
        _drain(eng, p)
        out.append(eng.admit(p, prompt, max_new=4)[0])
        _drain(eng, p)
        return [g.tokens for g in out]

    one = StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=8,
                     prefix_cache=True)
    ref = run(one)
    eng = StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=8,
                     prefix_cache=True, shards=2, num_pages=32)
    gens = [eng.admit(p, prompt, max_new=4)[0]]
    first_pages = list(eng.slots[gens[0].slot].pages)
    _drain(eng, p)
    assert eng.stats["prefix_hits"] == 0
    g2 = eng.admit(p, prompt, max_new=4)[0]
    assert eng.stats["prefix_hits"] == 1
    hit_pages = list(eng.slots[g2.slot].pages)
    # the hit's whole allocation sits on the shard of the cached run
    shards = {eng._pages.shard_of(pg) for pg in hit_pages}
    assert shards == {eng._pages.shard_of(first_pages[0])}
    _drain(eng, p)
    got = [gens[0].tokens, g2.tokens]
    assert got == ref


# ---------------------------------------------------------------------------
# per-shard leak fuzz: free + reachable == allocatable, per shard
# ---------------------------------------------------------------------------

def _check_shard_invariants(eng):
    pool = eng._pages
    held = [g.pages for g in eng.slots if g is not None and g.pages]
    table_pages = [pg for pages in held for pg in pages]
    reachable = set(table_pages) | eng._prefix.pages()
    free_ids = [list(dq) for dq in pool._shards]
    all_free = {pg for dq in free_ids for pg in dq}
    # no page is simultaneously free and referenced, on any shard
    assert not (all_free & set(pool._ref)), sorted(all_free & set(pool._ref))
    for s in range(pool.num_shards):
        own = {pg for pg in reachable if pool.shard_of(pg) == s}
        # conservation PER SHARD, not just pool-wide: a page freed to the
        # wrong shard's list keeps the global sum intact but breaks this
        assert len(free_ids[s]) + len(own) == pool.per_shard_allocatable, (
            s, sorted(free_ids[s]), sorted(own))
        for pg in free_ids[s]:
            assert pool.shard_of(pg) == s, (s, pg)
    # refcounts: tables + index pin, exactly (no cross-shard drift)
    for pg in reachable:
        want = table_pages.count(pg) + (1 if pg in eng._prefix.pages()
                                        else 0)
        cow_pins = [ps.cow[0] for ps in eng._pending if ps.cow is not None]
        want += cow_pins.count(pg)
        assert pool.refcount(pg) == want, (pg, want, pool.refcount(pg))


def _shard_fuzz_run(m, p, cfg, seed):
    rng = np.random.default_rng(seed)
    eng = StepEngine(m, batch_size=3, max_len=32, paged=True, page_size=4,
                     prefill_chunk=8, prefix_cache=True, shards=4,
                     num_pages=24)
    families = [np.asarray(tokens_for(cfg, 1, 28, seed=100 + i))
                for i in range(3)]
    streams = []
    for _ in range(40):
        act = rng.integers(0, 3)
        if act == 0 and eng.free_slots() and not eng.pending_slots():
            fam = families[rng.integers(0, len(families))]
            cut = int(rng.integers(4, 25))
            toks = fam[:, :cut].copy()
            if rng.random() < 0.5:
                toks[0, -1] = int((toks[0, -1] + 1) % cfg.vocab_size)
            if eng.can_admit(toks, 3):
                eng.admit(p, toks, max_new=3)
        elif act == 1 and eng.live_slots():
            for g in eng.step(p):
                streams.append(tuple(g.tokens))
        elif act == 2 and eng.live_slots():
            for g in eng.drain(p):
                streams.append(tuple(g.tokens))
        _check_shard_invariants(eng)
    for g in eng.drain(p):
        streams.append(tuple(g.tokens))
    _check_shard_invariants(eng)
    free_lists = [tuple(dq) for dq in eng._pages._shards]
    return streams, free_lists, dict(eng.stats)


def test_shard_fuzz_leak_free_and_replays(f32_lm):
    """Randomized admit/step/drain traffic over a 4-shard bank: after
    every event each shard conserves its pages (free + reachable ==
    per-shard allocatable, free-lists hold only own-shard ids, refcounts
    exact), and the deterministic routing makes the whole run — streams,
    final per-shard free-list ORDER, stats — replay exactly."""
    cfg, m, p = f32_lm
    s1, f1, st1 = _shard_fuzz_run(m, p, cfg, seed=0)
    s2, f2, st2 = _shard_fuzz_run(m, p, cfg, seed=0)
    assert s1 == s2 and f1 == f2 and st1 == st2
    assert st1["prefix_hits"] > 0


# ---------------------------------------------------------------------------
# prefix-index persistence across reset
# ---------------------------------------------------------------------------

def test_prefix_index_survives_reset(f32_lm):
    """``reset(keep_prefix=True)``: the trie snapshots before teardown
    and re-adopts its pages after — the bank bytes were never dropped
    (reset reuses the cache arrays), so a resubmission still hits."""
    cfg, m, p = f32_lm
    prompt = np.asarray(tokens_for(cfg, 1, 24, seed=5))
    eng = StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=8,
                     prefix_cache=True, shards=2, num_pages=32)
    ref = eng.admit(p, prompt, max_new=4)[0]
    _drain(eng, p)
    cached = set(eng._prefix.pages())
    assert cached
    eng.reset(keep_prefix=True)
    assert set(eng._prefix.pages()) == cached       # same pages re-pinned
    assert eng.free_pages() == eng._pages.allocatable - len(cached)
    g = eng.admit(p, prompt, max_new=4)[0]
    assert eng.stats["prefix_hits"] == 1
    _drain(eng, p)
    assert g.tokens == ref.tokens


def test_prefix_index_export_restore_roundtrip(f32_lm):
    """Explicit snapshot/restore: ``export_prefix_index`` captures the
    trie, a plain ``reset()`` drops it, ``restore_prefix_index`` adopts
    back every page still free — and pages reallocated in between drop
    out with their subtrees instead of aliasing someone else's bytes."""
    cfg, m, p = f32_lm
    prompt = np.asarray(tokens_for(cfg, 1, 24, seed=5))
    eng = StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=8,
                     prefix_cache=True)
    eng.admit(p, prompt, max_new=4)
    _drain(eng, p)
    snap = eng.export_prefix_index()
    cached = set(eng._prefix.pages())
    eng.reset()                             # keeps arrays, drops the index
    assert not eng._prefix.pages()
    adopted = eng.restore_prefix_index(snap)
    assert set(adopted) == cached
    g = eng.admit(p, prompt, max_new=4)[0]
    assert eng.stats["prefix_hits"] == 1
    _drain(eng, p)

    # stale snapshot: hand the cached pages to someone else first
    eng2 = StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=8,
                      prefix_cache=True)
    eng2.admit(p, prompt, max_new=4)
    _drain(eng2, p)
    snap2 = eng2.export_prefix_index()
    eng2.reset()
    eng2._pages.take(eng2._pages.allocatable)       # recycle everything
    assert eng2.restore_prefix_index(snap2) == []   # nothing adoptable
    assert not eng2._prefix.pages()


def test_prefix_restore_rejects_mismatched_snapshot(f32_lm):
    cfg, m, p = f32_lm
    eng = StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=8,
                     prefix_cache=True)
    snap = eng.export_prefix_index()
    other = StepEngine(m, batch_size=2, max_len=64, paged=True,
                       page_size=16, prefix_cache=True)
    with pytest.raises(ValueError):
        other.restore_prefix_index(snap)            # page_size mismatch
    plain = StepEngine(m, batch_size=2, max_len=64, paged=True,
                       page_size=8)
    with pytest.raises(ValueError):
        plain.restore_prefix_index(snap)            # cache off


# ---------------------------------------------------------------------------
# admission-block attribution
# ---------------------------------------------------------------------------

def test_engine_reports_admit_block_reason(f32_lm):
    cfg, m, p = f32_lm
    eng = StepEngine(m, batch_size=2, max_len=32, paged=True, page_size=4,
                     shards=2, num_pages=20)     # 9 allocatable per shard
    toks = np.asarray(tokens_for(cfg, 1, 8, seed=1))
    assert eng.can_admit(toks, 2) and eng.last_admit_block is None
    g1 = eng.admit(p, toks, max_new=2)[0]
    g2 = eng.admit(p, toks, max_new=2)[0]
    assert not eng.can_admit(toks, 2)
    assert eng.last_admit_block == "slots"       # pool is slot-bound
    del g1, g2


def test_engine_reports_shard_pages_block(f32_lm):
    """Pages exist pool-wide but not on the shard the request routes to:
    the block reason says so (``shard_pages``), distinguishing a
    placement problem from a capacity problem."""
    cfg, m, p = f32_lm
    eng = StepEngine(m, batch_size=3, max_len=32, paged=True, page_size=4,
                     shards=2, num_pages=18)     # 8 allocatable per shard
    long = np.asarray(tokens_for(cfg, 1, 24, seed=1))   # 7 pages: 1 shard
    eng.admit(p, long, max_new=2)                # shard 0 down to 1 free
    eng.admit(p, long, max_new=2)                # shard 1 down to 1 free
    mid = np.asarray(tokens_for(cfg, 1, 6, seed=2))     # needs 2 pages
    assert not eng.can_admit(mid, 2)             # 2 free total, 1 + 1...
    assert eng.last_admit_block == "shard_pages"
    tiny = np.asarray(tokens_for(cfg, 1, 2, seed=2))    # 1 page fits
    assert eng.can_admit(tiny, 0)
    assert eng.last_admit_block is None


def test_scheduler_attributes_blocked_admissions():
    """ContinuousScheduler counters split WHY the queue head could not
    admit: no slots vs no pages vs no pages on the routed shard."""
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    names = ["supersub-sub"]
    server, cfgs = build_server(names, 2, 32,
                                arch_overrides={"dtype": "float32",
                                                "param_dtype": "float32"})
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfgs[names[0]].vocab_size, (1, 24))
    with ContinuousScheduler(server, batch_size=3, paged=True, page_size=4,
                             shards=2) as sched:
        futs = [sched.submit(names[0], toks, steps=4) for _ in range(4)]
        for f in futs:
            f.result(timeout=300)
    stats = sched.stats
    # 24-token prompts fill a whole shard each; with 2 shards the third+
    # queued request must wait on shard pages at some point
    assert stats["admit_blocked_no_shard_pages"] > 0 or \
        stats["admit_blocked_no_pages"] > 0 or \
        stats["admit_blocked_no_slots"] > 0
    assert stats["admitted_requests"] == 4
    server.shutdown()


# ---------------------------------------------------------------------------
# EngineKey / construction plumbing
# ---------------------------------------------------------------------------

def test_engine_key_has_shards_field():
    k = EngineKey(name="a", batch_size=4, page_size=8, shards=4)
    assert k.shards == 4
    assert k != EngineKey(name="a", batch_size=4, page_size=8)
    assert EngineKey(name="a", batch_size=4).shards == 1


def test_sharded_engine_guards(f32_lm):
    cfg, m, p = f32_lm
    with pytest.raises(ValueError, match="paged"):
        StepEngine(m, batch_size=2, max_len=64, shards=4)
    with pytest.raises(ValueError, match="divide"):
        StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=16,
                   shards=3, num_pages=16)
    with pytest.raises(ValueError, match="worst-case"):
        StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=16,
                   shards=4, num_pages=4)    # 0 allocatable pages/shard
    with pytest.raises(ValueError, match="mesh"):
        StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=16,
                   local_read=True)          # local_read needs a mesh


def test_default_page_budget_scales_with_shards(f32_lm):
    """Default sizing gives every shard the batch's worst case share
    plus one spare, and reduces to the old batch*ppr+1 at one shard."""
    cfg, m, p = f32_lm
    one = StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=16)
    assert one._pages.total_pages == 2 * 4 + 1
    four = StepEngine(m, batch_size=2, max_len=64, paged=True,
                      page_size=16, shards=4)
    assert four._pages.total_pages == 4 * (2 + 1)
    assert four._pages.per_shard_allocatable == 2
