"""Multi-device distribution semantics, via an 8-fake-device subprocess
(keeps the main pytest process at 1 device, per the dry-run isolation rule).

Covers: EP MoE all_to_all dispatch, sharded-vs-single-device training
equivalence, int8 error-feedback gradient compression, ppermute pipeline
parallelism, elastic restore on a smaller mesh, and sequence-sharded
(SP) decode.
"""
import json
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "_distributed_worker.py")


@pytest.fixture(scope="module")
def worker_results():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, WORKER], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS_JSON:")]
    assert line, out.stdout + out.stderr[-2000:]
    return json.loads(line[-1][len("RESULTS_JSON:"):])


@pytest.mark.parametrize("check", [
    "moe_ep_vs_ref", "sharded_train_step", "int8_ef_compression",
    "pipeline_1f1b", "elastic_restore", "sp_decode_seq_sharded_kv"])
def test_distributed_check(worker_results, check):
    res = worker_results.get(check)
    assert res is not None, f"check {check} did not run: {worker_results}"
    assert res["ok"], res
