"""Sharded-page-bank multi-device checks, run in a subprocess with 4
fake host devices (the CI ``multi-device`` job exports the same flag).

Prints one JSON line: RESULTS_JSON:{check: {"ok": bool, ...}}.
Invoked by tests/test_sharded_devices.py; runnable standalone:
    python tests/_sharded_worker.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from conftest import reduced_arch, tokens_for  # noqa: E402
from repro.distributed.mesh import make_mesh  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import StepEngine  # noqa: E402

RESULTS = {}


def record(name, ok, **extra):
    RESULTS[name] = {"ok": bool(ok), **extra}


def _run_stream(eng, p, prompts, steps, seeds):
    gens = [eng.admit(p, prompts[0], max_new=steps, seeds=[seeds[0]])[0]]
    for _ in range(2):
        eng.step(p)
    gens.append(eng.admit(p, prompts[1], max_new=steps,
                          seeds=[seeds[1]])[0])
    while eng.live_slots():
        eng.step(p)
    return [g.tokens for g in gens]


def main():
    assert jax.device_count() == 4, jax.device_count()
    cfg = reduced_arch("tinyllama-1.1b", dtype="float32",
                       param_dtype="float32")
    m = build_model(cfg, cache_dtype=jnp.float32)
    p = m.init(jax.random.key(0))
    mesh = make_mesh((4,), ("model",))
    prompts = [np.asarray(tokens_for(cfg, 1, 12, seed=3)),
               np.asarray(tokens_for(cfg, 1, 40, seed=4))]

    # the single-device reference streams the signature invariant pins
    refs = {}
    for temp, seeds in ((0.0, [None, None]), (0.8, [7, 9])):
        for chunk in (None, 8):
            one = StepEngine(m, batch_size=2, max_len=256,
                             temperature=temp, paged=True, page_size=64,
                             prefill_chunk=chunk)
            refs[(temp, chunk)] = _run_stream(one, p, prompts, 5, seeds)

    # 1. mesh placement: bank leaves actually live sharded over the mesh
    eng = StepEngine(m, batch_size=2, max_len=256, paged=True,
                     page_size=64, mesh=mesh)
    leaf = eng.state.caches["b0"].k
    sh = leaf.sharding
    placed = (getattr(sh, "mesh", None) is not None
              and "model" in str(sh.spec)
              and len(leaf.devices()) == 4)
    record("bank_placed_over_mesh", placed, spec=str(sh))

    # 2. signature invariant: sharded streams bitwise-identical to the
    # single-device paged engine (greedy + seeded temperature, one-shot
    # + chunked), under forced host device count 4
    ok = True
    for temp, seeds in ((0.0, [None, None]), (0.8, [7, 9])):
        for chunk in (None, 8):
            eng = StepEngine(m, batch_size=2, max_len=256,
                             temperature=temp, paged=True, page_size=64,
                             prefill_chunk=chunk, mesh=mesh)
            got = _run_stream(eng, p, prompts, 5, seeds)
            if got != refs[(temp, chunk)]:
                ok = False
                record(f"mesh_bitwise_t{temp}_c{chunk}", False,
                       got=got, want=refs[(temp, chunk)])
    record("mesh_streams_bitwise", ok)

    # 3. prefix hits stay bitwise under the mesh too
    def hit_run(eng):
        out = [eng.admit(p, prompts[0], max_new=4)[0]]
        while eng.live_slots():
            eng.step(p)
        out.append(eng.admit(p, prompts[0], max_new=4)[0])
        while eng.live_slots():
            eng.step(p)
        return [g.tokens for g in out], eng.stats["prefix_hits"]

    ref_hit, _ = hit_run(StepEngine(m, batch_size=2, max_len=256,
                                    paged=True, page_size=8,
                                    prefix_cache=True))
    got_hit, hits = hit_run(StepEngine(m, batch_size=2, max_len=256,
                                       paged=True, page_size=8,
                                       prefix_cache=True, mesh=mesh))
    record("mesh_prefix_bitwise", got_hit == ref_hit and hits == 1,
           hits=int(hits))

    # 4. local_read: every shard's kernel instance reads only its local
    # bank slice inside shard_map; the cross-shard flash combine changes
    # reduction order, so this tier is greedy-identical in practice and
    # gated allclose on logits-equivalent streams
    eng = StepEngine(m, batch_size=2, max_len=256, paged=True,
                     page_size=64, mesh=mesh, local_read=True)
    got = _run_stream(eng, p, prompts, 5, [None, None])
    record("local_read_greedy_streams", got == refs[(0.0, None)],
           got=got, want=refs[(0.0, None)])
    eng = StepEngine(m, batch_size=2, max_len=256, paged=True,
                     page_size=64, prefill_chunk=8, mesh=mesh,
                     local_read=True)
    got = _run_stream(eng, p, prompts, 5, [None, None])
    record("local_read_chunked_streams", got == refs[(0.0, 8)])

    print("RESULTS_JSON:" + json.dumps(RESULTS))


if __name__ == "__main__":
    main()
