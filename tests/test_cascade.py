"""Super-Sub dynamic inference (paper Fig 6a/b, S1a): dynamic >= static
accuracy, pipelined prefetch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeMember, SuperSubCascade
from repro.core.context import ContextSwitchEngine
from repro.train.data import HierarchicalTask


@pytest.fixture(scope="module")
def task():
    return HierarchicalTask(num_super=4, subs_per_super=3, vocab=64,
                            seq_len=48, seed=0)


def _members(task, noise=0.35, seed=0):
    """Bayes-style classifiers from the task's true distributions.

    The generalist sees *noisy* log-likelihoods over all subclasses (it must
    spread capacity); each specialist has clean likelihoods but only within
    its superclass — the paper's premise, without training a network in the
    unit test (examples/train_cascade.py trains real ones).
    """
    rng = np.random.default_rng(seed)
    logd = np.log(task.dists + 1e-9)                    # (num_sub, vocab)
    sup_of = task.sub_of_super

    def counts(x):
        return jax.vmap(lambda r: jnp.bincount(r, length=task.vocab))(x)

    def super_fn(params, x):
        c = counts(x).astype(jnp.float32)
        sub_ll = c @ params["logd"].T                   # (B, num_sub)
        sup_ll = jnp.zeros((x.shape[0], task.num_super))
        return sup_ll.at[:, params["sup_of"]].add(
            jax.nn.softmax(sub_ll, -1))

    def make_generalist():
        noisy = logd + rng.normal(0, noise, logd.shape)
        return {"logd": jnp.asarray(noisy, jnp.float32),
                "sup_of": jnp.asarray(sup_of)}

    def gen_fn(params, x):
        c = counts(x).astype(jnp.float32)
        return c @ params["logd"].T

    def make_specialist(g):
        subs = np.where(sup_of == g)[0]
        return {"logd": jnp.asarray(logd[subs], jnp.float32)}

    def spec_fn(params, x):
        c = counts(x).astype(jnp.float32)
        return c @ params["logd"].T                     # local sub ids

    sup = CascadeMember("super", super_fn,
                        lambda: {"logd": jnp.asarray(logd, jnp.float32),
                                 "sup_of": jnp.asarray(sup_of)})
    gen = CascadeMember("generalist", gen_fn, make_generalist)
    specs = [CascadeMember(f"spec{g}", spec_fn,
                           lambda g=g: make_specialist(g), covers=g)
             for g in range(task.num_super)]
    return sup, gen, specs


def test_dynamic_beats_static(task):
    sup, gen, specs = _members(task)
    eng = ContextSwitchEngine(num_slots=2)
    cas = SuperSubCascade(eng, sup, specs, gen, task.sub_of_super)
    accs = []
    for b in range(6):
        x, sub, _ = task.sample(64, seed=b)
        # batches are single-superclass (the paper's workflow infers one
        # superclass per batch before specializing)
        pick = sub == sub[0]
        accs.append(cas.evaluate(np.asarray(x)[np.asarray(pick)],
                                 np.asarray(sub)[np.asarray(pick)],
                                 batch=int(pick.sum())))
    dyn = np.mean([a["dynamic_acc"] for a in accs])
    sta = np.mean([a["static_acc"] for a in accs])
    assert dyn >= sta, (dyn, sta)   # paper: up to +3 % — must not be worse
    eng.shutdown()


def test_pipelined_matches_sequential(task):
    sup, gen, specs = _members(task)
    eng = ContextSwitchEngine(num_slots=3)
    cas = SuperSubCascade(eng, sup, specs, gen, task.sub_of_super)
    batches = []
    for b in range(4):
        x, sub, _ = task.sample(16, seed=100 + b,
                                subclasses=np.array([3 * (b % 4)]))
        batches.append(x)
    seq = [cas.dynamic_infer(x) for x in batches]
    eng2 = ContextSwitchEngine(num_slots=3)
    cas2 = SuperSubCascade(eng2, sup, specs, gen, task.sub_of_super)
    pipe = cas2.dynamic_infer_pipelined(batches)
    for a, b in zip(seq, pipe):
        assert a["super"] == b["super"]
        np.testing.assert_array_equal(a["sub"], b["sub"])
    eng.shutdown()
    eng2.shutdown()


def test_pipelined_hides_specialist_load(task):
    """Fig S1(a)'s point: with one batch kept in flight, the specialist's
    weight streaming overlaps real execution — the engine must account
    hidden reconfiguration time (the old implementation drained each
    batch immediately, so nothing ever overlapped)."""
    import time as _time
    sup, gen, specs = _members(task)

    def slow(m, delay=0.05):
        inner = m.weights_fn

        def weights_fn():
            _time.sleep(delay)          # emulate streaming a real context
            return inner()
        return CascadeMember(m.name, m.apply_fn, weights_fn, covers=m.covers)

    eng = ContextSwitchEngine(num_slots=3)
    cas = SuperSubCascade(eng, slow(sup), [slow(s) for s in specs],
                          slow(gen), task.sub_of_super)
    batches = []
    for b in range(4):
        x, _, _ = task.sample(16, seed=200 + b,
                              subclasses=np.array([3 * (b % 4)]))
        batches.append(x)
    out = cas.dynamic_infer_pipelined(batches)
    assert len(out) == len(batches)
    assert eng.stats["hidden_load_seconds"] > 0.0, eng.stats
    eng.shutdown()


def test_unknown_superclass_falls_back_to_generalist(task):
    sup, gen, specs = _members(task)
    # drop specialist 0: batches of superclass 0 must route to generalist
    eng = ContextSwitchEngine(num_slots=2)
    cas = SuperSubCascade(eng, sup, specs[1:], gen, task.sub_of_super)
    x, sub, _ = task.sample(32, seed=5, subclasses=np.array([0, 1, 2]))
    out = cas.dynamic_infer(np.asarray(x))
    assert out["sub"].shape == (32,)
    eng.shutdown()
