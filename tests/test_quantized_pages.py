"""int8 page bank: the shared KV page pool stored as int8 codes with
per-token-per-head f32 scales in parallel leaves.

Quantized serving is tolerance-close, NOT bitwise — so the suite is a
parity ladder: exact bounds where exactness exists (roundtrip error,
kernel vs dequantized-row oracle), bounded logit divergence for greedy
teacher-forcing, and distribution-level statistics for sampling
(softmax total-variation distance + same-noise sampled-token agreement).
What stays bitwise: int8 multi-step == int8 single-step — the fused
loop and the tick loop run the same quantized programs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.layers import dequantize_kv, quantize_kv
from repro.models.model import build_model
from repro.serve.engine import StepEngine


@pytest.fixture(scope="module")
def f32_lm():
    cfg = reduced_arch("tinyllama-1.1b", dtype="float32",
                       param_dtype="float32")
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(0))


def _drain(eng, p):
    while eng.live_slots():
        eng.step(p)


# ---------------------------------------------------------------------------
# quantizer + pool layout
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_bounded():
    """Symmetric absmax int8: per-(token, head) error is at most half a
    quantization step, i.e. absmax/254 (+ rounding slack)."""
    x = jax.random.normal(jax.random.key(1), (3, 4, 20, 32)) * 5.0
    q, scale = quantize_kv(x)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    assert scale.shape == x.shape[:-1]
    err = jnp.abs(x - dequantize_kv(q, scale))
    assert float(jnp.max(err / scale[..., None])) <= 0.5 + 1e-4


def test_quantized_pool_layout(f32_lm):
    cfg, m, p = f32_lm
    pools = m.init_page_pool(8, 16, quantized=True)
    for c in pools.values():
        R, NP, Hkv, page, hd = c.k.shape
        assert (NP, Hkv, page, hd) == (8, cfg.num_kv_heads, 16,
                                       cfg.head_dim)
        assert c.k.dtype == c.v.dtype == jnp.int8
        assert c.ks.shape == c.vs.shape == (R, NP, Hkv, page)
        assert c.ks.dtype == c.vs.dtype == jnp.float32
        # the headline ratio: codes+scales vs a bf16 pool, per token-head
        bf16 = 2 * hd
        assert (hd + 4) / bf16 < 0.6      # hd=32 reduced: 1.78x fewer


# ---------------------------------------------------------------------------
# kernel parity: int8 pool vs the dequantized-row oracle
# ---------------------------------------------------------------------------

def _quantized_pool_from_rows(k, v, page, seed, spare_pages=3):
    """Quantize a contiguous (B, Hkv, S, hd) row cache per token-head and
    scatter codes + scales into a SHUFFLED shared pool (garbage codes in
    unreferenced pages).  Returns the pool leaves, the tables, and the
    dequantized rows — the exact values the kernel must reproduce."""
    B, Hkv, S, hd = k.shape
    P = S // page
    NP = B * P + 1 + spare_pages
    rng = np.random.default_rng(seed)
    table = rng.permutation(np.arange(1, NP))[:B * P].reshape(B, P)
    kq, ksc = quantize_kv(k)
    vq, vsc = quantize_kv(v)
    kp = rng.integers(-127, 128, (NP, Hkv, page, hd)).astype(np.int8)
    vp = rng.integers(-127, 128, (NP, Hkv, page, hd)).astype(np.int8)
    ks = rng.random((NP, Hkv, page)).astype(np.float32)
    vs = rng.random((NP, Hkv, page)).astype(np.float32)
    for b in range(B):
        for j in range(P):
            sl = slice(j * page, (j + 1) * page)
            kp[table[b, j]] = np.asarray(kq[b, :, sl])
            vp[table[b, j]] = np.asarray(vq[b, :, sl])
            ks[table[b, j]] = np.asarray(ksc[b, :, sl])
            vs[table[b, j]] = np.asarray(vsc[b, :, sl])
    deq = (dequantize_kv(kq, ksc), dequantize_kv(vq, vsc))
    return (jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(ks),
            jnp.asarray(vs), jnp.asarray(table, jnp.int32), deq)


@pytest.mark.parametrize("B,H,Hkv,S,hd,page,pos", [
    (2, 4, 2, 64, 32, 16, (30, 63)),
    (1, 4, 4, 128, 32, 32, 0),             # first token
])
def test_int8_paged_decode_matches_dequant_oracle(B, H, Hkv, S, hd, page,
                                                  pos):
    from repro.kernels.decode_attention.ref import decode_reference
    from repro.kernels.paged_attention.ops import (
        paged_decode_attention, paged_decode_reference)
    ks = jax.random.split(jax.random.key(S + page), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    kp, vp, kscale, vscale, table, (kd, vd) = _quantized_pool_from_rows(
        k, v, page, seed=S)
    pos = jnp.asarray(pos, jnp.int32)
    ref = decode_reference(q, kd, vd, pos, ring=False)
    pref = paged_decode_reference(q, kp, vp, table, pos,
                                  k_scale=kscale, v_scale=vscale)
    np.testing.assert_allclose(np.asarray(pref), np.asarray(ref),
                               atol=1e-6)
    out = paged_decode_attention(q, kp, vp, table, pos,
                                 k_scale=kscale, v_scale=vscale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-3)


@pytest.mark.parametrize("B,H,Hkv,S,hd,page,K,pos", [
    (2, 4, 2, 64, 32, 16, 4, (40, 3)),
    (1, 4, 2, 64, 32, 32, 3, 0),
])
def test_int8_paged_verify_matches_dequant_oracle(B, H, Hkv, S, hd, page,
                                                  K, pos):
    """Mixed precision by design: int8 pool history, full-precision
    in-flight verify block."""
    from repro.kernels.paged_attention.ops import (
        paged_verify_attention, paged_verify_reference)
    from repro.kernels.verify_attention.ref import verify_reference
    ks = jax.random.split(jax.random.key(S + K), 5)
    q = jax.random.normal(ks[0], (B, K, H, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    bk = jax.random.normal(ks[3], (B, K, Hkv, hd))
    bv = jax.random.normal(ks[4], (B, K, Hkv, hd))
    kp, vp, kscale, vscale, table, (kd, vd) = _quantized_pool_from_rows(
        k, v, page, seed=S + 1)
    pos = jnp.asarray(pos, jnp.int32)
    ref = verify_reference(q, kd, vd, bk, bv, pos, ring=False)
    pref = paged_verify_reference(q, kp, vp, bk, bv, table, pos,
                                  k_scale=kscale, v_scale=vscale)
    np.testing.assert_allclose(np.asarray(pref), np.asarray(ref),
                               atol=1e-6)
    out = paged_verify_attention(q, kp, vp, bk, bv, table, pos,
                                 k_scale=kscale, v_scale=vscale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-3)


# ---------------------------------------------------------------------------
# model-level divergence: int8 pool vs f32 pool, teacher-forced
# ---------------------------------------------------------------------------

def test_int8_logit_divergence_bounded(f32_lm):
    """Admit the same prompt into an f32 page pool and an int8 page pool,
    teacher-force the f32 greedy continuation through BOTH, and bound the
    damage per step: small worst-case logit error relative to the logit
    spread, small softmax total-variation distance at serving
    temperature, and high same-noise sampled-token agreement (the
    statistical sampling test: identical gumbel noise, the two logit
    sets must pick the same token nearly always)."""
    cfg, m, p = f32_lm
    page, P, steps, temp = 16, 4, 8, 0.8
    L = 12
    toks = jnp.asarray(tokens_for(cfg, 2, L, seed=3))
    B = toks.shape[0]
    max_len = P * page
    logits, rows = m.prefill(p, toks, max_len)
    tables = jnp.arange(1, 1 + B * P, dtype=jnp.int32).reshape(B, P)

    pools = {}
    for mode in ("f32", "int8"):
        pool = m.init_page_pool(1 + B * P + 2, page,
                                quantized=mode == "int8")
        pools[mode] = m.insert_cache_pages(pool, rows, tables)

    tok = jnp.argmax(logits[:, -1], -1)
    pos = jnp.full((B,), L, jnp.int32)
    worst_rel, worst_tv, worst_agree, greedy_same = 0.0, 0.0, 1.0, 0
    for i in range(steps):
        lf, pools["f32"] = m.decode_step_pages(
            p, pools["f32"], tok[:, None], pos, tables)
        lq, pools["int8"] = m.decode_step_pages(
            p, pools["int8"], tok[:, None], pos, tables)
        lf, lq = lf[:, -1], lq[:, -1]
        spread = jnp.max(lf, -1) - jnp.min(lf, -1)
        rel = jnp.max(jnp.abs(lf - lq), -1) / spread
        tv = 0.5 * jnp.sum(jnp.abs(jax.nn.softmax(lf / temp)
                                   - jax.nn.softmax(lq / temp)), -1)
        g = jax.random.gumbel(jax.random.key(i), (64,) + lf.shape)
        agree = jnp.mean(jnp.argmax(lf / temp + g, -1)
                         == jnp.argmax(lq / temp + g, -1))
        worst_rel = max(worst_rel, float(jnp.max(rel)))
        worst_tv = max(worst_tv, float(jnp.max(tv)))
        worst_agree = min(worst_agree, float(agree))
        greedy_same += int(jnp.all(jnp.argmax(lf, -1)
                                   == jnp.argmax(lq, -1)))
        tok = jnp.argmax(lf, -1)           # teacher-force the f32 stream
        pos = pos + 1
    # Random-init weights are the worst case for quantization (no learned
    # redundancy); measured worst rel ~0.11, tv ~0.023, agree ~0.98.
    assert worst_rel < 0.2, worst_rel      # <20% of the logit spread
    assert worst_tv < 0.05, worst_tv
    assert worst_agree > 0.9, worst_agree
    assert greedy_same >= steps - 2        # greedy picks survive quant


# ---------------------------------------------------------------------------
# engine: int8 multi-step is bitwise int8 single-step; no page leaks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_int8_multistep_bitwise_matches_int8_single(f32_lm, temperature):
    cfg, m, p = f32_lm

    def run(T):
        eng = StepEngine(m, batch_size=3, max_len=64,
                         temperature=temperature, seed=5, paged=True,
                         page_size=16, multi_step=T, quantize_kv="int8")
        seeds = [7, 9] if temperature > 0 else [None, None]
        gens = eng.admit(p, np.asarray(tokens_for(cfg, 1, 8, seed=1)),
                         max_new=6, seeds=seeds[:1])
        gens += eng.admit(p, np.asarray(tokens_for(cfg, 1, 20, seed=2)),
                          max_new=9, seeds=seeds[1:])
        _drain(eng, p)
        assert eng.free_pages() == eng._pages.allocatable   # no leaks
        return [g.tokens for g in gens]

    assert run(4) == run(1)


def test_quantize_guards(f32_lm):
    cfg, m, p = f32_lm
    with pytest.raises(ValueError, match="paged"):
        StepEngine(m, batch_size=2, max_len=64, quantize_kv="int8")
    with pytest.raises(ValueError, match="quantize_kv"):
        StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=16,
                   quantize_kv="int4")
