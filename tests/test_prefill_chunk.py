"""Chunked prefill on the step engine: chunk exactness against one-shot
admission (cache rows + token streams), the disturb-free invariant for
in-flight rows, the compile-count guard, the shared slot-pool base's
admission validation, and the stateful-``_max_len`` regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.engine import StepEngine
from repro.serve.speculative import SpecEngine


@pytest.fixture(scope="module")
def f32_lm():
    """f32 end to end: chunked admission recomputes the same values as
    one-shot prefill through differently-shaped programs, so the identity
    tests need f32's headroom (same policy as the speculative suite)."""
    cfg = reduced_arch("tinyllama-1.1b", dtype="float32",
                       param_dtype="float32")
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(0))


def _drain(eng, p):
    while eng.live_slots():
        eng.step(p)


def _prefill_only(eng, p):
    """Run chunk ticks until admission completes (no decode interleaved:
    the pool has no live rows until the final chunk)."""
    while eng.pending_slots():
        eng.prefill_tick(p)


# ---------------------------------------------------------------------------
# chunk exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("C", [4, 5, 8, 32])
def test_chunked_rows_match_one_shot_prefill(f32_lm, C):
    """Chunked admission == one-shot ``prefill`` leaf-for-leaf on the
    inserted cache rows, for an unaligned chunk (5), an exact-multiple
    chunk (4, 8 over S=16), and a chunk wider than the prompt (32).
    Includes the zero tail past the prompt: pad writes are masked and a
    recycled slot's stale row is zeroed at chunk 0."""
    cfg, m, p = f32_lm
    S, max_len = 16, 48
    prompt = np.asarray(tokens_for(cfg, batch=1, seq=S, seed=3))

    _, rows = m.prefill(p, jnp.asarray(prompt), max_len)
    ref = jax.tree.map(lambda r: np.asarray(r[:, 0]), rows)

    eng = StepEngine(m, batch_size=2, max_len=max_len, prefill_chunk=C)
    # dirty BOTH slots first so chunk 0 must clean its recycled row
    eng.admit(p, np.asarray(tokens_for(cfg, 2, 20, seed=9)), max_new=2)
    _prefill_only(eng, p)
    _drain(eng, p)
    g = eng.admit(p, prompt, max_new=4)[0]
    assert g.tokens == []                  # reserved, not yet sampled
    _prefill_only(eng, p)
    assert len(g.tokens) == 1                        # first token sampled
    got = jax.tree.map(lambda c: np.asarray(c[:, g.slot]), eng.state.caches)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(a, b, atol=2e-4)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_chunked_streams_token_identical(f32_lm, temperature):
    """Full generated streams are token-identical between one-shot and
    chunked admission across chunk sizes — greedy, and seeded temperature
    (a seeded row's draws depend only on (key, position), so the chunk
    schedule cannot move them)."""
    cfg, m, p = f32_lm
    S = 16
    prompt = np.asarray(tokens_for(cfg, batch=1, seq=S, seed=4))
    seeds = [7] if temperature > 0 else None

    ref_eng = StepEngine(m, batch_size=2, max_len=48,
                         temperature=temperature)
    gr = ref_eng.admit(p, prompt, max_new=6, seeds=seeds)[0]
    _drain(ref_eng, p)

    for C in (5, 8, 16, 32):       # unaligned, multiple, exact, S < C
        eng = StepEngine(m, batch_size=2, max_len=48,
                         temperature=temperature, prefill_chunk=C)
        g = eng.admit(p, prompt, max_new=6, seeds=seeds)[0]
        _drain(eng, p)
        assert g.tokens == gr.tokens, f"chunk={C}"
        assert eng.free_slots() == 2


def test_chunked_admission_never_disturbs_inflight_rows(f32_lm):
    """The dual-port disturb-free invariant: a long prompt streaming in
    chunk-by-chunk must not change a live row's tokens, and the live row
    keeps decoding every tick (admission latency bounded by one chunk,
    not by the whole prompt)."""
    cfg, m, p = f32_lm
    pa = np.asarray(tokens_for(cfg, 1, 12, seed=3))
    pb = np.asarray(tokens_for(cfg, 1, 30, seed=5))

    solo = StepEngine(m, batch_size=2, max_len=64)
    ga = solo.admit(p, pa, max_new=10)[0]
    _drain(solo, p)
    solo2 = StepEngine(m, batch_size=2, max_len=64)
    gb = solo2.admit(p, pb, max_new=5)[0]
    _drain(solo2, p)

    eng = StepEngine(m, batch_size=2, max_len=64, prefill_chunk=4)
    a = eng.admit(p, pa, max_new=10)[0]
    _prefill_only(eng, p)
    len_before = len(a.tokens)
    b = eng.admit(p, pb, max_new=5)[0]     # 30 tokens = 8 chunks
    eng.step(p)                            # one tick: one chunk + decode
    assert len(a.tokens) == len_before + 1  # live row was not stalled
    _drain(eng, p)
    assert a.tokens == list(ga.tokens)
    assert b.tokens == list(gb.tokens)


def test_chunk_compile_count_guard(f32_lm):
    """Admissions at N distinct prompt lengths compile at most TWO chunk
    programs (streaming + final) — the per-length ``_admit_<S>`` compile
    is gone.  Probed via the jitted functions' lowering caches."""
    cfg, m, p = f32_lm
    eng = StepEngine(m, batch_size=2, max_len=64, prefill_chunk=8)
    for S in (3, 8, 11, 17, 24):           # < C, == C, and 3 unaligned
        g = eng.admit(p, np.asarray(tokens_for(cfg, 1, S, seed=S)),
                      max_new=2)[0]
        _drain(eng, p)
        assert g.done
    n = eng._chunk_fn._cache_size() + eng._chunk_final_fn._cache_size()
    assert n <= 2, f"{n} chunk programs compiled for 5 prompt lengths"
    assert eng._admit_fn._cache_size() == 0   # one-shot path never used


def test_chunked_mode_rejects_unsupported_models():
    """Chunked admission is the restricted layer (LM.prefill_chunk stays
    general): recurrent mixers and ring caches must be rejected."""
    hybrid = build_model(reduced_arch("jamba-v0.1-52b"))
    with pytest.raises(ValueError, match="all-attention"):
        StepEngine(hybrid, batch_size=2, max_len=32, prefill_chunk=4)
    windowed = build_model(reduced_arch("tinyllama-1.1b",
                                        sliding_window=16))
    with pytest.raises(ValueError, match="ring"):
        StepEngine(windowed, batch_size=2, max_len=32, prefill_chunk=4)


# ---------------------------------------------------------------------------
# scheduler integration
# ---------------------------------------------------------------------------

def test_continuous_scheduler_with_chunked_prefill():
    """End to end through ContinuousScheduler: chunked admission produces
    the same greedy outputs as the run-to-completion reference while
    mixed-length prompts stream in."""
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    names = ["supersub-super", "supersub-sub"]
    # f32: chunked and one-shot prefill recompute the same values through
    # differently-shaped programs; bf16 can flip a near-tie argmax
    server, cfgs = build_server(names, 2, 64, load_delay_s=0.01,
                                arch_overrides={"dtype": "float32",
                                                "param_dtype": "float32"})
    rng = np.random.default_rng(0)
    reqs = [(names[r % 2],
             rng.integers(0, cfgs[names[r % 2]].vocab_size,
                          (2, [8, 40, 16][r % 3])))
            for r in range(6)]
    with ContinuousScheduler(server, batch_size=2,
                             prefill_chunk=8) as sched:
        futs = [sched.submit(n, t, steps=4) for n, t in reqs]
        outs = [f.result(timeout=300) for f in futs]
    assert all(o.shape == (2, 4) for o in outs)
    for (name, toks), out in zip(reqs, outs):
        ref = server.serve_batch(name, toks, steps=4)
        np.testing.assert_array_equal(out, ref)
    server.shutdown()


# ---------------------------------------------------------------------------
# shared pool base: admission validation + FIFO recycling
# ---------------------------------------------------------------------------

def test_admit_validates_seeds_and_metas(f32_lm):
    cfg, m, p = f32_lm
    eng = StepEngine(m, batch_size=4, max_len=48)
    toks = np.asarray(tokens_for(cfg, 2, 8))
    with pytest.raises(ValueError, match="seeds"):
        eng.admit(p, toks, max_new=2, seeds=[1, 2, 3])   # over-long
    with pytest.raises(ValueError, match="metas"):
        eng.admit(p, toks, max_new=2, metas=["only-one"])  # short
    assert eng.free_slots() == 4           # nothing leaked

    spec = SpecEngine(m, m, batch_size=4, max_len=48, k=2)
    with pytest.raises(ValueError, match="metas"):
        spec.admit((p, p), toks, max_new=2, metas=[None])
    assert spec.free_slots() == 4


def test_failed_admit_preserves_fifo_slot_order(f32_lm):
    """A failed admission restores its slots to the FRONT of the
    free-list in their original order: the retry is indistinguishable
    from the failed call (slot order is load-bearing for the seeded
    admission draw, which indexes a shared (B, V) field by slot)."""
    cfg, m, p = f32_lm
    eng = StepEngine(m, batch_size=4, max_len=48)
    order_before = list(eng._free)
    with pytest.raises(BaseException):
        eng.admit(None, np.asarray(tokens_for(cfg, 2, 8)), max_new=2)
    assert list(eng._free) == order_before


# ---------------------------------------------------------------------------
# stateful-_max_len regression
# ---------------------------------------------------------------------------

def test_shared_lm_across_pools_with_different_max_len(f32_lm):
    """One LM shared by two engines with different ``max_len`` (the
    draft/target and generate()-vs-step-engine sharing patterns): cache
    sizes must come from each engine's own argument.  The old code
    stashed ``self._max_len`` on the model between ``prefill`` and the
    block that read it at trace time, so an interleaved trace from the
    other pool could silently build wrong-size cache rows."""
    cfg, m, p = f32_lm
    prompt = np.asarray(tokens_for(cfg, 1, 12, seed=3))

    small = StepEngine(m, batch_size=2, max_len=32)
    big = StepEngine(m, batch_size=2, max_len=96)
    gs = small.admit(p, prompt, max_new=4)[0]
    gb = big.admit(p, prompt, max_new=4)[0]       # interleaved admits
    _drain(small, p)
    _drain(big, p)
    assert gs.tokens == gb.tokens                 # greedy: size-invariant
    assert {l.shape[3] for l in jax.tree.leaves(small.state.caches)} == {32}
    assert {l.shape[3] for l in jax.tree.leaves(big.state.caches)} == {96}
    # the regression guard itself: prefill must not leave trace-time
    # state on the shared model object
    assert not hasattr(m, "_max_len")
