"""Training substrate: loss descent, grad-accumulation equivalence,
chunked-loss equivalence, optimizer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import reduced_arch, tokens_for
from repro.configs.base import OptimizerConfig, ParallelConfig, RunConfig
from repro.models.model import build_model
from repro.train.data import SyntheticTokens
from repro.train.trainer import (
    Trainer, chunked_lm_loss, init_state, make_train_step,
    softmax_xent)


def _run_cfg(eps=1e-8, **kw):
    return RunConfig(optimizer=OptimizerConfig(lr=1e-3, total_steps=100,
                                               warmup_steps=5, eps=eps),
                     parallel=ParallelConfig(**kw))


def test_loss_decreases(tmp_path):
    cfg = reduced_arch("tinyllama-1.1b")
    m = build_model(cfg)
    rc = _run_cfg()
    rc.checkpoint_dir = str(tmp_path)
    rc.log_every = 5
    data = SyntheticTokens(cfg.vocab_size, 64, 8, seed=0)
    tr = Trainer(m, rc, data)
    state = tr.init_or_restore(jax.random.key(0))
    tr.train(state, 40)
    losses = [m_["loss"] for m_ in tr.metrics_log]
    assert losses[-1] < losses[0] - 0.1, losses


def test_grad_accumulation_equivalence():
    """A=1 vs A=4 must produce the same update on the same global batch."""
    cfg = reduced_arch("tinyllama-1.1b")
    m = build_model(cfg)
    batch = {"tokens": tokens_for(cfg, batch=8, seq=32)}
    # eps=1: at step 1 adam's m/(sqrt(v)+eps) ~ sign(g) for tiny eps and
    # amplifies f32 summation-order noise into +-lr flips; a smooth update
    # makes the accumulation equivalence testable at tight tolerance.
    s1 = init_state(m, jax.random.key(0), _run_cfg(microbatches=1))
    s4 = init_state(m, jax.random.key(0), _run_cfg(microbatches=4))
    step1 = jax.jit(make_train_step(m, _run_cfg(eps=1.0, microbatches=1)))
    step4 = jax.jit(make_train_step(m, _run_cfg(eps=1.0, microbatches=4)))
    out1, m1 = step1(s1, batch)
    out4, m4 = step4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-3)
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-3)


def test_remat_matches_no_remat():
    cfg = reduced_arch("tinyllama-1.1b")
    m = build_model(cfg)
    batch = {"tokens": tokens_for(cfg, batch=4, seq=32)}
    sa = init_state(m, jax.random.key(0), _run_cfg())
    sb = init_state(m, jax.random.key(0), _run_cfg(remat="full"))
    stepa = jax.jit(make_train_step(m, _run_cfg()))
    stepb = jax.jit(make_train_step(m, _run_cfg(remat="full")))
    _, ma = stepa(sa, batch)
    _, mb = stepb(sb, batch)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(ma["grad_norm"]),
                               float(mb["grad_norm"]), rtol=1e-4)


def test_chunked_loss_equals_full():
    B, S, D, V = 2, 64, 16, 128
    ks = jax.random.split(jax.random.key(0), 3)
    hidden = jax.random.normal(ks[0], (B, S, D))
    head = jax.random.normal(ks[1], (D, V))
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    mask = jnp.ones((B, S))
    full = softmax_xent((hidden @ head), labels, mask)
    chunked = chunked_lm_loss(hidden, head, labels, mask, chunk=16)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)
    # gradients agree too (the chunked path recomputes on backward)
    gf = jax.grad(lambda h: softmax_xent(h @ head, labels, mask))(hidden)
    gc = jax.grad(lambda h: chunked_lm_loss(h, head, labels, mask,
                                            chunk=16))(hidden)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gc), atol=1e-5)


def test_grad_clipping_and_schedule():
    from repro.train.optimizer import adamw_init, adamw_update, make_schedule
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=10, total_steps=100,
                           grad_clip=1.0)
    sched = make_schedule(ocfg)
    assert float(sched(0)) < float(sched(10))          # warmup ramps
    assert float(sched(99)) < float(sched(10))         # cosine decays
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, om = adamw_update(huge, opt, params, ocfg, sched)
    assert float(om["grad_norm"]) > 1.0                # raw norm reported


def test_trainer_resume_exact(tmp_path):
    """Kill/restart: resumed run must be bitwise identical to uninterrupted."""
    cfg = reduced_arch("tinyllama-1.1b")

    def fresh():
        m = build_model(cfg)
        rc = _run_cfg()
        rc.checkpoint_dir = str(tmp_path / "a")
        rc.checkpoint_every = 5
        data = SyntheticTokens(cfg.vocab_size, 32, 4, seed=0)
        return Trainer(m, rc, data)

    tr = fresh()
    state = tr.init_or_restore(jax.random.key(0))
    final_uninterrupted = tr.train(state, 10)

    # separate dir: run 5, "crash", resume 5
    m2 = build_model(cfg)
    rc2 = _run_cfg()
    rc2.checkpoint_dir = str(tmp_path / "b")
    rc2.checkpoint_every = 5
    data2 = SyntheticTokens(cfg.vocab_size, 32, 4, seed=0)
    t1 = Trainer(m2, rc2, data2)
    s = t1.init_or_restore(jax.random.key(0))
    t1.train(s, 5)
    t2 = Trainer(m2, rc2, data2)              # new process analogue
    s2 = t2.init_or_restore(jax.random.key(0))
    assert t2.start_step == 5
    final_resumed = t2.train(s2, 5)

    for a, b in zip(jax.tree.leaves(final_uninterrupted["params"]),
                    jax.tree.leaves(final_resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_prefetch():
    from repro.train.data import PrefetchLoader

    class SlowSource:
        def __init__(self):
            self.calls = 0

        def batch_at(self, step):
            import time
            self.calls += 1
            if self.calls == 3:
                time.sleep(0.6)               # one straggling batch
            return {"tokens": jnp.full((2, 4), step)}

    loader = PrefetchLoader(SlowSource(), depth=1, deadline_s=0.2)
    got = [loader.batch_at(i) for i in range(5)]
    assert loader.stats["stragglers"] >= 1
    assert len(got) == 5
    loader.close()
