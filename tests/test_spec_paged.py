"""Paged SpecEngine vs the retired dense-row engine, the tree-verify
path, adaptive K, and cross-engine bank sharing.

The dense-row speculative engine was deleted once the paged engine
reproduced its streams bitwise; ``_dense_oracle`` below reimplements its
exact device schedule (same key folds, same admission draw, same
accept/commit arithmetic, dense row caches) so the equivalence stays a
*tested* property, not a remembered one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import reduced_arch, tokens_for

from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.serve.speculative import (SpecEngine, speculative_accept,
                                     tree_speculative_accept)
from repro.serve.switching import ServedModel, SwitchableServer


def _f32_model(name="tinyllama-1.1b", pseed=0, **extra):
    cfg = reduced_arch(name, dtype="float32", param_dtype="float32",
                       **extra)
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(pseed))


def _perturb(params, scale=0.02, seed=9):
    """Slightly noised copy: argmax usually agrees with the original,
    sometimes lands on its runner-up — exercises partial accepts and the
    tree's alternative-sibling path."""
    keys = iter(jax.random.split(jax.random.key(seed), 4096))
    return jax.tree.map(
        lambda x: x + scale * jax.random.normal(next(keys), x.shape,
                                                x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)


def _dense_oracle(model, dp, tp, tokens, steps, k, temperature, max_len,
                  seed=0):
    """The retired dense-row SpecEngine, run as a plain host loop: one
    one-shot full-batch admission at t=0, then flat K-rounds to
    completion.  Key schedule, admission draw, roll gumbels, verify key,
    and commit clamping are verbatim from the deleted engine."""
    tokens = np.asarray(tokens)
    B, S = tokens.shape
    V = model.cfg.vocab_size
    T, K = temperature, k
    key = jax.random.PRNGKey(seed)
    t = jnp.zeros((), jnp.int32)

    logits, rows = model.prefill(tp, jnp.asarray(tokens, jnp.int32),
                                 max_len)
    last = logits[:, -1]
    if T > 0.0:
        salted = jax.random.fold_in(key, (1 << 30) ^ t)
        akey = jnp.where(t == 0, key, salted)
        g = jax.random.gumbel(akey, (B, V), jnp.float32)
        first = jnp.argmax(last / T + g[jnp.arange(B)], axis=-1)
    else:
        first = jnp.argmax(last, axis=-1)
    first = first.astype(jnp.int32)
    t_caches = model.insert_cache_rows(model.init_cache(B, max_len), rows,
                                       jnp.arange(B))
    _, drows = model.prefill(dp, jnp.asarray(tokens, jnp.int32), max_len)
    d_caches = model.insert_cache_rows(model.init_cache(B, max_len),
                                       drows, jnp.arange(B))
    tok = first[:, None]
    pos = jnp.full((B,), S, jnp.int32)
    out = [[int(first[i])] for i in range(B)]
    produced = np.ones(B, np.int64)
    while (produced < steps).any():
        live = jnp.asarray(produced < steps)
        remaining = jnp.asarray(np.maximum(steps - produced, 0), jnp.int32)
        base = jax.random.fold_in(key, t)
        caches, tk = d_caches, tok
        props, dlog = [], []
        for i in range(K + 1):
            lg, caches = model.decode_step(dp, caches, tk, pos + i)
            lastd = lg[:, -1]
            if T > 0.0:
                g = jax.random.gumbel(jax.random.fold_in(base, i),
                                      (B, V), jnp.float32)
                nxt = jnp.argmax(lastd / T + g, axis=-1)
            else:
                nxt = jnp.argmax(lastd, axis=-1)
            nxt = nxt.astype(jnp.int32)
            if i < K:
                props.append(nxt)
                dlog.append(lastd)
            tk = nxt[:, None]
        d_caches = caches
        props = jnp.stack(props, 1)
        dlog = jnp.stack(dlog, 1)
        block = jnp.concatenate([tok, props], axis=1)
        lg, t_caches = model.verify_step(tp, t_caches, block, pos)
        vkey = jax.random.fold_in(jax.random.fold_in(key, t), 1 << 20)
        toks, n = speculative_accept(vkey, props, dlog, lg, T)
        m = jnp.where(live, jnp.minimum(n + 1, remaining), 0)
        tok_new = jnp.take_along_axis(toks,
                                      jnp.clip(m - 1, 0, K)[:, None],
                                      axis=1)
        tok = jnp.where(m[:, None] > 0, tok_new, tok)
        pos = jnp.minimum(pos + m, max_len - 1)
        key = jax.random.fold_in(key, t)
        t = t + 1
        mn, tn = np.asarray(m), np.asarray(toks)
        for b in range(B):
            out[b].extend(int(x) for x in tn[b, :int(mn[b])])
            produced[b] += int(mn[b])
    return np.stack([np.asarray(o[:steps], np.int32) for o in out])


# --------------------------------------------------------------- bitwise
@pytest.mark.parametrize("temperature,chunk", [(0.0, None), (0.0, 3),
                                               (1.3, None)],
                         ids=["greedy", "greedy-chunked", "temp"])
def test_paged_matches_dense_row_engine(temperature, chunk):
    """The tentpole guarantee: the paged SpecEngine commits bitwise the
    stream the dense-row engine did — same pool key schedule, same
    accepts — for greedy (one-shot AND chunked admission) and for
    pool-temperature sampling (one-shot; chunking legitimately shifts
    which round an admission draw lands on, exactly as in StepEngine)."""
    max_len, steps, k = 64, 12, 3
    cfg, m, tp = _f32_model()
    dp = _perturb(tp)
    prompts = np.asarray(tokens_for(cfg, 3, 7, seed=5))
    ref = _dense_oracle(m, dp, tp, prompts, steps, k, temperature,
                        max_len)
    eng = SpecEngine(m, m, batch_size=3, max_len=max_len, k=k,
                     temperature=temperature, prefill_chunk=chunk)
    gens = eng.admit((dp, tp), prompts, max_new=steps)
    eng.drain((dp, tp))
    out = np.stack([np.asarray(g.tokens, np.int32) for g in gens])
    np.testing.assert_array_equal(out, ref)


def test_tree_greedy_matches_generate():
    """W>1 greedy must still equal plain target greedy: the chain's
    committed token is always the target argmax at its node, alternative
    siblings only shortcut rounds, and both caches are repaired before
    the next round reads them (a repair bug shows up as divergence a few
    rounds after the first alternative accept)."""
    max_len, steps = 64, 16
    cfg, m, tp = _f32_model()
    dp = _perturb(tp)
    prompts = np.asarray(tokens_for(cfg, 3, 10, seed=5))
    ref = ServingEngine(m, tp, max_len).generate(prompts, steps)
    eng = SpecEngine(m, m, batch_size=3, max_len=max_len, k=4,
                     tree_width=2)
    gens = eng.admit((dp, tp), prompts, max_new=steps)
    eng.drain((dp, tp))
    out = np.stack([np.asarray(g.tokens) for g in gens])
    np.testing.assert_array_equal(out, np.asarray(ref))


def test_adaptive_k_commits_only_target_tokens():
    """Moving K mid-stream must never commit a token a fixed-K engine
    wouldn't: greedy committed streams are target-argmax streams for
    EVERY K, so resizing between ticks cannot change the output."""
    max_len, steps = 64, 16
    cfg, m, tp = _f32_model()
    dp = _perturb(tp)
    prompts = np.asarray(tokens_for(cfg, 2, 8, seed=3))
    ref = ServingEngine(m, tp, max_len).generate(prompts, steps)
    eng = SpecEngine(m, m, batch_size=2, max_len=max_len, k=4)
    gens = eng.admit((dp, tp), prompts, max_new=steps)
    ks = [1, 2, 4, 3, 1, 2]
    i = 0
    while any(not g.done for g in gens):
        eng.set_k(ks[i % len(ks)])
        i += 1
        eng.step((dp, tp))
    assert eng.k_max == 4 and eng.k == ks[(i - 1) % len(ks)]
    out = np.stack([np.asarray(g.tokens) for g in gens])
    np.testing.assert_array_equal(out, np.asarray(ref))
    eng.set_k(0)                 # out-of-range requests clamp, not raise
    assert eng.k == 1
    eng.set_k(99)
    assert eng.k == eng.k_max


def test_int8_columns_aligned_draft():
    """int8 page banks on BOTH columns: a draft that IS the target reads
    back the same quantized history, so nearly every chain accepts in
    full (bitwise identity is not promised across different matmul
    shapes, acceptance is the observable)."""
    cfg, m, tp = _f32_model()
    prompts = np.asarray(tokens_for(cfg, 2, 8, seed=4))
    eng = SpecEngine(m, m, batch_size=2, max_len=64, k=4,
                     quantize_kv="int8", page_size=16)
    gens = eng.admit((tp, tp), prompts, max_new=16)
    eng.drain((tp, tp))
    assert all(len(g.tokens) == 16 for g in gens)
    assert eng.accepted_per_round > 4.0


# ------------------------------------------------------------- tree math
def test_tree_accept_first_token_target_distributed():
    """Exact tree speculative sampling: whatever the draft proposes (W
    iid draws per depth here), the depth-1 committed token is distributed
    exactly as target sampling at the root node."""
    B, K, W, V, T = 40000, 2, 2, 16, 1.0
    key = jax.random.key(0)
    kq, kp, kc, kv = jax.random.split(key, 4)
    q_logits = jax.random.normal(kq, (K, V)) * 1.5
    t_logits = jax.random.normal(kp, (1 + K * W, V)) * 1.5
    # iid proposals from each depth's draft distribution, per row/sibling
    g = jax.random.gumbel(kc, (B, K, W, V))
    cand = jnp.argmax(q_logits[None, :, None, :] / T + g,
                      axis=-1).astype(jnp.int32)
    dlog = jnp.broadcast_to(q_logits[None], (B, K, V))
    tlog = jnp.broadcast_to(t_logits[None], (B, 1 + K * W, V))
    toks, n, alt_depth, alt_tok = tree_speculative_accept(
        kv, cand, dlog, tlog, T)
    emp = np.bincount(np.asarray(toks[:, 0]), minlength=V) / B
    want = np.asarray(jax.nn.softmax(t_logits[0] / T))
    np.testing.assert_allclose(emp, want, atol=0.015)
    assert (np.asarray(n) >= 0).all() and (np.asarray(n) <= K).all()
    assert ((np.asarray(alt_depth) == 0)
            | (np.asarray(alt_depth) <= K)).all()


def test_tree_verify_kernel_matches_ref():
    """The tree-verify kernel on a shuffled page table with per-row
    ancestor bitmasks must match the gather-then-mask oracle."""
    from repro.kernels.paged_attention.ops import paged_verify_attention
    from repro.kernels.paged_attention.ref import paged_verify_reference
    B, K, H, Hkv, hd, page, P = 3, 7, 4, 2, 64, 8, 4
    NP = B * P + 1
    key = jax.random.key(1)
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (B, K, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (NP, Hkv, page, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (NP, Hkv, page, hd), jnp.float32)
    bk = jax.random.normal(ks[3], (B, K, Hkv, hd), jnp.float32)
    bv = jax.random.normal(ks[4], (B, K, Hkv, hd), jnp.float32)
    # shuffled non-contiguous tables (page 0 stays the park page)
    perm = np.random.RandomState(0).permutation(NP - 1) + 1
    table = jnp.asarray(perm[:B * P].reshape(B, P), jnp.int32)
    pos = jnp.asarray([13, 5, 22], jnp.int32)
    # random per-row visibility masks with the self-bit always set
    masks = np.random.RandomState(1).randint(0, 1 << K, size=(B, K))
    masks |= 1 << np.arange(K)[None, :]
    tree = jnp.asarray(masks, jnp.int32)
    out = paged_verify_attention(q, kp, vp, bk, bv, table, pos, tree=tree)
    ref = paged_verify_reference(q, kp, vp, bk, bv, table, pos, tree=tree)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ bank share
def test_shared_bank_prefix_hits_across_engine_kinds():
    """Satellite: one PrefixIndex per bank content.  A prompt served by
    the plain paged engine leaves its pages in the shared bank; the SAME
    prompt admitted to a spec engine of the same context is a prefix hit
    on the target column (and the stream stays the target's greedy)."""
    max_len, ps = 32, 8
    cfg, m, tp = _f32_model()
    dp = _perturb(tp)
    srv = SwitchableServer()
    srv.register(ServedModel(name="tgt", model=m, weights_fn=lambda: tp,
                             max_len=max_len))
    srv.register(ServedModel(name="drf", model=m, weights_fn=lambda: dp,
                             max_len=max_len))
    step = srv.step_engine("tgt", batch_size=2, paged=True, page_size=ps,
                           prefix_cache=True, share_bank=True,
                           num_pages=2 * (max_len // ps) + 6)
    spec = srv.spec_engine("tgt", "drf", batch_size=2, k=3, page_size=ps,
                           prefix_cache=True, share_bank=True)
    assert step._prefix is spec._prefix      # literally one index
    assert step._pages is spec._t_pages      # and one target pool
    prompt = np.asarray(tokens_for(cfg, 1, 12, seed=7))
    ref = np.asarray(ServingEngine(m, tp, max_len).generate(prompt, 8))
    g1 = step.admit(tp, prompt, max_new=8)
    step.drain(tp)
    np.testing.assert_array_equal(
        np.stack([np.asarray(g1[0].tokens)]), ref)
    assert spec.stats["prefix_hits"] == 0
    g2 = spec.admit((dp, tp), prompt, max_new=8)
    spec.drain((dp, tp))
    assert spec.stats["prefix_hits"] == 1
    assert spec.stats["prefix_pages_mapped"] >= 1
    np.testing.assert_array_equal(
        np.stack([np.asarray(g2[0].tokens)]), ref)
    srv.shutdown()
