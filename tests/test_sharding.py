"""Sharding-rule unit behaviour (single device; multi-device semantics are
covered by tests/test_distributed.py subprocesses and the dry-run)."""
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.sharding import (
    DEFAULT_RULES, logical_to_spec)


class FakeMesh:
    """Just enough mesh for logical_to_spec (shape lookup only)."""

    def __init__(self, shape: dict):
        self.shape = shape


def test_non_dividing_axis_dropped():
    mesh = FakeMesh({"data": 16, "model": 16})
    # heads=36 does not divide 16 -> replicated
    spec = logical_to_spec(mesh, ("batch", "heads"), (256, 36))
    assert spec[1] is None
    spec = logical_to_spec(mesh, ("batch", "heads"), (256, 32))
    assert spec[1] == "model"


def test_axis_used_once_per_spec():
    mesh = FakeMesh({"data": 16, "model": 16})
    # both dims map to model; only the first one gets it
    spec = logical_to_spec(mesh, ("heads", "ffn"), (32, 64))
    assert spec == P("model", None)


def test_act_attn_q_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # heads shard -> q-chunk replicated
    s = logical_to_spec(mesh, ("batch", "act_heads", "act_attn_q", None),
                        (256, 32, 1024, 4096))
    assert s == P(("data",), "model", None, None)
    # starcoder2: 36 heads -> fallback to q-chunk sharding
    s = logical_to_spec(mesh, ("batch", "act_heads", "act_attn_q", None),
                        (256, 36, 1024, 4096))
    assert s == P(("data",), None, "model", None)


def test_missing_mesh_axis_ignored():
    mesh = FakeMesh({"data": 4})
    spec = logical_to_spec(mesh, ("batch", "heads"), (8, 32))
    assert spec == P(("data",), None)


def test_pod_axis_composes_with_data():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = logical_to_spec(mesh, ("batch", None), (256, 1))
    assert spec[0] == ("pod", "data")


def test_rules_override():
    r = DEFAULT_RULES.with_(kv_seq="model", kv_heads=None)
    assert r["kv_seq"] == "model"
    assert r["kv_heads"] is None
    assert DEFAULT_RULES["kv_seq"] is None      # original untouched


def test_decode_rules_pick_seq_for_small_kv():
    from repro.launch.specs import decode_rules
    mesh = FakeMesh({"data": 16, "model": 16})
    r = decode_rules(get_arch("qwen3-moe-235b-a22b"), mesh)   # kv=4
    assert r["kv_seq"] == "model" and r["kv_heads"] is None
    r = decode_rules(get_arch("deepseek-7b"), mesh)           # kv=32
    assert r["kv_heads"] == "model" and r["kv_seq"] is None


def test_fit_batch_axes_long_500k():
    from repro.launch.specs import fit_batch_axes
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert fit_batch_axes(mesh, 1) == ()            # B=1: unshardable
    assert fit_batch_axes(mesh, 32) == ("pod", "data")
    assert fit_batch_axes(mesh, 2) == ("pod",)


def test_cell_applicability_matrix():
    from repro.configs import ASSIGNED_ARCHS, SHAPES, cell_is_runnable
    runnable = {(a, s) for a in ASSIGNED_ARCHS for s in SHAPES
                if cell_is_runnable(get_arch(a), SHAPES[s])[0]}
    # exactly the DESIGN.md skip list: 7 pure-attention archs skip long_500k
    assert len(runnable) == 33
    for a in ("xlstm-125m", "jamba-v0.1-52b", "mixtral-8x7b"):
        assert (a, "long_500k") in runnable
    for a in ("tinyllama-1.1b", "deepseek-7b", "pixtral-12b",
              "qwen3-moe-235b-a22b", "codeqwen1.5-7b", "starcoder2-7b",
              "musicgen-medium"):
        assert (a, "long_500k") not in runnable
