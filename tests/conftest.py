"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests see 1 device;
multi-device tests spawn subprocesses with their own flags."""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, get_arch, reduced  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


def reduced_arch(name: str, **kw):
    return reduced(get_arch(name), **kw)


@pytest.fixture(params=ASSIGNED_ARCHS)
def arch_name(request):
    return request.param


def tokens_for(cfg, batch=2, seq=32, seed=1):
    return jax.random.randint(jax.random.key(seed), (batch, seq), 0,
                              cfg.vocab_size)


def patch_for(cfg, batch=2, seed=2):
    if cfg.frontend.kind != "vision_patches":
        return None
    return jax.random.normal(
        jax.random.key(seed),
        (batch, cfg.frontend.num_positions, cfg.frontend.embed_dim),
        jnp.float32)
