"""Page-level prefix sharing: refcounted pages + copy-on-write admission.

Covers the refcounted ``PagePool`` contract (acquire/decref, free only
at refcount 0, FIFO + restore order preserved), the ``PrefixIndex``
radix semantics (whole-page matching, first-writer-wins, LRU-leaf
eviction, namespace separation), the headline bitwise gate — a
prefix-hit admission's token stream is identical to a cold admission's
across {greedy, seeded temperature} x {one-shot, chunked} x {fp16-path
f32, int8} — CoW immutability of shared donor pages, cache eviction
under page pressure, and a randomized admit/diverge/retire fuzz whose
refcount-conservation invariants are checked after every event and
whose whole run replays deterministically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.engine import EngineKey, StepEngine
from repro.serve.pool import PagePool, PrefixIndex


@pytest.fixture(scope="module")
def f32_lm():
    """f32 end to end: the identity tests assert BITWISE equality of
    token streams between a cold prefill and a prefix-hit admission that
    reuses device pages written by an earlier request — which holds
    exactly (same causal math, same positions) only in a dtype where the
    intermediates are the same numbers."""
    cfg = reduced_arch("tinyllama-1.1b", dtype="float32",
                       param_dtype="float32")
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(0))


def _engine(m, prefix_cache, chunk=None, batch=4, max_len=64, page=8,
            temp=0.0, num_pages=None, quantize=None):
    return StepEngine(m, batch_size=batch, max_len=max_len,
                      temperature=temp, prefill_chunk=chunk,
                      paged=True, page_size=page, num_pages=num_pages,
                      quantize_kv=quantize, prefix_cache=prefix_cache)


# ---------------------------------------------------------------------------
# PagePool refcounts
# ---------------------------------------------------------------------------

def test_refcount_lifecycle():
    pool = PagePool(8)
    a = pool.take(3)
    assert [pool.refcount(p) for p in a] == [1, 1, 1]
    pool.acquire(a)                         # second reference (index/table)
    assert [pool.refcount(p) for p in a] == [2, 2, 2]
    pool.release(a)                         # first owner retires...
    assert pool.free_pages() == 4           # ...pages stay allocated
    assert [pool.refcount(p) for p in a] == [1, 1, 1]
    pool.release(a)                         # last reference drops
    assert pool.free_pages() == 7
    assert [pool.refcount(p) for p in a] == [0, 0, 0]


def test_refcount_guards():
    pool = PagePool(4)
    with pytest.raises(ValueError):
        pool.acquire([1])                   # never allocated
    a = pool.take(1)
    pool.release(a)
    with pytest.raises(ValueError):
        pool.release(a)                     # refcount underflow


def test_refcount_restore_front_release_back():
    """Order contract survives refcounts: restore puts pages reaching 0
    at the FRONT in order, release at the BACK; a page another holder
    still references touches neither end."""
    pool = PagePool(8)
    a = pool.take(3)                        # [1, 2, 3]
    pool.acquire([a[1]])                    # page 2 held twice
    pool.restore(a)                         # 1, 3 -> front; 2 stays out
    assert pool.take(2) == [1, 3]
    assert pool.refcount(2) == 1
    pool.release([2])
    assert pool.take(5) == [4, 5, 6, 7, 2]  # 2 recycled last (FIFO back)


# ---------------------------------------------------------------------------
# PrefixIndex
# ---------------------------------------------------------------------------

def test_index_whole_page_matching():
    idx = PrefixIndex(page_size=4)
    toks = list(range(10))                  # 2 full pages + 2 leftover
    assert idx.insert(toks, [5, 6, 7]) == [5, 6]   # partial page ignored
    assert idx.lookup(toks) == [5, 6]
    assert idx.lookup(toks[:8]) == [5, 6]
    assert idx.lookup(toks[:7]) == [5]      # second page incomplete
    assert idx.lookup([9] + toks[1:]) == []
    assert idx.pages() == {5, 6}


def test_index_first_writer_wins():
    idx = PrefixIndex(page_size=4)
    toks = list(range(8))
    assert idx.insert(toks, [1, 2]) == [1, 2]
    assert idx.insert(toks, [3, 4]) == []   # duplicate content: no adoption
    assert idx.lookup(toks) == [1, 2]
    # divergent second page under the same first page
    assert idx.insert(list(range(4)) + [9] * 4, [1, 7]) == [7]
    assert idx.lookup(list(range(4)) + [9] * 4) == [1, 7]


def test_index_lru_leaf_eviction():
    idx = PrefixIndex(page_size=2)
    idx.insert([0, 1, 2, 3], [1, 2])        # chain 1 -> 2
    idx.insert([0, 1, 8, 9], [1, 3])        # chain 1 -> 3
    idx.lookup([0, 1, 2, 3])                # bump leaf 2
    # leaf 3 is LRU; inner page 1 is not a leaf and must survive first
    assert idx.evict_lru(2, lambda p: True) == [3, 2]
    assert idx.evict_lru(5, lambda p: True) == [1]   # now a leaf
    assert idx.pages() == set()


def test_index_eviction_respects_can_evict():
    idx = PrefixIndex(page_size=2)
    idx.insert([0, 1, 2, 3], [1, 2])
    assert idx.evict_lru(2, lambda p: p != 2) == []   # leaf 2 pinned;
    assert idx.pages() == {1, 2}                      # 1 unreachable-safe


def test_index_namespace_separation():
    """fp16 and int8 banks store different bytes for the same tokens:
    their index entries must never cross-match."""
    a = PrefixIndex(page_size=2, namespace="fp16")
    b = PrefixIndex(page_size=2, namespace="int8")
    a.insert([0, 1], [1])
    assert b.lookup([0, 1]) == []
    b.insert([0, 1], [1])
    assert a.lookup([0, 1]) == [1] and b.lookup([0, 1]) == [1]


# ---------------------------------------------------------------------------
# bitwise identity: prefix hit == cold admission
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [None, 16])
@pytest.mark.parametrize("seeded", [False, True])
def test_hit_stream_matches_cold(f32_lm, chunk, seeded):
    """The headline gate: a request admitted through a prefix hit (pages
    mapped read-only, CoW on the boundary, suffix-only prefill) emits a
    token stream bitwise-identical to the same request admitted cold."""
    cfg, m, p = f32_lm
    prompt = tokens_for(cfg, 1, 40, seed=3)          # 5 exact pages
    temp = 0.8 if seeded else 0.0
    seeds = [11] if seeded else None

    cold = _engine(m, False, chunk=chunk, temp=temp)
    cold.admit(p, prompt, max_new=6, seeds=seeds)
    ref = cold.drain(p)[0].tokens

    eng = _engine(m, True, chunk=chunk, temp=temp)
    eng.admit(p, prompt, max_new=6, seeds=seeds)     # donor (cold, indexes)
    eng.drain(p)
    gens = eng.admit(p, prompt, max_new=6, seeds=seeds)
    eng.drain(p)
    assert gens[0].tokens == ref
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_pages_mapped"] == 4     # 5th page is the CoW
    assert eng.stats["cow_copies"] == 1


def test_hit_stream_matches_cold_partial_divergence(f32_lm):
    """Divergence mid-prompt: only the shared whole pages map, the
    suffix prefills from the first divergent token, no CoW needed."""
    cfg, m, p = f32_lm
    base = np.asarray(tokens_for(cfg, 1, 37, seed=4))
    var = base.copy()
    var[0, 20:] = (var[0, 20:] + 1) % cfg.vocab_size

    cold = _engine(m, False, chunk=16)
    cold.admit(p, var, max_new=6)
    ref = cold.drain(p)[0].tokens

    eng = _engine(m, True, chunk=16)
    eng.admit(p, base, max_new=6)
    eng.drain(p)
    gens = eng.admit(p, var, max_new=6)
    eng.drain(p)
    assert gens[0].tokens == ref
    assert eng.stats["prefix_pages_mapped"] == 2     # pages 0-1 shared
    assert eng.stats["cow_copies"] == 0


def test_hit_stream_matches_cold_int8(f32_lm):
    """int8 bank: quantized page codes are a deterministic function of
    the source k/v, so hit == cold holds bitwise *within* the int8
    namespace too."""
    cfg, m, p = f32_lm
    prompt = tokens_for(cfg, 1, 40, seed=5)

    cold = _engine(m, False, chunk=16, quantize="int8")
    cold.admit(p, prompt, max_new=6)
    ref = cold.drain(p)[0].tokens

    eng = _engine(m, True, chunk=16, quantize="int8")
    eng.admit(p, prompt, max_new=6)
    eng.drain(p)
    gens = eng.admit(p, prompt, max_new=6)
    eng.drain(p)
    assert gens[0].tokens == ref
    assert eng.stats["prefix_hits"] == 1


# ---------------------------------------------------------------------------
# CoW: shared pages are never mutated
# ---------------------------------------------------------------------------

def test_cow_leaves_donor_pages_untouched(f32_lm):
    """An exact-multiple prompt fully covered by the cache forces the
    boundary page to be CoW-copied: the hit's last-token recompute (and
    its decode writes) land in the copy, and every indexed donor page is
    bit-identical before and after the hit's whole generation."""
    cfg, m, p = f32_lm
    prompt = tokens_for(cfg, 1, 40, seed=6)
    eng = _engine(m, True)
    eng.admit(p, prompt, max_new=6)
    eng.drain(p)
    donors = sorted(eng._prefix.pages())
    assert len(donors) == 5
    before = jax.tree.map(np.asarray, eng.state.caches)

    eng.admit(p, prompt, max_new=6)
    eng.drain(p)
    assert eng.stats["cow_copies"] == 1
    after = jax.tree.map(np.asarray, eng.state.caches)
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        # leaf shape (blocks, NP, ...): axis 1 is the page axis
        np.testing.assert_array_equal(b[:, donors], a[:, donors])


# ---------------------------------------------------------------------------
# eviction under page pressure
# ---------------------------------------------------------------------------

def test_cached_pages_evicted_lru_under_pressure(f32_lm):
    """When free pages cannot cover an admission, refcount-1 cached
    pages are reclaimed LRU-first instead of rejecting; live tables'
    pages are never touched."""
    cfg, m, p = f32_lm
    # max_len 32 / page 8 -> 4 pages per row; 9 pages total (8 usable)
    eng = _engine(m, True, batch=2, max_len=32, num_pages=9)
    a = tokens_for(cfg, 1, 24, seed=7)
    b = tokens_for(cfg, 1, 24, seed=8)
    c = tokens_for(cfg, 1, 24, seed=9)
    eng.admit(p, a, max_new=4)
    eng.drain(p)                            # A indexes 3 pages
    eng.admit(p, b, max_new=4)
    eng.drain(p)                            # B indexes 3 more: 6 cached
    assert eng.free_pages() == 2
    assert eng.can_admit(c, 4)              # forces a reclaim of 2 pages
    eng.admit(p, c, max_new=4)
    eng.drain(p)
    assert eng.stats["cache_evictions"] >= 2
    # A's chain went first (least recently used)
    assert len(eng._prefix.lookup(a[0])) < 3
    # drained engine: every non-cached page is back on the free-list
    assert eng.free_pages() + len(eng._prefix.pages()) == 8


def test_full_cache_drops_for_fresh_admissions(f32_lm):
    """Degenerate pressure: the cache may hold every page; the next
    cold-prefix admission must still get in by emptying it."""
    cfg, m, p = f32_lm
    eng = _engine(m, True, batch=1, max_len=32, num_pages=5)
    a = tokens_for(cfg, 1, 24, seed=10)
    eng.admit(p, a, max_new=4)
    eng.drain(p)
    assert len(eng._prefix.pages()) == 3
    c = tokens_for(cfg, 1, 24, seed=11)
    assert eng.can_admit(c, 4)
    eng.admit(p, c, max_new=4)
    eng.drain(p)
    assert eng.stats["cache_evictions"] >= 2


def test_deferred_cow_source_survives_reclaim(f32_lm):
    """Chunked prefix-hit admission defers its boundary CoW copy to the
    request's first chunk tick.  Until that tick the copy SOURCE must be
    refcount-pinned: without the pin, an interleaved admission's
    ``can_admit`` reclaim sees the page at refcount 1 (its donor
    retired; only the index holds it), evicts it, and the next admission
    recycles and overwrites the storage the deferred copy then reads —
    silently corrupting the hit's stream."""
    cfg, m, p = f32_lm
    # 32/8 -> 4 pages per row; 10 pages -> 9 allocatable
    eng = _engine(m, True, chunk=8, batch=3, max_len=32, page=8,
                  num_pages=10)
    F = tokens_for(cfg, 1, 24, seed=20)
    cold = _engine(m, False, chunk=8, batch=3, max_len=32, page=8,
                   num_pages=10)
    cold.admit(p, F, max_new=4)
    ref = cold.drain(p)[0].tokens

    eng.admit(p, F, max_new=4)                   # donor
    eng.drain(p)                                 # indexes h0, h1, h2
    h2 = eng._prefix.lookup(F[0], peek=True)[2]
    # a long cold prompt occupies the chunk queue so the hit behind it
    # waits several ticks before its final chunk (and its CoW copy) runs
    eng.admit(p, tokens_for(cfg, 1, 24, seed=21), max_new=4)
    hit = eng.admit(p, F, max_new=4)[0]          # pending, cow = (h2, .)
    assert eng._pages.refcount(h2) == 2          # index + deferred-CoW pin
    assert eng.free_pages() == 0
    # an admission probe under page pressure must NOT reclaim the pinned
    # source (pre-fix it was evicted here, then recycled by this very
    # admission and overwritten before the hit's copy ran)
    assert not eng.can_admit(tokens_for(cfg, 1, 4, seed=22), 4)
    assert eng.stats["cache_evictions"] == 0
    assert h2 in eng._prefix.pages()
    _check_invariants(eng)
    eng.drain(p)
    assert hit.tokens == ref                     # bitwise = cold stream
    assert eng._pages.refcount(h2) == 1          # pin dropped at the copy
    _check_invariants(eng)


# ---------------------------------------------------------------------------
# randomized fuzz: refcount conservation + deterministic replay
# ---------------------------------------------------------------------------

def _check_invariants(eng):
    """Refcount conservation after any event:

      free + |pages reachable from live tables  U  cached index pages|
        == allocatable,

    and each allocated page's refcount equals the number of tables
    mapping it, plus the index's pin, plus one per pending admission
    still holding it as an un-executed CoW source (the pin that keeps
    ``_reclaim`` off the page until the deferred copy runs) — so a page
    can only appear in two tables if its refcount is > 1."""
    held = [g.pages for g in eng.slots if g is not None and g.pages]
    table_pages = [p for pages in held for p in pages]
    index_pages = eng._prefix.pages()
    cow_pins = [ps.cow[0] for ps in eng._pending if ps.cow is not None]
    reachable = set(table_pages) | index_pages
    assert eng.free_pages() + len(reachable) == eng._pages.allocatable, (
        "page leak/double-free", eng.free_pages(), sorted(reachable))
    # an un-executed CoW source is always still indexed (its pin keeps
    # its refcount >= 2, so LRU eviction cannot drop it mid-pending)
    assert set(cow_pins) <= index_pages, (cow_pins, sorted(index_pages))
    for pg in reachable:
        want = (table_pages.count(pg) + (1 if pg in index_pages else 0)
                + cow_pins.count(pg))
        assert eng._pages.refcount(pg) == want, (pg, want)
    for pg in range(1, eng._pages.total_pages):
        if pg not in reachable:
            assert eng._pages.refcount(pg) == 0, pg


def _check_indexed_immutable(eng, snaps):
    """CoW-never-mutates, observed directly: every page the index pins
    is byte-identical to its content at index time (decode writes land
    past the prompt; hits write only their own fresh/CoW pages).  An
    evicted page leaves ``snaps`` — its storage may be recycled."""
    leaf = np.asarray(jax.tree.leaves(eng.state.caches)[0])
    cached = eng._prefix.pages()
    for pg in list(snaps):
        if pg not in cached:
            del snaps[pg]
    for pg in cached:
        if pg in snaps:
            np.testing.assert_array_equal(leaf[:, pg], snaps[pg])
        else:
            snaps[pg] = leaf[:, pg].copy()


def _fuzz_run(m, p, cfg, seed):
    rng = np.random.default_rng(seed)
    eng = _engine(m, True, chunk=8, batch=3, max_len=32, page=4,
                  num_pages=16)
    families = [np.asarray(tokens_for(cfg, 1, 28, seed=100 + i))
                for i in range(3)]
    streams, snaps = [], {}
    for _ in range(40):
        act = rng.integers(0, 3)
        if act == 0 and eng.free_slots() and not eng.pending_slots():
            fam = families[rng.integers(0, len(families))]
            cut = int(rng.integers(4, 25))
            toks = fam[:, :cut].copy()
            if rng.random() < 0.5:          # diverge the tail
                toks[0, -1] = int((toks[0, -1] + 1) % cfg.vocab_size)
            if eng.can_admit(toks, 3):
                eng.admit(p, toks, max_new=3)
        elif act == 1 and eng.live_slots():
            for g in eng.step(p):
                streams.append(tuple(g.tokens))
        elif act == 2 and eng.live_slots():
            for g in eng.drain(p):
                streams.append(tuple(g.tokens))
        _check_invariants(eng)
        _check_indexed_immutable(eng, snaps)
    for g in eng.drain(p):
        streams.append(tuple(g.tokens))
    _check_invariants(eng)
    _check_indexed_immutable(eng, snaps)
    # fully drained: only the index still pins pages
    assert eng.free_pages() + len(eng._prefix.pages()) \
        == eng._pages.allocatable
    return streams, list(eng._pages._free), dict(eng.stats)


def test_fuzz_refcount_conservation_and_replay(f32_lm):
    cfg, m, p = f32_lm
    s1, f1, st1 = _fuzz_run(m, p, cfg, seed=0)
    s2, f2, st2 = _fuzz_run(m, p, cfg, seed=0)
    assert s1 == s2 and f1 == f2 and st1 == st2   # deterministic replay
    assert st1["prefix_hits"] > 0                 # traffic actually shared


# ---------------------------------------------------------------------------
# EngineKey / plumbing
# ---------------------------------------------------------------------------

def test_engine_key_fields_and_aliasing():
    k = EngineKey(name="a", batch_size=4, page_size=8, prefix_cache=True)
    assert k.name == "a" and k.prefix_cache and k.multi_step == 1
    assert k != EngineKey(name="a", batch_size=4, page_size=8)
    # positional prefix unpacking (scheduler failure path) still works
    name, bsz, *_ = k
    assert (name, bsz) == ("a", 4)


def test_prefix_cache_requires_paged(f32_lm):
    cfg, m, p = f32_lm
    with pytest.raises(ValueError, match="paged"):
        StepEngine(m, batch_size=2, max_len=64, prefix_cache=True)


def test_scheduler_prefix_cache_end_to_end():
    """ContinuousScheduler(prefix_cache=True): shared-prefix traffic
    produces the run-to-completion reference outputs, and the snapshot
    surfaces the sharing counters."""
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    names = ["supersub-super", "supersub-sub"]
    server, cfgs = build_server(names, 2, 64,
                                arch_overrides={"dtype": "float32",
                                                "param_dtype": "float32"})
    rng = np.random.default_rng(0)
    shared = {n: rng.integers(0, cfgs[n].vocab_size, (1, 32))
              for n in names}
    reqs = []
    for r in range(6):
        n = names[r % 2]
        tail = rng.integers(0, cfgs[n].vocab_size, (1, 8))
        reqs.append((n, np.concatenate([shared[n], tail], axis=1)))
    with ContinuousScheduler(server, batch_size=4, paged=True,
                             page_size=16, prefix_cache=True) as sched:
        futs = [sched.submit(n, t, steps=4) for n, t in reqs]
        outs = [f.result(timeout=300) for f in futs]
        snap = sched.snapshot()
    for (name, toks), out in zip(reqs, outs):
        ref = server.serve_batch(name, toks, steps=4)
        np.testing.assert_array_equal(out, ref)
    assert snap["prefix_hits"] >= 4          # 2 of 6 are cold firsts
    assert snap["prefix_pages_mapped"] >= 8  # 2 shared pages per hit
    with pytest.raises(ValueError, match="paged"):
        ContinuousScheduler(server, batch_size=4, prefix_cache=True)
    server.shutdown()
