"""Paper-constant validation (Fig 5 / Supplementary): the transcribed
hardware model must reproduce the paper's published ratios."""
import pytest

from repro.core import hwmodel as hw


def test_area_ratios_match_paper_claims():
    for (kind, tech), claim in hw.AREA_RATIO_CLAIMS.items():
        ours = hw.AREA_LAMBDA2[kind][tech] / hw.AREA_LAMBDA2[kind]["sram_1cfg"]
        assert ours == pytest.approx(claim, abs=0.005), (kind, tech)


def test_headline_area_reductions():
    # abstract: 63.0 % LUT / 71.1 % CB reduction for the dual-config design
    lut = 1 - hw.AREA_LAMBDA2["LUT"]["fefet_2cfg"] / \
        hw.AREA_LAMBDA2["LUT"]["sram_1cfg"]
    cb = 1 - hw.AREA_LAMBDA2["CB"]["fefet_2cfg"] / \
        hw.AREA_LAMBDA2["CB"]["sram_1cfg"]
    assert lut == pytest.approx(hw.HEADLINE_AREA_REDUCTION["LUT"], abs=0.005)
    assert cb == pytest.approx(hw.HEADLINE_AREA_REDUCTION["CB"], abs=0.005)


def test_critical_path_deltas_calibrated():
    """Fig 5(c): FeFET single-config -8.6 %, dual-config +9.6 % vs SRAM."""
    d1 = hw.critical_path_delta("fefet_1cfg")
    d2 = hw.critical_path_delta("fefet_2cfg")
    assert d1 == pytest.approx(hw.CRITICAL_PATH_CLAIMS["fefet_1cfg"],
                               abs=0.02)
    assert d2 == pytest.approx(hw.CRITICAL_PATH_CLAIMS["fefet_2cfg"],
                               abs=0.02)


def test_primitive_delay_power_statements():
    # stated numbers: 124.3 ps / 13.1 uW 6-input LUT; CB ~7.8 ps, ~2x SRAM
    assert hw.LUT_READ_DELAY_PS["fefet_1cfg"] == 124.3
    assert hw.LUT_READ_POWER_UW["fefet_1cfg"] == 13.1
    assert hw.CB_DELAY_PS["fefet_1cfg"] == pytest.approx(
        2 * hw.CB_DELAY_PS["sram_1cfg"], rel=0.05)
    # FeFET LUT power smallest of all techs (paper statement)
    assert hw.LUT_READ_POWER_UW["fefet_1cfg"] == \
        min(hw.LUT_READ_POWER_UW.values())
    # dual-config LUT delay < RRAM single-config (paper statement)
    assert hw.LUT_READ_DELAY_PS["fefet_2cfg"] < \
        hw.LUT_READ_DELAY_PS["rram_1cfg"]


def test_reconfig_time_formula():
    # paper: bitstream bits / 3.2 Gb/s ICAP
    t = hw.reconfig_time_s(180.0)      # resnet50-scale bitstream, megabits
    assert t == pytest.approx(180e6 / 3.2e9)


def test_context_load_time_model():
    t = hw.context_load_time_s(1_000_000_000)   # 1 GB over 25 GB/s
    assert t == pytest.approx(0.04)
