"""Multi-device checks, run in a subprocess with 8 fake host devices.

Prints one JSON line: {check_name: {"ok": bool, "err": float}}.
Invoked by tests/test_distributed.py; runnable standalone:
    python tests/_distributed_worker.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_arch, override, reduced  # noqa: E402
from repro.configs.base import OptimizerConfig, ParallelConfig, RunConfig  # noqa: E402
from repro.distributed.compat import shard_map  # noqa: E402
from repro.distributed.mesh import make_mesh  # noqa: E402
from repro.distributed.sharding import DEFAULT_RULES, shard_params_tree  # noqa: E402
from repro.models.model import build_model  # noqa: E402

RESULTS = {}


def record(name, ok, err=0.0):
    RESULTS[name] = {"ok": bool(ok), "err": float(err)}


# ---------------------------------------------------------------------------
# 1. EP MoE (shard_map all_to_all) == dense reference
# ---------------------------------------------------------------------------

def check_moe_ep():
    from repro.models.common import init_params
    from repro.models.moe import moe_dense_ref, moe_ep, moe_specs
    cfg = reduced(get_arch("qwen3-moe-235b-a22b"))
    cfg = override(cfg, moe=override(cfg.moe, num_experts=4, top_k=2,
                                     capacity_factor=4.0))  # no drops
    mesh = make_mesh((2, 4), ("data", "model"))
    specs = moe_specs(cfg)
    p = init_params(jax.random.key(0), specs)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    y_ref, aux_ref = moe_dense_ref(p, x, cfg)
    with mesh:
        y_ep, aux_ep = jax.jit(
            lambda p, x: moe_ep(p, x, cfg, mesh))(p, x)
    err = float(jnp.abs(y_ref - y_ep).max())
    record("moe_ep_vs_ref", err < 5e-4, err)


# ---------------------------------------------------------------------------
# 2. sharded train step == single-device step
# ---------------------------------------------------------------------------

def check_sharded_training():
    from repro.train.trainer import init_state, make_train_step
    cfg = override(reduced(get_arch("tinyllama-1.1b")), dtype="float32")
    rc = RunConfig(optimizer=OptimizerConfig(lr=1e-3),
                   parallel=ParallelConfig())
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0,
                                          cfg.vocab_size)}
    m0 = build_model(cfg)
    s0 = init_state(m0, jax.random.key(0), rc)
    out0, met0 = jax.jit(make_train_step(m0, rc))(s0, batch)

    mesh = make_mesh((4, 2), ("data", "model"))
    m1 = build_model(cfg, mesh=mesh)
    with mesh:
        s1 = init_state(m1, jax.random.key(0), rc)
        sh = shard_params_tree(mesh, s1["params"], m1.logical())
        s1["params"] = jax.device_put(s1["params"], sh)
        out1, met1 = jax.jit(make_train_step(m1, rc, mesh))(s1, batch)
    err = abs(float(met0["loss"]) - float(met1["loss"]))
    perr = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(out0["params"]), jax.tree.leaves(out1["params"])))
    record("sharded_train_step", err < 1e-4 and perr < 1e-3,
           max(err, perr))


# ---------------------------------------------------------------------------
# 3. int8 error-feedback gradient compression across the pod axis
# ---------------------------------------------------------------------------

def check_compression():
    from repro.distributed.compression import compressed_psum_mean
    mesh = make_mesh((2, 4), ("pod", "data"))
    g = jax.random.normal(jax.random.key(0), (2, 64))  # per-pod grads
    ef = jnp.zeros((2, 64))

    def body(g, ef):
        red, ef = compressed_psum_mean({"g": g[0]}, "pod", {"g": ef[0]})
        return red["g"], ef["g"]

    f = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("pod"), P("pod")),
        out_specs=(P(), P("pod")), check_vma=False))
    red, ef_out = f(g, ef)
    true_mean = g.mean(0)
    err = float(jnp.abs(red - true_mean).max())
    # int8 with shared scale: |err| <= scale = amax/127 (+ mean div)
    bound = float(jnp.abs(g).max()) / 127.0
    resid_ok = float(jnp.abs(ef_out).max()) <= bound + 1e-6
    record("int8_ef_compression", err <= bound + 1e-6 and resid_ok, err)


# ---------------------------------------------------------------------------
# 4. pipeline parallelism == direct apply
# ---------------------------------------------------------------------------

def check_pipeline():
    from repro.distributed.pipeline import pipeline_apply
    mesh = make_mesh((4,), ("pipe",))
    S, B, D = 4, 8, 16
    ws = jax.random.normal(jax.random.key(0), (S, D, D)) / np.sqrt(D)
    x = jax.random.normal(jax.random.key(1), (B, D))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    with mesh:
        y_pipe = pipeline_apply(stage_fn, mesh, ws, x, num_microbatches=4)
    y_ref = x
    for s in range(S):
        y_ref = stage_fn(ws[s], y_ref)
    err = float(jnp.abs(y_pipe - y_ref).max())
    record("pipeline_1f1b", err < 1e-5, err)


# ---------------------------------------------------------------------------
# 5. elastic restart: checkpoint on mesh A, restore on smaller mesh B
# ---------------------------------------------------------------------------

def check_elastic(tmp="/tmp/repro_elastic_test"):
    import shutil
    from repro.train.checkpoint import CheckpointManager
    from repro.train.elastic import restore_elastic
    from repro.train.trainer import init_state
    shutil.rmtree(tmp, ignore_errors=True)
    cfg = override(reduced(get_arch("tinyllama-1.1b")), dtype="float32")
    rc = RunConfig()
    mesh_a = make_mesh((4, 2), ("data", "model"))
    m = build_model(cfg, mesh=mesh_a)
    with mesh_a:
        state = init_state(m, jax.random.key(0), rc)
        sh = shard_params_tree(mesh_a, state["params"], m.logical())
        state["params"] = jax.device_put(state["params"], sh)
    mgr = CheckpointManager(tmp, keep=2, async_save=False)
    mgr.save(1, state, extra={"step": 1})
    mgr.wait()

    mesh_b = make_mesh((2, 1), ("data", "model"))   # "lost" 6 of 8 devices
    m_b = build_model(cfg, mesh=mesh_b)
    with mesh_b:
        restored, extra = restore_elastic(tmp, m_b, rc, mesh_b,
                                          jax.random.key(0))
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(jax.device_get(state["params"])),
        jax.tree.leaves(jax.device_get(restored["params"]))))
    ok = err == 0.0 and extra.get("step") == 1
    shards = jax.tree.leaves(restored["params"])[0].sharding
    record("elastic_restore", ok and shards.mesh.shape == mesh_b.shape, err)


# ---------------------------------------------------------------------------
# 6. kv-seq-sharded decode (SP) == replicated decode
# ---------------------------------------------------------------------------

def check_sp_decode():
    cfg = override(reduced(get_arch("deepseek-7b")), dtype="float32")
    m0 = build_model(cfg)
    m0.cache_dtype = jnp.float32
    p = m0.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    logits0, caches0 = m0.prefill(p, toks, max_len=32)
    step0, c0 = m0.decode_step(p, caches0, toks[:, :1], jnp.int32(16))

    mesh = make_mesh((2, 4), ("data", "model"))
    rules = DEFAULT_RULES.with_(kv_heads=None, kv_seq="model")
    m1 = build_model(cfg, mesh=mesh, rules=rules)
    m1.cache_dtype = jnp.float32
    with mesh:
        logits1, caches1 = jax.jit(
            lambda p, t: m1.prefill(p, t, 32))(p, toks)
        step1, _ = jax.jit(m1.decode_step)(p, caches1, toks[:, :1],
                                           jnp.int32(16))
    err = float(jnp.abs(step0 - step1).max())
    record("sp_decode_seq_sharded_kv", err < 5e-3, err)


if __name__ == "__main__":
    for fn in (check_moe_ep, check_sharded_training, check_compression,
               check_pipeline, check_elastic, check_sp_decode):
        try:
            fn()
        except Exception as e:  # pragma: no cover
            record(fn.__name__, False, -1.0)
            RESULTS[fn.__name__]["exc"] = repr(e)
    print("RESULTS_JSON:" + json.dumps(RESULTS))
