"""Dry-run smoke: one production-mesh cell compiles end-to-end, in a
subprocess (512 fake devices must never leak into this process)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
         "--mesh", "single", "--skip-metrics", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=600, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.load(open(tmp_path / "tinyllama-1.1b_decode_32k_single.json"))
    assert rec["chips"] == 256
    assert rec["compile_s"] > 0
    assert "error" not in rec["memory_analysis"]
    # sharded-collective sanity: decode on a 16x16 mesh must communicate
    assert rec["collectives_scanned"]["moved_bytes"] > 0


def test_main_process_still_single_device():
    import jax
    assert len(jax.devices()) == 1
