"""Continuous-batching step engine: equivalence with the classic
run-to-completion loop, slot-pool isolation, and the token-granular
scheduler end to end."""
import jax
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.engine import ServingEngine, StepEngine


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced_arch("tinyllama-1.1b")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


# ---------------------------------------------------------------------------
# equivalence with generate()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_step_engine_matches_generate(tiny_lm, temperature):
    """A batch of same-context requests admitted one by one at t=0 and
    stepped to completion emits token-for-token what generate() emits for
    the whole batch — greedy and seeded temperature (the per-row gumbel
    draw reproduces ``jax.random.categorical`` rows exactly)."""
    cfg, m, p = tiny_lm
    prompt = np.asarray(tokens_for(cfg, batch=3, seq=16))
    ref = ServingEngine(m, p, max_len=48, temperature=temperature,
                        seed=5).generate(prompt, steps=6)

    eng = StepEngine(m, batch_size=3, max_len=48,
                     temperature=temperature, seed=5)
    gens = []
    for r in range(3):                      # one admission per request
        gens += eng.admit(p, prompt[r], max_new=6)
    while eng.live_slots():
        eng.step(p)
    out = np.stack([np.asarray(g.tokens) for g in gens])
    np.testing.assert_array_equal(out, ref)


def test_generate_is_step_engine_wrapper(tiny_lm):
    """generate() == generate_fused() still holds now that generate runs
    on the step engine (greedy, whole batch admitted at t=0)."""
    cfg, m, p = tiny_lm
    eng = ServingEngine(m, p, max_len=48, temperature=0.0)
    prompt = tokens_for(cfg, batch=2, seq=16)
    host = eng.generate(prompt, steps=6)
    fused = np.asarray(eng.generate_fused(prompt, steps=6))
    np.testing.assert_array_equal(host, fused)


# ---------------------------------------------------------------------------
# slot-pool semantics
# ---------------------------------------------------------------------------

def _solo(m, p, prompt, steps, batch_size=2, max_len=64):
    eng = StepEngine(m, batch_size=batch_size, max_len=max_len)
    g = eng.admit(p, prompt, max_new=steps)[0]
    while eng.live_slots():
        eng.step(p)
    return np.asarray(g.tokens)


def test_admission_never_disturbs_inflight_rows(tiny_lm):
    """The serial-enable invariant at slot granularity: admitting and
    retiring neighbors must not change a live row's tokens (same pool
    shape, so the comparison is bitwise)."""
    cfg, m, p = tiny_lm
    pa = np.asarray(tokens_for(cfg, batch=1, seq=12, seed=3))
    pb = np.asarray(tokens_for(cfg, batch=1, seq=20, seed=4))
    ref_a = _solo(m, p, pa, 10)
    ref_b = _solo(m, p, pb, 5)

    eng = StepEngine(m, batch_size=2, max_len=64)
    ga = eng.admit(p, pa, max_new=10)[0]
    for _ in range(3):
        eng.step(p)
    gb = eng.admit(p, pb, max_new=5)[0]    # joins while a is mid-decode
    while eng.live_slots():
        eng.step(p)
    np.testing.assert_array_equal(np.asarray(ga.tokens), ref_a)
    np.testing.assert_array_equal(np.asarray(gb.tokens), ref_b)
    assert ga.slot != gb.slot
    assert eng.free_slots() == 2           # both retired back to the pool


def test_slot_recycling_is_clean(tiny_lm):
    """A freed slot's stale cache row must not leak into the next
    admission (per-slot cache reset via insert_cache_rows)."""
    cfg, m, p = tiny_lm
    eng = StepEngine(m, batch_size=2, max_len=64)
    for seed in (3, 4):                    # fill both slots and retire
        eng.admit(p, np.asarray(tokens_for(cfg, 1, 16, seed=seed)),
                  max_new=4)
    while eng.live_slots():
        eng.step(p)
    pc = np.asarray(tokens_for(cfg, batch=1, seq=20, seed=9))
    ref = _solo(m, p, pc, 6)
    gc = eng.admit(p, pc, max_new=6)[0]
    while eng.live_slots():
        eng.step(p)
    np.testing.assert_array_equal(np.asarray(gc.tokens), ref)


def test_admission_guards(tiny_lm):
    cfg, m, p = tiny_lm
    eng = StepEngine(m, batch_size=2, max_len=32)
    with pytest.raises(ValueError):        # would run off the cache
        eng.admit(p, np.asarray(tokens_for(cfg, 1, 16)), max_new=20)
    eng.admit(p, np.asarray(tokens_for(cfg, 2, 16)), max_new=4)
    with pytest.raises(RuntimeError):      # pool is full
        eng.admit(p, np.asarray(tokens_for(cfg, 1, 16)), max_new=4)


def test_eos_retires_slot(tiny_lm):
    """EOS retirement frees the slot before the step limit."""
    cfg, m, p = tiny_lm
    probe = StepEngine(m, batch_size=1, max_len=64)
    prompt = np.asarray(tokens_for(cfg, 1, 12, seed=3))
    g = probe.admit(p, prompt, max_new=8)[0]
    while probe.live_slots():
        probe.step(p)
    eos = g.tokens[2]                      # greedy is deterministic: make
    eng = StepEngine(m, batch_size=1, max_len=64,   # the 3rd token "EOS"
                     eos_id=eos)
    g2 = eng.admit(p, prompt, max_new=8)[0]
    while eng.live_slots():
        eng.step(p)
    assert g2.done
    # retires at the first occurrence of the eos token, before the limit
    assert len(g2.tokens) == g.tokens.index(eos) + 1 <= 3
    assert eng.free_slots() == 1


# ---------------------------------------------------------------------------
# token-granular scheduler end to end
# ---------------------------------------------------------------------------

def test_continuous_scheduler_mixed_contexts():
    from repro.launch.serve import build_server, request_stream
    from repro.serve.scheduler import ContinuousScheduler

    names = ["supersub-super", "supersub-sub"]
    server, cfgs = build_server(names, 2, 32, load_delay_s=0.01)
    reqs = list(request_stream(names, cfgs, 6, 2, 12, 0))
    # pool width == request width so the greedy outputs are bitwise equal
    # to the run-to-completion reference (same batch shape, same kernels)
    with ContinuousScheduler(server, batch_size=2) as sched:
        futs = [sched.submit(n, t, steps=4) for n, t in reqs]
        outs = [f.result(timeout=300) for f in futs]
    assert all(o.shape == (2, 4) for o in outs)
    snap = sched.snapshot()
    assert snap["requests"] == 6
    assert snap["admitted_rows"] == 12
    assert snap["steps"] > 0
    # both contexts loaded once and switching happened between steps
    assert snap["loads"] >= 2
    assert snap["context_changes"] >= 2

    # greedy continuous output == the run-to-completion server output
    for (name, toks), out in zip(reqs, outs):
        ref = server.serve_batch(name, toks, steps=4)
        np.testing.assert_array_equal(out, ref)
    server.shutdown()


def test_continuous_scheduler_survives_unloadable_context():
    """A context whose weights never load must fail ITS requests (no
    eternal retry spin) while the healthy context keeps serving."""
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler
    from repro.serve.switching import ServedModel
    from repro.models.model import build_model

    server, cfgs = build_server(["supersub-super"], 2, 32)
    cfg = cfgs["supersub-super"]
    broken = build_model(reduced_arch("supersub-sub"))

    def bad_weights():
        raise IOError("checkpoint corrupted")

    server.register(ServedModel(name="broken", model=broken,
                                weights_fn=bad_weights, max_len=32))
    with ContinuousScheduler(server, batch_size=2) as sched:
        bad = sched.submit("broken",
                           np.asarray(tokens_for(cfg, 1, 8)), steps=2)
        good = sched.submit("supersub-super",
                            np.asarray(tokens_for(cfg, 1, 8)), steps=2)
        with pytest.raises(IOError):
            bad.result(timeout=60)
        assert good.result(timeout=300).shape == (1, 2)
    server.shutdown()


def test_continuous_scheduler_drain_on_stop():
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    server, cfgs = build_server(["supersub-super"], 2, 32)
    cfg = cfgs["supersub-super"]
    sched = ContinuousScheduler(server, batch_size=2).start()
    futs = [sched.submit("supersub-super",
                         np.asarray(tokens_for(cfg, 1, 8, seed=s)), steps=3)
            for s in range(5)]
    sched.stop(drain=True)                 # everything queued still serves
    for f in futs:
        assert f.result(timeout=5).shape == (1, 3)
    server.shutdown()
