"""Unified serving telemetry: registry/view/histogram semantics, the
disabled-tracer overhead gate, Chrome trace schema validity, cross-layer
conservation invariants, and the headline acceptance check — the
hidden-load fraction recomputed from exported trace spans matches the
engine's own accounting."""
import json

import jax
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.core.scheduler import Run, simulate_dynamic
from repro.core.telemetry import (Histogram, ManualClock, MetricRegistry,
                                  Telemetry, Tracer, safe_ratio)
from repro.models.model import build_model
from repro.serve.engine import StepEngine
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.switching import ServedModel, SwitchableServer


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced_arch("supersub-sub")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def _make_server(names, telemetry=None, max_len=48):
    server = SwitchableServer(num_slots=2, telemetry=telemetry)
    cfgs = {}
    for i, name in enumerate(names):
        cfg = reduced_arch(name)
        cfgs[name] = cfg
        m = build_model(cfg)
        p = m.init(jax.random.key(i))
        server.register(ServedModel(name=name, model=m,
                                    weights_fn=lambda p=p: p,
                                    max_len=max_len))
    return server, cfgs


# ---------------------------------------------------------------------------
# registry / view / histogram units
# ---------------------------------------------------------------------------

def test_histogram_buckets_and_percentiles():
    h = Histogram(buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count == 5
    assert h.counts == [2, 1, 1, 1]
    assert h.percentile(0.0) == 0.01          # first non-empty bucket edge
    assert h.percentile(0.5) == 0.1           # 3rd of 5 obs -> bucket edge
    assert h.percentile(1.0) == 5.0           # overflow reports the max
    s = h.summary()
    assert s["count"] == 5 and s["max"] == 5.0
    assert s["mean"] == pytest.approx(5.56 / 5, abs=1e-6)


def test_registry_scalars_histograms_and_keys():
    reg = MetricRegistry()
    reg.inc("a.n", doc="a counter")
    reg.inc("a.n", 2)
    reg.gauge("free", 7)
    reg.observe("lat_s", 0.02, doc="a histogram")
    assert reg.value("a.n") == 3
    assert "a.n" in reg and "lat_s" in reg and "nope" not in reg
    assert reg.keys() == ["a.n", "free", "lat_s"]
    snap = reg.snapshot()
    assert snap["a.n"] == 3 and snap["free"] == 7
    assert snap["lat_s"]["count"] == 1


def test_metric_view_is_dict_compatible():
    reg = MetricRegistry()
    va = reg.view("eng.0.")
    vb = reg.view("eng.1.")
    va.update({"ticks": 0, "busy": 0.0})
    va["ticks"] += 2
    vb["ticks"] = 5
    assert va["ticks"] == 2 and vb["ticks"] == 5      # namespaced values
    assert dict(va) == {"ticks": 2, "busy": 0.0}
    assert sorted(va.items()) == [("busy", 0.0), ("ticks", 2)]
    assert va.setdefault("ticks", 99) == 2
    assert "ticks" in va and "other" not in va        # local namespace only
    assert reg.value("eng.0.ticks") == 2              # shared store
    with pytest.raises(KeyError):
        va["missing"]
    del va["busy"]
    assert "busy" not in va and "eng.0.busy" not in reg


def test_scoped_telemetry_shares_store():
    tm = Telemetry()
    child = tm.scoped("eng.0.")
    child.view()["x"] = 1
    child.observe("lat_s", 0.5)               # histograms stay unprefixed
    assert tm.registry.value("eng.0.x") == 1
    assert tm.registry.histogram("lat_s").count == 1
    assert child.tracer is tm.tracer and child.clock is tm.clock


# ---------------------------------------------------------------------------
# zero-denominator guards (satellite: early snapshots report 0.0, never NaN)
# ---------------------------------------------------------------------------

def test_safe_ratio_zero_denominator():
    assert safe_ratio(3.0, 2.0) == 1.5
    assert safe_ratio(3.0, 0.0) == 0.0
    assert safe_ratio(3.0, 0) == 0.0
    assert safe_ratio(0.0, 0.0, default=1.0) == 1.0


def test_fresh_snapshot_ratios_are_zero_not_nan(tiny_lm):
    """A snapshot taken before any load/tick happened must report 0.0
    ratios (present, finite), not raise or emit NaN."""
    server, _ = _make_server(["supersub-sub"])
    try:
        assert server.engine.hidden_load_fraction() == 0.0
        sched = ContinuousScheduler(server, batch_size=2)   # never started
        snap = sched.snapshot()
        assert snap["steps_per_tick"] == 0.0
        assert snap["host_ticks"] == 0 and snap["device_steps"] == 0
        assert snap["hidden_load_fraction"] == 0.0
        eng = server.step_engine("supersub-sub", 2)
        assert eng.stats["host_ticks"] == 0
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# injected clock: simulator and live engine emit the same stream
# ---------------------------------------------------------------------------

def test_manual_clock_drives_registry_and_tracer():
    clk = ManualClock()
    tm = Telemetry(clock=clk, trace=True)
    clk.set(10.0)
    tm.tracer.instant("ev", "trk")
    clk.advance(2.5)
    tm.tracer.span("sp", "trk", 10.0, clk())
    evs = tm.tracer.events()
    assert evs[0]["t0"] == 10.0
    assert evs[1]["dur"] == 2.5


def test_simulate_dynamic_emits_live_engine_keys():
    """The simulator writes the very ``ctx.*`` counters the live
    ``ContextSwitchEngine`` writes, on virtual time, and its hidden-load
    accounting matches the closed-form expectation."""
    tm = Telemetry(clock=ManualClock(), trace=True)
    sched = [Run("a", 1.0), Run("b", 1.0), Run("a", 1.0), Run("b", 1.0)]
    load = {"a": 0.5, "b": 0.5}
    total = simulate_dynamic(sched, load, num_slots=2, telemetry=tm)
    # baseline path unchanged by telemetry
    assert total == simulate_dynamic(sched, load, num_slots=2)
    v = tm.view("ctx.")
    assert v["loads"] == 2                     # a and b load exactly once
    assert v["load_seconds"] == pytest.approx(1.0)
    # a's initial load is a visible stall; b's load hides behind a's run
    assert v["visible_stall_seconds"] == pytest.approx(0.5)
    assert v["hidden_load_seconds"] == pytest.approx(0.5)
    assert v["switches"] == 4 and v["context_changes"] == 4
    tracks = {e["track"] for e in tm.tracer.events()}
    assert tracks == {"sim-loader", "sim-exec"}


# ---------------------------------------------------------------------------
# disabled-tracer overhead gate
# ---------------------------------------------------------------------------

def test_disabled_tracer_allocates_nothing():
    """Disabled, span/instant must return without allocating — the hot
    decode loop pays one attribute test per record point and nothing
    else (no tuple, no deque append, no args dict)."""
    import tracemalloc
    tr = Tracer(enabled=False)
    name, track = "tick", "eng"
    for _ in range(4):                         # warm any lazy setup
        tr.span(name, track, 0.0, 1.0)
        tr.instant(name, track, ts=0.0)
    # tracemalloc attributes every allocation to its source line, so
    # background-thread noise cannot produce a false positive: any
    # telemetry.py allocation during the loop is a real per-call cost
    tracemalloc.start()
    try:
        snap1 = tracemalloc.take_snapshot()
        for _ in range(1000):
            tr.span(name, track, 0.0, 1.0)
            tr.instant(name, track, ts=0.0)
        snap2 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    import os
    impl = os.path.join("core", "telemetry.py")
    grown = [st for st in snap2.compare_to(snap1, "lineno")
             if st.size_diff > 0
             and st.traceback[0].filename.endswith(impl)]
    assert len(tr) == 0
    assert not grown, [str(st) for st in grown]


def test_traced_and_untraced_outputs_identical(tiny_lm):
    """Tracing is observational: enabling it changes no token."""
    cfg, m, p = tiny_lm
    prompt = np.asarray(tokens_for(cfg, batch=2, seq=8, seed=7))
    outs = []
    for trace in (False, True):
        eng = StepEngine(m, batch_size=2, max_len=32,
                         telemetry=Telemetry(trace=trace))
        gens = eng.admit(p, prompt, max_new=4)
        while eng.live_slots():
            eng.step(p)
        outs.append(np.stack([np.asarray(g.tokens) for g in gens]))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# trace schema
# ---------------------------------------------------------------------------

def test_chrome_trace_schema(tiny_lm):
    """Exported JSON is valid Chrome trace-event format: metadata names
    every track, complete events carry non-negative ts/dur, instants
    carry a scope, and everything survives a json round-trip."""
    cfg, m, p = tiny_lm
    tm = Telemetry(trace=True)
    eng = StepEngine(m, batch_size=2, max_len=32, telemetry=tm)
    gens = eng.admit(p, np.asarray(tokens_for(cfg, batch=2, seq=8)),
                     max_new=4)
    while eng.live_slots():
        eng.step(p)
    assert all(g.done for g in gens)
    doc = json.loads(json.dumps(tm.tracer.chrome_trace()))
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    data = [e for e in evs if e["ph"] != "M"]
    assert data, "no events recorded"
    named_tids = {e["tid"] for e in meta if e["name"] == "thread_name"}
    assert {e["tid"] for e in data} <= named_tids
    for e in data:
        assert e["ph"] in ("X", "i")
        assert e["pid"] == 1 and e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        else:
            assert e["s"] == "t"
    kinds = {e["name"].split(":")[0] for e in data}
    assert {"tick", "first-token", "req"} <= kinds


# ---------------------------------------------------------------------------
# conservation invariants across layers
# ---------------------------------------------------------------------------

def test_conservation_invariants_continuous():
    """submitted == admitted + rejected + queued; every token is counted
    exactly once; histogram counts equal their triggering events."""
    tm = Telemetry()
    server, cfgs = _make_server(["supersub-super", "supersub-sub"],
                                telemetry=tm)
    names = list(cfgs)
    steps = 3
    try:
        with ContinuousScheduler(server, batch_size=4) as sched:
            futs = []
            for i in range(6):
                nm = names[i % 2]
                toks = np.asarray(tokens_for(cfgs[nm], batch=1, seq=8,
                                             seed=i))
                futs.append(sched.submit(nm, toks, steps=steps))
            outs = [f.result(timeout=300) for f in futs]
        snap = sched.snapshot()
        assert snap["requests"] == 6
        assert snap["requests"] == (snap["admitted_requests"]
                                    + snap["rejected_requests"]
                                    + snap["queued_requests"])
        reg = tm.registry
        eng_sum = {k: 0 for k in ("tokens_out", "admitted_rows",
                                  "retired_rows")}
        for key in reg.keys():
            for stat in eng_sum:
                if key.startswith("eng.") and key.endswith("." + stat):
                    eng_sum[stat] += reg.value(key)
        total_tokens = sum(int(np.asarray(o).size) for o in outs)
        assert eng_sum["tokens_out"] == total_tokens == 6 * steps
        assert eng_sum["admitted_rows"] == eng_sum["retired_rows"] == 6
        # one TTFT and one gen-latency observation per retired row
        assert reg.histogram("ttft_s").count == 6
        assert reg.histogram("gen_latency_s").count == 6
        # queue-wait observed once per admitted row
        assert reg.histogram("queue_wait_s").count == 6
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# the acceptance criterion: trace spans reproduce the engine's hidden-load
# accounting, and the overlap is visible in the trace
# ---------------------------------------------------------------------------

def _hidden_from_trace(events):
    loads = [e for e in events if e["name"].startswith("load:")]
    runs = [e for e in events if e["name"].startswith("run:")]
    hidden = total = 0.0
    overlapped = 0
    for ld in loads:
        l0, l1 = ld["t0"], ld["t0"] + ld["dur"]
        ov = sum(max(0.0, min(l1, r["t0"] + r["dur"]) - max(l0, r["t0"]))
                 for r in runs)
        if ov > 0:
            overlapped += 1
        hidden += min(ov, ld["dur"])
        total += ld["dur"]
    return hidden, total, overlapped


def test_hidden_load_fraction_matches_trace():
    """Mixed-model continuous serving with emulated load latency: the
    hidden-load fraction recomputed from exported ``load:``/``run:``
    spans matches ``ContextSwitchEngine`` accounting to < 1%, and at
    least one context load overlaps an active decode span (the paper's
    hidden reconfiguration, visually provable in Perfetto)."""
    from repro.launch.serve import build_server
    tm = Telemetry(trace=True)
    server, cfgs = build_server(["supersub-super", "supersub-sub"],
                                slots=2, max_len=48, load_delay_s=0.05,
                                telemetry=tm)
    names = list(cfgs)
    try:
        with ContinuousScheduler(server, batch_size=4) as sched:
            futs = []
            for i in range(8):
                nm = names[i % 2]
                toks = np.asarray(tokens_for(cfgs[nm], batch=1, seq=8,
                                             seed=i))
                futs.append(sched.submit(nm, toks, steps=6))
            for f in futs:
                f.result(timeout=300)
        eng_frac = server.engine.hidden_load_fraction()
        hidden, total, overlapped = _hidden_from_trace(tm.tracer.events())
        assert total > 0 and eng_frac > 0
        assert overlapped >= 1, "no load span overlapped a run span"
        trace_frac = hidden / total
        assert trace_frac == pytest.approx(eng_frac, rel=0.01)
        # the engine's raw accumulators match the span sums too
        assert total == pytest.approx(
            server.engine.stats["load_seconds"], rel=1e-6)
        assert hidden == pytest.approx(
            server.engine.stats["hidden_load_seconds"], rel=1e-6)
    finally:
        server.shutdown()
