"""Speculative cascade decode: the multi-token verify path (LM.verify_step
== a K-iteration decode loop), exact speculative sampling statistics, the
SpecEngine's greedy identity with plain decode, per-request seed
reproducibility under continuous batching, and the paused-context
starvation guard."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.engine import ServingEngine, StepEngine
from repro.serve.speculative import SpecEngine, speculative_accept


def _f32_model(name, **extra):
    cfg = reduced_arch(name, dtype="float32", param_dtype="float32", **extra)
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(0))


def _pooled(m, p, prompts, max_len):
    """Admit rows one by one so each sits at its own position — the
    continuous-batching state the verify path must handle."""
    B = len(prompts)
    caches = m.init_cache(B, max_len)
    pos, toks = [], []
    for r, pr in enumerate(prompts):
        pr = np.atleast_2d(pr)
        logits, rows = m.prefill(p, jnp.asarray(pr), max_len)
        caches = m.insert_cache_rows(caches, rows, jnp.asarray([r]))
        pos.append(pr.shape[1])
        toks.append(int(jnp.argmax(logits[0, -1])))
    return caches, np.asarray(pos, np.int32), np.asarray(toks)


# ---------------------------------------------------------------------------
# multi-token verify path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,extra,lens", [
    ("tinyllama-1.1b", {}, (20, 7)),                      # dense, full cache
    ("tinyllama-1.1b", {"sliding_window": 16}, (30, 9)),  # ring: one row
    ("jamba-v0.1-52b", {}, (16, 16)),                     # wrapped mid-block
])
def test_verify_step_matches_decode_loop(name, extra, lens):
    """verify_step over K tokens == K decode_step iterations: logits and
    final caches, with per-row positions, ring wraparound (the windowed
    case), and recurrent mixers (the hybrid case)."""
    cfg, m, p = _f32_model(name, **extra)
    max_len, K = 48, 4
    prompts = [np.asarray(tokens_for(cfg, 1, L, seed=3 + i))
               for i, L in enumerate(lens)]
    caches, pos, _ = _pooled(m, p, prompts, max_len)
    block = np.asarray(tokens_for(cfg, len(prompts), K, seed=7))

    c = caches
    outs = []
    for i in range(K):
        lg, c = m.decode_step(p, c, jnp.asarray(block[:, i:i + 1]),
                              jnp.asarray(pos + i))
        outs.append(np.asarray(lg[:, 0]))
    loop = np.stack(outs, 1)

    vl, vc = m.verify_step(p, caches, jnp.asarray(block), jnp.asarray(pos))
    np.testing.assert_allclose(np.asarray(vl), loop, atol=1e-4)
    for a, b in zip(jax.tree.leaves(vc), jax.tree.leaves(c)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


# ---------------------------------------------------------------------------
# exact speculative sampling
# ---------------------------------------------------------------------------

def test_speculative_accept_matches_target_distribution():
    """The first committed token's marginal equals the TARGET distribution
    even under a disagreeing draft — the accept/residual construction is
    exact, not approximate."""
    V, K, T, N = 8, 2, 1.0, 40_000
    ks = jax.random.split(jax.random.key(0), 4)
    q_logits = jax.random.normal(ks[0], (K, V)) * 1.5
    t_logits = jax.random.normal(ks[1], (K + 1, V)) * 1.5
    qb = jnp.broadcast_to(q_logits, (N, K, V))
    tb = jnp.broadcast_to(t_logits, (N, K + 1, V))
    props = jax.random.categorical(ks[2], qb / T).astype(jnp.int32)
    tokens, n = speculative_accept(ks[3], props, qb, tb, T)
    n = np.asarray(n)
    assert 0 < n.mean() < K          # both accept and reject paths exercised
    emp = np.bincount(np.asarray(tokens[:, 0]), minlength=V) / N
    expect = np.asarray(jax.nn.softmax(t_logits[0] / T))
    # ~5 sigma for a multinomial proportion at N=40k
    np.testing.assert_allclose(emp, expect, atol=0.013)


def test_speculative_accept_greedy_is_target_argmax():
    """Greedy acceptance commits exactly the target argmax prefix."""
    V, K = 16, 3
    ks = jax.random.split(jax.random.key(1), 2)
    t_logits = jax.random.normal(ks[0], (4, K + 1, V))
    tgt = np.asarray(jnp.argmax(t_logits, -1))
    props = np.array(tgt[:, :K])
    props[1, 1] = (props[1, 1] + 1) % V          # diverge row 1 at step 1
    props[2, 0] = (props[2, 0] + 1) % V          # diverge row 2 at step 0
    tokens, n = speculative_accept(
        ks[1], jnp.asarray(props), jnp.zeros((4, K, V)),
        t_logits, 0.0)
    tokens, n = np.asarray(tokens), np.asarray(n)
    np.testing.assert_array_equal(n, [K, 1, 0, K])
    for b in range(4):
        np.testing.assert_array_equal(tokens[b, :n[b] + 1], tgt[b, :n[b] + 1])


# ---------------------------------------------------------------------------
# SpecEngine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cascade():
    """f32 draft/target pair: the greedy-identity guarantee is exact in
    f32; bf16 caches can flip near-tie argmaxes because the multi-token
    verify rounds k/v differently than the one-token loop (see the
    SpecEngine docstring)."""
    cfg_t, mt, pt = _f32_model("supersub-super")
    cfg_d = reduced_arch("supersub-sub", dtype="float32",
                         param_dtype="float32")
    md = build_model(cfg_d, cache_dtype=jnp.float32)
    return cfg_t, mt, pt, md, md.init(jax.random.key(1))


def test_spec_engine_greedy_identical_to_generate(cascade):
    """Greedy speculative decode is token-for-token identical to
    StepEngine.generate for ANY draft — here a different model entirely —
    with staggered admissions and retirement mid-stream."""
    cfg, mt, pt, md, pd = cascade
    prompt = np.asarray(tokens_for(cfg, 3, 16))
    ref = ServingEngine(mt, pt, max_len=64).generate(prompt, steps=9)

    eng = SpecEngine(md, mt, batch_size=3, max_len=64, k=4)
    gens = eng.admit((pd, pt), prompt[0], max_new=9)
    eng.step((pd, pt))                            # row 0 runs a round alone
    for r in (1, 2):
        gens += eng.admit((pd, pt), prompt[r], max_new=9)
    while eng.live_slots():
        eng.step((pd, pt))
    out = np.stack([np.asarray(g.tokens) for g in gens])
    np.testing.assert_array_equal(out, ref)
    assert eng.stats["rounds"] < 9 * 3            # actually speculating
    assert eng.free_slots() == 3


def test_spec_engine_aligned_draft_accepts_everything(cascade):
    """A draft sharing the target's weights accepts every proposal:
    accepted-tokens/round hits the K+1 ceiling (modulo remaining-step
    caps) and output still matches plain generate."""
    cfg, mt, pt, _, _ = cascade
    prompt = np.asarray(tokens_for(cfg, 2, 12, seed=5))
    ref = ServingEngine(mt, pt, max_len=64).generate(prompt, steps=10)
    eng = SpecEngine(mt, mt, batch_size=2, max_len=64, k=4)
    gens = [g for r in range(2)
            for g in eng.admit((pt, pt), prompt[r], max_new=10)]
    while eng.live_slots():
        eng.step((pt, pt))
    np.testing.assert_array_equal(
        np.stack([np.asarray(g.tokens) for g in gens]), ref)
    assert eng.accepted_per_round > 4.0           # ceiling is K+1 = 5


def test_spec_engine_eos_retires_mid_block(cascade):
    """An EOS inside an accepted block truncates the row there and frees
    the slot."""
    cfg, mt, pt, md, pd = cascade
    prompt = np.asarray(tokens_for(cfg, 1, 12, seed=3))
    probe = ServingEngine(mt, pt, max_len=64).generate(prompt, steps=8)[0]
    eos = int(probe[2])
    eng = SpecEngine(md, mt, batch_size=1, max_len=64, k=4, eos_id=eos)
    g = eng.admit((pd, pt), prompt, max_new=8)[0]
    while eng.live_slots():
        eng.step((pd, pt))
    assert g.done
    assert g.tokens == [int(t) for t in probe[:list(probe).index(eos) + 1]]
    assert eng.free_slots() == 1


def test_spec_engine_admissions_draw_independently(cascade):
    """The admission gumbel field must advance across rounds: re-admitting
    the same prompt into the same slot at temperature>0 has to produce
    fresh draws, not clones of the first request's."""
    cfg, mt, pt, md, pd = cascade
    prompt = np.asarray(tokens_for(cfg, 1, 10, seed=8))
    eng = SpecEngine(md, mt, batch_size=1, max_len=48, k=3, temperature=1.5)
    firsts = []
    for _ in range(6):
        g = eng.admit((pd, pt), prompt, max_new=4)[0]
        while not g.done:
            eng.step((pd, pt))
        firsts.append(g.tokens[0])
    assert len(set(firsts)) > 1


def test_spec_engine_rejects_unsupported_models(cascade):
    cfg, mt, pt, md, _ = cascade
    hybrid = build_model(reduced_arch("jamba-v0.1-52b"))
    with pytest.raises(ValueError):               # recurrent state: no rewind
        SpecEngine(hybrid, mt, batch_size=1, max_len=32)
    windowed = build_model(reduced_arch("supersub-super",
                                        sliding_window=16))
    with pytest.raises(ValueError):               # ring writes: no rollback
        SpecEngine(md, windowed, batch_size=1, max_len=32)
    with pytest.raises(ValueError):               # per-request seeds
        eng = SpecEngine(md, mt, batch_size=1, max_len=32, k=2)
        eng.admit(None, np.zeros((1, 4), np.int32), max_new=2, seeds=[7])


# ---------------------------------------------------------------------------
# scheduler integration: mixed speculative / plain traffic
# ---------------------------------------------------------------------------

def test_continuous_scheduler_mixed_spec_and_plain_traffic():
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    names = ["supersub-super", "supersub-sub", "tinyllama-1.1b"]
    server, cfgs = build_server(names, 3, 40, load_delay_s=0.01,
                                arch_overrides={"dtype": "float32",
                                                "param_dtype": "float32"})
    rng = np.random.default_rng(0)
    reqs = []
    for r in range(8):                 # spec target and plain model alternate
        name = ["supersub-super", "tinyllama-1.1b"][r % 2]
        reqs.append((name, rng.integers(0, cfgs[name].vocab_size, (1, 12))))
    with ContinuousScheduler(server, batch_size=2,
                             draft={"supersub-super": "supersub-sub"},
                             spec_k=3) as sched:
        with pytest.raises(ValueError):           # spec contexts: no seeds
            sched.submit("supersub-super", reqs[0][1], steps=2, seed=1)
        futs = [sched.submit(n, t, steps=6) for n, t in reqs]
        outs = [f.result(timeout=300) for f in futs]
    snap = sched.snapshot()
    assert snap["spec_rounds"] > 0
    assert snap["loads"] >= 3          # all three contexts streamed in
    for (name, toks), out in zip(reqs, outs):
        ref = server.serve_batch(name, toks, steps=6)
        np.testing.assert_array_equal(out, ref)
    server.shutdown()


# ---------------------------------------------------------------------------
# per-request seeds under continuous batching
# ---------------------------------------------------------------------------

def test_seeded_rows_reproduce_across_slots_and_traffic():
    """A seeded row's draws depend only on (seed, prompt, position) — not
    the slot it lands in, the pool seed, or neighboring traffic."""
    cfg = reduced_arch("tinyllama-1.1b")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    prompt = np.asarray(tokens_for(cfg, 1, 12, seed=3))
    filler = np.asarray(tokens_for(cfg, 1, 8, seed=4))

    def run(pool_seed, pre_steps, seed):
        eng = StepEngine(m, batch_size=3, max_len=48, temperature=0.9,
                         seed=pool_seed)
        eng.admit(p, filler, max_new=20)
        for _ in range(pre_steps):
            eng.step(p)
        g = eng.admit(p, prompt, max_new=6, seeds=[seed])[0]
        while not g.done:
            eng.step(p)
        return g.tokens

    assert run(0, 0, 11) == run(5, 7, 11) == run(2, 3, 11)
    assert run(0, 0, 11) != run(0, 0, 12)     # different seed, new stream
    assert run(0, 0, None) != run(5, 7, None)  # unseeded: pool schedule


def test_continuous_scheduler_seeded_resubmission():
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    server, cfgs = build_server(["supersub-super"], 2, 40, temperature=0.8)
    cfg = cfgs["supersub-super"]
    prompt = np.asarray(tokens_for(cfg, 2, 10, seed=6))

    def serve(n_noise, seed):
        with ContinuousScheduler(server, batch_size=4) as sched:
            for i in range(n_noise):          # surrounding traffic varies
                sched.submit("supersub-super",
                             np.asarray(tokens_for(cfg, 1, 8, seed=i)),
                             steps=5)
            return sched.submit("supersub-super", prompt, steps=6,
                                seed=seed).result(timeout=300)

    a, b = serve(1, 123), serve(3, 123)
    np.testing.assert_array_equal(a, b)       # reproduces row-for-row
    assert not np.array_equal(a[0], a[1])     # rows are independent draws
    assert not np.array_equal(serve(1, 124), a)
    server.shutdown()


def test_recycled_slot_admission_draws_fresh_field():
    """A slot freed by step t and recycled at the next boundary must not
    hand the newcomer the gumbel row step t drew from — the admission key
    is salted past t=0."""
    cfg = reduced_arch("tinyllama-1.1b")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    T = 0.9
    pa = np.asarray(tokens_for(cfg, 1, 10, seed=1))
    pb = np.asarray(tokens_for(cfg, 1, 10, seed=2))
    eng = StepEngine(m, batch_size=1, max_len=48, temperature=T, seed=0)
    eng.admit(p, pa, max_new=2)
    eng.step(p)                    # retires A at step t=0 -> t becomes 1
    g2 = eng.admit(p, pb, max_new=2)[0]
    # the draw B would get if admission reused step 0's field
    logits, _ = m.prefill(p, jnp.asarray(pb), 48)
    stale = jax.random.gumbel(
        jax.random.fold_in(jax.random.PRNGKey(0), 0), (1, cfg.vocab_size),
        jnp.float32)
    leaked = int(jnp.argmax(logits[:, -1] / T + stale[0], axis=-1)[0])
    assert g2.tokens[0] != leaked


def test_step_failure_fails_only_the_failing_context():
    """A mid-tick step failure must fail the context the tick was acting
    on — not whatever context the previous tick served."""
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    server, cfgs = build_server(["supersub-super", "supersub-sub"], 2, 40)
    sched = ContinuousScheduler(server, batch_size=2)
    bad = sched._engine("supersub-sub")

    def boom(params=None):
        raise RuntimeError("injected step failure")

    bad.step = boom
    with sched:
        fa = sched.submit("supersub-super",
                          np.asarray(tokens_for(cfgs["supersub-super"],
                                                1, 8)), steps=4)
        fb = sched.submit("supersub-sub",
                          np.asarray(tokens_for(cfgs["supersub-sub"],
                                                1, 8)), steps=4)
        with pytest.raises(RuntimeError):
            fb.result(timeout=120)
        assert fa.result(timeout=300).shape == (1, 4)
    server.shutdown()


def test_serving_engine_bounds_cached_pools():
    """Traffic over many batch shapes must not accumulate KV pools
    without limit."""
    cfg = reduced_arch("supersub-super")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    eng = ServingEngine(m, p, max_len=24)
    for b in range(1, 7):
        eng.generate(np.asarray(tokens_for(cfg, b, 8)), steps=2)
    assert len(eng._step_engines) <= eng.max_cached_pools


# ---------------------------------------------------------------------------
# starvation guard
# ---------------------------------------------------------------------------

def test_starvation_guard_resumes_preempted_context():
    """A context preempted with frozen live rows must finish even while a
    hot competitor keeps its queue full: stranded rows age-boost exactly
    like queued requests, so the paused context eventually outranks the
    flood."""
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    server, cfgs = build_server(["supersub-super", "supersub-sub"], 2, 40)
    cfg = cfgs["supersub-super"]
    sched = ContinuousScheduler(server, batch_size=2, age_weight=200.0)
    try:
        # the victim: one long-running row on A
        fut_a = sched.submit("supersub-super",
                             np.asarray(tokens_for(cfg, 1, 8, seed=1)),
                             steps=12)
        cur = sched._tick(None)               # A activates, admits, steps
        hot = np.asarray(tokens_for(cfgs["supersub-sub"], 1, 8, seed=2))
        deadline = time.perf_counter() + 60.0
        preempted = False
        while not fut_a.done():
            with sched._cv:
                backlog = len(sched._queues["supersub-sub"])
            for _ in range(6 - backlog):      # keep the competitor hot
                sched.submit("supersub-sub", hot, steps=2)
            cur = sched._tick(cur)
            preempted |= cur == "supersub-sub"
            assert time.perf_counter() < deadline, \
                "stranded context never resumed under sustained pressure"
        assert preempted                      # the flood did take over
        assert fut_a.result().shape == (1, 12)
    finally:
        sched._stopping = True
        sched.stop(drain=False)
        server.shutdown()
