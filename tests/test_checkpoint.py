"""Fault-tolerant checkpointing: atomicity, integrity, corruption fallback,
pruning, mesh-agnostic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (
    CheckpointManager, load_pytree, save_pytree)


def _tree(seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    return {"a": jax.random.normal(ks[0], (8, 4)),
            "nested": {"b": jax.random.normal(ks[1], (3,)),
                       "c": jnp.arange(5, dtype=jnp.int32)}}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    p = str(tmp_path / "ck")
    save_pytree(p, t, extra={"step": 7})
    loaded = load_pytree(p)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupted_checkpoint_detected(tmp_path):
    t = _tree()
    p = str(tmp_path / "ck")
    save_pytree(p, t)
    raw = open(p, "rb").read()
    with open(p, "wb") as f:                   # truncate mid-file
        f.write(raw[: len(raw) // 2])
    with pytest.raises(Exception):
        load_pytree(p)


def test_manager_falls_back_on_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    t1, t2 = _tree(1), _tree(2)
    mgr.save(1, t1)
    mgr.save(2, t2)
    mgr.wait()
    # corrupt the newest
    newest = mgr._path(2)
    raw = open(newest, "rb").read()
    with open(newest, "wb") as f:
        f.write(raw[: len(raw) // 3])
    like = jax.tree.map(jnp.zeros_like, t1)
    restored, extra = mgr.restore(like=like)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_prunes_old(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in range(5):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_atomic_no_partial_file_visible(tmp_path):
    """A crash mid-save must never leave a *visible* half checkpoint (tmp +
    rename): the committed path appears only complete."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree())
    mgr.wait()
    files = os.listdir(tmp_path)
    assert not any(f.endswith(".tmp") for f in files)


def test_restore_respects_dtype_and_shape(tmp_path):
    t = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    p = str(tmp_path / "ck")
    save_pytree(p, t)
    out = load_pytree(p)
    assert out["w"].dtype == jnp.bfloat16
    assert out["w"].shape == (4, 4)
