"""The unified reconfiguration policy: unit semantics, simulator
invariants under random schedules, and the "simulate what you fly"
property — the discrete-event simulator and a live ContextSwitchEngine
driven through the same ``ReconfigPolicy`` code must agree on
eviction/prefetch ordering.  (Seeded ``random`` schedules, not
hypothesis: the hermetic CI image has no third-party strategy libs.)"""
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.context import ContextDescriptor, ContextSwitchEngine
from repro.core.policy import EnsureDecision, ReconfigPolicy
from repro.core.scheduler import (
    Run, run_schedule_live, simulate_conventional, simulate_dynamic,
    simulate_preloaded)


# ------------------------------------------------------------------ unit
def test_lru_eviction_order():
    p = ReconfigPolicy(num_slots=2)
    for n in ("a", "b"):
        assert p.ensure(n).load
        p.complete(n)
    p.activate("a")
    p.activate("b")                       # LRU order now: a, b
    d = p.ensure("c", active="b")
    assert d.evictions == ("a",)          # least-recently activated goes
    assert p.holds("c") and not p.holds("a")


def test_active_never_evicted():
    p = ReconfigPolicy(num_slots=2)
    p.ensure("a"), p.complete("a"), p.activate("a")
    p.ensure("b"), p.complete("b")
    # both slots full; only the non-active resident is a candidate
    d = p.ensure("c", active="a")
    assert d.evictions == ("b",)


def test_pending_load_is_pinned():
    p = ReconfigPolicy(num_slots=2)
    p.ensure("a"), p.complete("a"), p.activate("a")
    p.ensure("b", active="a")             # queued, never completed
    assert p.is_pending("b")
    assert p.ensure("c", active="a") is None   # a active, b pinned: refuse
    assert not p.holds("c")               # refusal must not mutate


def test_protect_shields_earlier_needs():
    p = ReconfigPolicy(num_slots=3)
    for n in ("a", "b", "x"):
        p.ensure(n), p.complete(n)
    for n in ("x", "b"):
        p.activate(n)                     # LRU: a, x, b
    d = p.ensure("c", active="b", protect=["a"])
    assert d.evictions == ("x",)          # a is needed sooner: spared


def test_prefetch_plans_in_need_order_and_stops_when_full():
    p = ReconfigPolicy(num_slots=2)
    p.ensure("a"), p.complete("a"), p.activate("a")
    decs = p.prefetch(["b", "c", "b"], active="a")
    # one free slot: b fits, c would need to evict b (needed sooner) or
    # the active a -> planning stops
    assert [d.net for d in decs] == ["b"]
    assert p.is_pending("b") and not p.holds("c")


def test_prefetch_limit_and_dedup():
    p = ReconfigPolicy(num_slots=4)
    p.ensure("a"), p.complete("a"), p.activate("a")
    decs = p.prefetch(["b", "b", "c", "d"], active="a", limit=2)
    assert [d.net for d in decs] == ["b", "c"]


def test_ensure_noop_when_held():
    p = ReconfigPolicy(num_slots=2)
    p.ensure("a")
    assert p.ensure("a") == EnsureDecision(net="a")
    p.complete("a")
    assert p.ensure("a") == EnsureDecision(net="a")


def test_activate_requires_residency():
    p = ReconfigPolicy(num_slots=2)
    with pytest.raises(KeyError):
        p.activate("ghost")


def test_rank_contexts_prefers_resident_on_pressure_tie():
    p = ReconfigPolicy(num_slots=2)
    p.ensure("warm"), p.complete("warm")
    ranked = p.rank_contexts({"warm": 3.0, "cold": 3.0},
                             load_cost={"cold": 1.0, "warm": 1.0})
    assert ranked[0] == "warm"            # resident => zero switch-in cost
    # overwhelming pressure still wins over residency
    ranked = p.rank_contexts({"warm": 1.0, "cold": 5.0},
                             load_cost={"cold": 1.0})
    assert ranked[0] == "cold"


def test_rank_contexts_deterministic_tiebreak():
    p = ReconfigPolicy(num_slots=2)
    assert p.rank_contexts({"b": 1.0, "a": 1.0}) == ["a", "b"]
    assert p.rank_contexts({"a": 0.0, "b": 1.0}) == ["b"]   # idle dropped


def test_release_and_abort_free_slots():
    p = ReconfigPolicy(num_slots=2)
    p.ensure("a"), p.complete("a")
    p.ensure("b")
    p.abort("b")
    p.release("a")
    assert p.occupied() == 0


# ------------------------------------------- simulator invariants (random)
def _random_case(rng: random.Random, max_nets=3):
    nets = [f"n{i}" for i in range(rng.randint(2, max_nets))]
    loads = {f"n{i}": rng.uniform(0.1, 30.0) for i in range(max_nets)}
    sched = [Run(rng.choice(nets), rng.uniform(0.1, 50.0),
                 rng.randint(1, 4))
             for _ in range(rng.randint(1, 12))]
    return sched, loads


def test_dynamic_between_preloaded_and_conventional_random():
    rng = random.Random(7)
    for _ in range(300):
        sched, loads = _random_case(rng)
        conv = simulate_conventional(sched, loads)
        pre = simulate_preloaded(sched, loads)
        dyn = simulate_dynamic(sched, loads, num_slots=2)
        assert pre <= dyn + 1e-9 <= conv + 1e-9


def test_more_slots_never_hurt_random():
    rng = random.Random(11)
    for _ in range(200):
        sched, loads = _random_case(rng)
        slots = rng.randint(2, 4)
        d = simulate_dynamic(sched, loads, num_slots=slots)
        d2 = simulate_dynamic(sched, loads, num_slots=slots + 1)
        assert d2 <= d + 1e-9


def test_zero_load_time_equalizes_random():
    rng = random.Random(13)
    for _ in range(100):
        sched, loads = _random_case(rng)
        zero = {k: 0.0 for k in loads}
        assert abs(simulate_dynamic(sched, zero)
                   - simulate_conventional(sched, zero)) < 1e-9


# --------------------------------------- sim/live decision-trace agreement
def _instant_desc(name):
    return ContextDescriptor(
        name=name, apply_fn=lambda p, x: x + p["w"],
        weights_fn=lambda: {"w": jnp.ones((4,), jnp.float32)})


def test_sim_and_live_engine_agree_on_policy_trace():
    """The tentpole property: `simulate_dynamic` and a live
    ``ContextSwitchEngine`` driven through the same schedule produce the
    exact same (load, evict, activate) decision sequence, because both
    route every decision through ``ReconfigPolicy``.  Zero-cost loads +
    ``settle`` serialize the live engine's decision points so the
    comparison is deterministic."""
    rng = random.Random(1234)
    nets = ["a", "b", "c"]
    for trial in range(25):
        slots = rng.choice([2, 2, 3])
        sched = [Run(rng.choice(nets), 0.0, 1)
                 for _ in range(rng.randint(1, 10))]

        sim_pol = ReconfigPolicy(slots)
        simulate_dynamic(sched, {n: 0.0 for n in nets},
                         num_slots=slots, policy=sim_pol)

        live_pol = ReconfigPolicy(slots)
        eng = ContextSwitchEngine(num_slots=slots, policy=live_pol)
        for n in nets:
            eng.register(_instant_desc(n))
        inputs = {n: (jnp.zeros((4,), jnp.float32),) for n in nets}
        run_schedule_live(eng, sched, inputs, dynamic=True,
                          lookahead=None, settle=True)
        eng.shutdown()

        assert sim_pol.actions() == live_pol.actions(), (
            trial, [r.net for r in sched], slots,
            sim_pol.actions(), live_pol.actions())


def test_live_dynamic_runs_correct_outputs():
    """Policy-driven eviction/prefetch never serves stale weights."""
    scales = {"a": 1.0, "b": 2.0, "c": 3.0}
    eng = ContextSwitchEngine(num_slots=2)
    for n, s in scales.items():
        eng.register(ContextDescriptor(
            name=n, apply_fn=lambda p, x: x * p["w"],
            weights_fn=lambda s=s: {"w": jnp.full((4,), s)}))
    sched = [Run(n, 0.0, 1) for n in "abcacba"]
    x = jnp.ones((4,))
    for r in sched:
        eng.preload(r.net, allow_evict_active=True)
        eng.switch(r.net, wait=True)
        out = np.asarray(eng.run(x))
        np.testing.assert_allclose(out, scales[r.net])
        eng.prefetch([q.net for q in sched], limit=1)
    eng.shutdown()
