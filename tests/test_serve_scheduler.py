"""Switch-aware async serving: correctness of coalesced execution, strict
switch reduction vs FIFO order, per-request sampling independence."""
import jax
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.scheduler import SwitchScheduler
from repro.serve.switching import ServedModel, SwitchableServer

NAMES = ["supersub-super", "supersub-sub", "tinyllama-1.1b"]


def _make_server(temperature: float = 0.0, num_slots: int = 2):
    server = SwitchableServer(num_slots=num_slots)
    cfgs = {}
    for i, name in enumerate(NAMES):
        cfg = reduced_arch(name)
        cfgs[name] = cfg
        m = build_model(cfg)
        p = m.init(jax.random.key(i))
        server.register(ServedModel(name=name, model=m,
                                    weights_fn=lambda p=p: p, max_len=40,
                                    temperature=temperature))
    return server, cfgs


@pytest.fixture(scope="module")
def servers():
    a, cfgs = _make_server()
    b, _ = _make_server()
    yield a, b, cfgs
    a.shutdown()
    b.shutdown()


def test_scheduler_outputs_match_sync_and_switches_fewer(servers):
    """N interleaved requests across 3 contexts on 2 slots: every future
    resolves to exactly what a synchronous server computes, and the
    coalescing scheduler flips contexts strictly fewer times than FIFO
    arrival order does."""
    sched_server, ref_server, cfgs = servers
    reqs = []
    for r in range(9):
        name = NAMES[r % 3]                 # worst case: round-robin
        toks = np.asarray(tokens_for(cfgs[name], batch=2, seq=16, seed=r))
        reqs.append((name, toks))

    changes0 = sched_server.engine.stats["context_changes"]
    with SwitchScheduler(sched_server) as sched:
        futs = [sched.submit(n, t, steps=2, seed=100 + i)
                for i, (n, t) in enumerate(reqs)]
        outs = [f.result(timeout=300) for f in futs]
    queue_changes = sched_server.engine.stats["context_changes"] - changes0

    fifo_changes0 = ref_server.engine.stats["context_changes"]
    for i, ((name, toks), out) in enumerate(zip(reqs, outs)):
        ref = ref_server.serve_batch(name, toks, steps=2, seed=100 + i)
        np.testing.assert_array_equal(ref, out)
    fifo_changes = (ref_server.engine.stats["context_changes"]
                    - fifo_changes0)

    assert queue_changes < fifo_changes, (queue_changes, fifo_changes)
    assert queue_changes <= len(NAMES)      # one streak per context
    assert sched.stats["requests"] == len(reqs)
    assert sched.stats["stacked_requests"] > 0   # same-shape greedy stacked


def test_scheduler_prefetches_into_shadow_slot(servers):
    """While one streak executes, the next-ranked context must already be
    loading/resident (the paper's hidden reconfiguration, request-level)."""
    sched_server, _, cfgs = servers
    loads0 = sched_server.engine.stats["loads"]
    reqs = []
    for r in range(6):
        name = NAMES[r % 2]
        reqs.append((name,
                     np.asarray(tokens_for(cfgs[name], 2, 16, seed=40 + r))))
    with SwitchScheduler(sched_server) as sched:
        futs = [sched.submit(n, t) for n, t in reqs]
        [f.result(timeout=300) for f in futs]
    # both contexts ended resident: the follow-up streak's model was
    # prefetched rather than demand-loaded at switch time
    resident = set(sched_server.engine.resident())
    assert {NAMES[0], NAMES[1]} <= resident


def test_submit_unknown_model_raises(servers):
    sched_server, _, _ = servers
    s = SwitchScheduler(sched_server)
    with pytest.raises(KeyError):
        s.submit("nope", np.zeros((1, 4), np.int64))


def test_stop_without_drain_fails_leftovers():
    server, cfgs = _make_server()
    sched = SwitchScheduler(server)         # never started: nothing drains
    fut = sched.submit(NAMES[0],
                       np.asarray(tokens_for(cfgs[NAMES[0]], 1, 16)))
    sched.stop(drain=False)
    with pytest.raises(RuntimeError):
        fut.result(timeout=5)
    with pytest.raises(RuntimeError):
        sched.submit(NAMES[0], np.zeros((1, 4), np.int64))
    server.shutdown()


def test_temperature_sampling_is_per_request():
    """Satellite fix: identical prompts at temperature>0 must be
    independent draws across requests (the old server pinned PRNGKey(0)
    forever); an explicit seed still reproduces exactly."""
    server, cfgs = _make_server(temperature=0.8)
    name = NAMES[0]
    toks = np.asarray(tokens_for(cfgs[name], batch=4, seq=16, seed=3))
    outs = [server.serve_batch(name, toks, steps=6) for _ in range(4)]
    distinct = {o.tobytes() for o in outs}
    assert len(distinct) > 1, "temperature>0 requests must not be clones"
    a = server.serve_batch(name, toks, steps=6, seed=77)
    b = server.serve_batch(name, toks, steps=6, seed=77)
    np.testing.assert_array_equal(a, b)     # explicit seed reproduces
    server.shutdown()
