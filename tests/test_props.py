"""Hypothesis property tests for system invariants beyond the scheduler:
sharding-spec legality, checkpoint roundtrips, quantization bounds, ring
cache indexing."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (hermetic env)")
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.distributed.sharding import logical_to_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


mesh_shapes = st.fixed_dictionaries({
    "pod": st.sampled_from([1, 2]),
    "data": st.sampled_from([1, 2, 4, 8, 16, 32]),
    "model": st.sampled_from([1, 2, 4, 8, 16]),
})
logical_names = st.sampled_from(
    ["batch", "embed", "heads", "kv_heads", "ffn", "vocab", "experts",
     "act_heads", "act_attn_q", "kv_seq", "layers", "head_dim"])
dims = st.integers(1, 512)


@given(mesh_shapes, st.lists(st.tuples(logical_names, dims), min_size=1,
                             max_size=5))
@settings(max_examples=300, deadline=None)
def test_spec_always_legal(mesh_shape, logical_dims):
    """Every produced PartitionSpec (a) only uses existing mesh axes,
    (b) never reuses an axis, (c) always divides the dim."""
    mesh = FakeMesh(mesh_shape)
    logical = tuple(n for n, _ in logical_dims)
    shape = tuple(d for _, d in logical_dims)
    spec = logical_to_spec(mesh, logical, shape)
    used = []
    for ax, dim in zip(spec, shape):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else ax
        total = 1
        for a in axes:
            assert a in mesh_shape
            assert a not in used
            used.append(a)
            total *= mesh_shape[a]
        assert dim % total == 0


@given(st.lists(st.tuples(st.sampled_from(["f32", "bf16", "i32"]),
                          st.lists(st.integers(1, 7), min_size=0,
                                   max_size=3)),
                min_size=1, max_size=6),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_checkpoint_roundtrip_arbitrary_pytree(leaf_specs, seed):
    import tempfile
    from repro.train.checkpoint import load_pytree, save_pytree
    rng = np.random.default_rng(seed)
    dts = {"f32": jnp.float32, "bf16": jnp.bfloat16, "i32": jnp.int32}
    tree = {}
    for i, (dt, shape) in enumerate(leaf_specs):
        a = rng.standard_normal(shape) * 100
        tree[f"leaf{i}"] = jnp.asarray(a, dts[dt])
    tmpdir = tempfile.mkdtemp()
    path = f"{tmpdir}/ck_{seed}"
    save_pytree(path, tree)
    out = load_pytree(path)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))


@given(st.integers(0, 2 ** 16), st.sampled_from([4, 8, 16, 64]))
@settings(max_examples=200, deadline=None)
def test_ring_cache_slot_validity(pos, window):
    """Sliding-window ring indexing: the valid-slot rule must mark exactly
    min(pos+1, window) slots valid and include the current token's slot."""
    idx = np.arange(window)
    valid = (idx <= pos % window) | (pos >= window)
    assert valid.sum() == min(pos + 1, window)
    assert valid[pos % window]


@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                min_size=1, max_size=64))
@settings(max_examples=200, deadline=None)
def test_int8_quantization_error_bound(xs):
    """Shared-scale int8: |dequant - x| <= scale/2 + eps, residual == err."""
    from hypothesis import assume
    x = jnp.asarray(xs, jnp.float32)
    amax = float(jnp.max(jnp.abs(x)))
    assume(amax == 0.0 or amax > 1e-30)    # subnormal scales are degenerate
    scale = amax / 127.0 if amax > 0 else 1.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    deq = q * scale
    err = np.asarray(jnp.abs(deq - x))
    assert (err <= scale / 2 * 1.001 + 1e-5 * max(amax, 1.0)).all()


@given(st.integers(1, 64), st.integers(1, 16))
@settings(max_examples=100, deadline=None)
def test_moe_dispatch_conservation(tokens, experts):
    """Capacity-padded dispatch: with capacity >= tokens, every (token,
    choice) lands in exactly one slot and combine reconstructs weights."""
    from repro.models.moe import _combine_local, _dispatch_local
    k = min(2, experts)
    rng = np.random.default_rng(tokens * 131 + experts)
    xt = jnp.asarray(rng.standard_normal((tokens, 4)), jnp.float32)
    top_i = jnp.asarray(rng.integers(0, experts, (tokens, k)))
    top_p = jnp.asarray(np.abs(rng.standard_normal((tokens, k))) + 0.1,
                        jnp.float32)
    cap = tokens * k                    # nothing can drop
    buf, slot, kept = _dispatch_local(xt, top_p, top_i, experts, cap)
    assert bool(kept.all())
    # identity expert: combine must return sum_k p_k * x
    y = _combine_local(buf, top_p, top_i, slot, kept, cap)
    want = (np.asarray(top_p).sum(1, keepdims=True) * np.asarray(xt))
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-5, atol=1e-5)
