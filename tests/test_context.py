"""The paper's contribution: ContextSwitchEngine slot semantics, overlap,
and the non-volatile context store."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.context import (
    ContextDescriptor, ContextStore, ContextSwitchEngine)


def _desc(name, scale, delay=0.0):
    def weights_fn():
        if delay:
            time.sleep(delay)
        return {"w": jnp.full((32, 32), scale, jnp.float32)}

    def apply_fn(params, x):
        return x @ params["w"]

    return ContextDescriptor(name=name, apply_fn=apply_fn,
                             weights_fn=weights_fn)


def test_switch_and_run():
    eng = ContextSwitchEngine(num_slots=2)
    eng.register(_desc("a", 1.0))
    eng.register(_desc("b", 2.0))
    eng.preload("a", block=True)
    eng.switch("a")
    x = jnp.ones((4, 32))
    ya = eng.run(x)
    eng.preload("b", block=True)
    eng.switch("b")
    yb = eng.run(x)
    np.testing.assert_allclose(np.asarray(yb), 2 * np.asarray(ya))
    eng.shutdown()


def test_switch_is_o1_vs_load():
    """The paper's headline: switching resident contexts is orders of
    magnitude cheaper than loading one."""
    eng = ContextSwitchEngine(num_slots=2)
    eng.register(_desc("a", 1.0, delay=0.05))
    eng.register(_desc("b", 2.0, delay=0.05))
    eng.preload("a", block=True)
    eng.preload("b", block=True)
    eng.switch("a")
    t_switch = min(eng.switch("b") or 1.0, eng.switch("a"))
    load_t = eng.stats["load_seconds"] / eng.stats["loads"]
    assert t_switch < load_t / 10, (t_switch, load_t)
    eng.shutdown()


def test_load_never_disturbs_active_execution():
    """The serial-enable-transistor invariant: run() output is unaffected
    by a concurrent load into the shadow slot."""
    eng = ContextSwitchEngine(num_slots=2)
    eng.register(_desc("a", 1.0))
    eng.register(_desc("b", 2.0, delay=0.02))
    eng.preload("a", block=True)
    eng.switch("a")
    x = jnp.ones((4, 32))
    want = np.asarray(eng.run(x))
    eng.preload("b")                      # loads while we keep running
    for _ in range(20):
        np.testing.assert_array_equal(np.asarray(eng.run(x)), want)
    eng.shutdown()


def test_active_slot_never_evicted():
    eng = ContextSwitchEngine(num_slots=2)
    for n, s in [("a", 1.0), ("b", 2.0), ("c", 3.0)]:
        eng.register(_desc(n, s))
    eng.preload("a", block=True)
    eng.switch("a")
    eng.preload("b", block=True)
    eng.preload("c", block=True)          # evicts b (READY), never a (ACTIVE)
    assert "a" in eng.resident()
    assert eng.active.name == "a"
    with pytest.raises(RuntimeError):
        eng.evict("a")
    eng.shutdown()


def test_switch_waits_for_loading_context():
    eng = ContextSwitchEngine(num_slots=2)
    eng.register(_desc("a", 1.0, delay=0.2))
    fut = eng.preload("a")
    dt = eng.switch("a", wait=True)       # visible stall = remaining load
    assert eng.active.name == "a"
    assert dt > 0.05                      # had to wait
    eng.shutdown()


def test_switch_unknown_context_raises():
    eng = ContextSwitchEngine(num_slots=2)
    eng.register(_desc("a", 1.0))
    with pytest.raises(KeyError):
        eng.switch("a")                   # never preloaded
    eng.shutdown()


def test_more_slots_time_multiplexed_mode():
    """num_slots > 2 == Trimberger'97 time-multiplexed FPGA: all resident."""
    eng = ContextSwitchEngine(num_slots=4)
    for n in "abcd":
        eng.register(_desc(n, 1.0))
        eng.preload(n, block=True)
    assert sorted(eng.resident()) == list("abcd")
    assert eng.stats["evictions"] == 0
    eng.shutdown()


def test_context_store_persistence(tmp_path):
    """FeFET non-volatility analogue: a context survives engine restart."""
    store = ContextStore(str(tmp_path))
    w = {"w": jnp.full((8, 8), 3.0)}
    store.save("ctx", w)
    eng = ContextSwitchEngine(num_slots=2, store=store)
    eng.register(ContextDescriptor(
        name="ctx", apply_fn=lambda p, x: x @ p["w"],
        weights_fn=store.weights_fn("ctx")))
    eng.preload("ctx", block=True)
    eng.switch("ctx")
    out = eng.run(jnp.ones((2, 8)))
    np.testing.assert_allclose(np.asarray(out), 24.0)
    eng.shutdown()


def test_overlap_accounting():
    eng = ContextSwitchEngine(num_slots=2)
    eng.register(_desc("a", 1.0))
    eng.register(_desc("b", 2.0, delay=0.05))
    eng.preload("a", block=True)
    eng.switch("a")
    x = jnp.ones((256, 32))
    eng.preload("b")
    for _ in range(10):
        eng.run(x)                        # execution overlaps the load
    eng.switch("b", wait=True)
    assert eng.stats["loads"] == 2
    assert eng.stats["switches"] >= 2
    eng.shutdown()


def test_partial_reconfiguration_delta_load():
    """Paper Fig 1(b) analogue: a specialist sharing the base's backbone
    loads only its head delta — wire bytes ~ delta, not full context."""
    backbone = {"backbone": jnp.ones((256, 256)), "head": jnp.ones((256, 8))}
    delta = {"head": jnp.full((256, 8), 2.0)}

    from repro.core.context import ContextDescriptor
    eng = ContextSwitchEngine(num_slots=3)
    eng.register(ContextDescriptor(
        name="base", apply_fn=lambda p, x: (x @ p["backbone"]) @ p["head"],
        weights_fn=lambda: backbone))
    eng.register(ContextDescriptor(
        name="spec", apply_fn=lambda p, x: (x @ p["backbone"]) @ p["head"],
        weights_fn=lambda: delta, base="base"))
    eng.preload("base", block=True)
    b0 = eng.stats["bytes_loaded"]
    eng.preload("spec", block=True)
    delta_bytes = eng.stats["bytes_loaded"] - b0
    assert delta_bytes == 256 * 8 * 4          # only the head crossed H2D
    eng.switch("spec")
    out = eng.run(jnp.ones((2, 256)))
    np.testing.assert_allclose(np.asarray(out), 256 * 256 * 2.0)
    # base context unchanged and still correct
    eng.switch("base")
    out_b = eng.run(jnp.ones((2, 256)))
    np.testing.assert_allclose(np.asarray(out_b), 256 * 256 * 1.0)
    eng.shutdown()


def test_delta_load_assembles_exactly_a_full_load():
    """Partial reconfiguration end state == full reconfiguration end
    state: the delta context's assembled slot must match, leaf for leaf,
    what a from-scratch full load of the same weights produces — while
    only the delta bytes cross the host->device link."""
    backbone = {"backbone": jnp.ones((64, 64)),
                "head": jnp.ones((64, 8)),
                "norm": {"w": jnp.full((64,), 0.5)}}
    delta = {"head": jnp.full((64, 8), 2.0),
             "norm": {"w": jnp.full((64,), 0.25)}}   # nested dicts merge
    full = {**backbone, **delta}

    eng = ContextSwitchEngine(num_slots=3)
    eng.register(ContextDescriptor(
        name="base", apply_fn=lambda p, x: x, weights_fn=lambda: backbone))
    eng.register(ContextDescriptor(
        name="spec", apply_fn=lambda p, x: x, weights_fn=lambda: delta,
        base="base"))
    eng.register(ContextDescriptor(
        name="spec-full", apply_fn=lambda p, x: x,
        weights_fn=lambda: full))
    eng.preload("base", block=True)
    b0 = eng.stats["bytes_loaded"]
    spec_slot = eng.preload("spec", block=True).result()
    delta_bytes = eng.stats["bytes_loaded"] - b0
    assert delta_bytes == sum(x.nbytes for x in jax.tree.leaves(delta))
    full_slot = eng.preload("spec-full", block=True).result()

    # identical structure and values; the untouched backbone tensor is the
    # base slot's device buffer (zero-copy on device)
    assert (jax.tree.structure(spec_slot.buffers)
            == jax.tree.structure(full_slot.buffers))
    for a, b in zip(jax.tree.leaves(spec_slot.buffers),
                    jax.tree.leaves(full_slot.buffers)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    base_slot = eng._find_slot("base")
    assert spec_slot.buffers["backbone"] is base_slot.buffers["backbone"]
    eng.shutdown()


def test_delta_load_requires_base_resident():
    from repro.core.context import ContextDescriptor
    eng = ContextSwitchEngine(num_slots=2)
    eng.register(ContextDescriptor(
        name="spec", apply_fn=lambda p, x: x,
        weights_fn=lambda: {"w": jnp.ones(2)}, base="missing"))
    fut = eng.preload("spec")
    with pytest.raises(Exception):
        fut.result(timeout=10)
    eng.shutdown()
