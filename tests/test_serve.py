"""Serving tier: generation loops and the context-switching server."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.serve.switching import ServedModel, SwitchableServer


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced_arch("tinyllama-1.1b")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def test_generate_shapes_and_determinism(tiny_lm):
    cfg, m, p = tiny_lm
    eng = ServingEngine(m, p, max_len=48, temperature=0.0)
    prompt = tokens_for(cfg, batch=2, seq=16)
    out1 = eng.generate(prompt, steps=8)
    out2 = eng.generate(prompt, steps=8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(out1, out2)       # greedy = deterministic
    assert eng.stats.tokens > 0


def test_generate_matches_fused(tiny_lm):
    cfg, m, p = tiny_lm
    eng = ServingEngine(m, p, max_len=48, temperature=0.0)
    prompt = tokens_for(cfg, batch=2, seq=16)
    host = eng.generate(prompt, steps=6)
    fused = np.asarray(eng.generate_fused(prompt, steps=6))
    np.testing.assert_array_equal(host, fused)


def test_switchable_server_round_robin():
    server = SwitchableServer(num_slots=2)
    cfgs = {}
    for i, name in enumerate(["supersub-super", "supersub-sub"]):
        cfg = reduced_arch(name)
        cfgs[name] = cfg
        m = build_model(cfg)
        p = m.init(jax.random.key(i))
        server.register(ServedModel(name=name, model=m,
                                    weights_fn=lambda p=p: p, max_len=40))
    outs = []
    for r in range(6):
        name = ["supersub-super", "supersub-sub"][r % 2]
        toks = np.asarray(tokens_for(cfgs[name], batch=2, seq=16, seed=r))
        outs.append(server.serve_batch(name, toks))
    assert len(outs) == 6
    stats = server.engine.stats
    assert stats["loads"] == 2                       # loaded once each
    assert stats["switches"] >= 6
    # O(1) switches: orders faster than loads
    assert (stats["switch_seconds"] / stats["switches"]) < \
        (stats["load_seconds"] / stats["loads"])
    server.shutdown()


def test_serve_stream_lookahead_equivalent():
    server = SwitchableServer(num_slots=2)
    name_cfg = {}
    for i, name in enumerate(["supersub-super", "supersub-sub"]):
        cfg = reduced_arch(name)
        name_cfg[name] = cfg
        m = build_model(cfg)
        p = m.init(jax.random.key(i))
        server.register(ServedModel(name=name, model=m,
                                    weights_fn=lambda p=p: p, max_len=40))
    reqs = [(n, np.asarray(tokens_for(name_cfg[n], 1, 16, seed=s)))
            for s, n in enumerate(["supersub-super", "supersub-sub",
                                   "supersub-super"])]
    with_la = server.serve_stream(reqs, lookahead=True)
    no_la = server.serve_stream(reqs, lookahead=False)
    for a, b in zip(with_la, no_la):
        np.testing.assert_array_equal(a, b)
    server.shutdown()


def test_run_schedule_live_conventional_slower():
    """Live engine: dynamic (overlapped) schedule beats conventional."""
    import time
    from repro.core.context import ContextDescriptor, ContextSwitchEngine
    from repro.core.scheduler import Run, run_schedule_live

    def desc(name, delay):
        def weights_fn():
            time.sleep(delay)
            return {"w": jnp.eye(512)}
        return ContextDescriptor(name=name,
                                 apply_fn=lambda p, x: jnp.tanh(x @ p["w"]),
                                 weights_fn=weights_fn)

    # execution long enough (repeat=40) for loads to hide behind it
    sched = [Run("a", 0.0, 40), Run("b", 0.0, 40),
             Run("a", 0.0, 40), Run("b", 0.0, 40)]
    inputs = {"a": (jnp.ones((2048, 512)),), "b": (jnp.ones((2048, 512)),)}
    # warm the backend so cold-start doesn't land in either branch's loads
    jnp.tanh(inputs["a"][0] @ jnp.eye(512)).block_until_ready()

    eng = ContextSwitchEngine(num_slots=2)
    eng.register(desc("a", 0.05))
    eng.register(desc("b", 0.05))
    dyn = run_schedule_live(eng, sched, inputs, dynamic=True)
    eng.shutdown()

    eng2 = ContextSwitchEngine(num_slots=2)
    eng2.register(desc("a", 0.05))
    eng2.register(desc("b", 0.05))
    conv = run_schedule_live(eng2, sched, inputs, dynamic=False)
    eng2.shutdown()
    # conventional pays a fresh 50 ms load on every net change (4 changes);
    # the dynamic engine pays at most the first two (cold) loads
    assert dyn["visible_stalls"] < conv["visible_stalls"]
    assert conv["visible_stalls"] > 0.15


def test_generate_paged_matches_dense():
    """Paged-cache serving loop == contiguous-cache loop, greedy.

    f32 end to end: in bf16 the two cache layouts reduce in different
    orders, and a random-weight model's near-flat logits let greedy
    argmax tie-break differently (the model-level paged test bounds the
    numeric gap at 5e-3)."""
    from repro.configs import override
    import jax.numpy as jnp
    cfg = override(reduced_arch("tinyllama-1.1b"), dtype="float32",
                   param_dtype="float32")
    m = build_model(cfg)
    m.cache_dtype = jnp.float32
    p = m.init(jax.random.key(0))
    eng = ServingEngine(m, p, max_len=64, temperature=0.0)
    prompt = tokens_for(cfg, batch=2, seq=16)
    dense = eng.generate(prompt, steps=20)
    paged = eng.generate_paged(prompt, steps=20, page=8)
    np.testing.assert_array_equal(dense, paged)
