"""Model-zoo behaviour: per-arch smoke, decode/prefill/train consistency,
family-specific form equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import patch_for, reduced_arch, tokens_for
from repro.configs import ASSIGNED_ARCHS, get_arch, override
from repro.models import xlstm as xl
from repro.models.model import build_model


# ---------------------------------------------------------------------------
# smoke: every assigned arch (reduced config) trains/forwards on CPU
# ---------------------------------------------------------------------------

def test_arch_forward_smoke(arch_name):
    cfg = reduced_arch(arch_name)
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    toks = tokens_for(cfg)
    logits, aux = m.forward(p, toks, patch_embeds=patch_for(cfg))
    n_patch = (cfg.frontend.num_positions
               if cfg.frontend.kind == "vision_patches" else 0)
    assert logits.shape == (2, 32 + n_patch, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    assert np.isfinite(float(aux))


def test_arch_train_step_smoke(arch_name):
    from repro.configs.base import RunConfig
    from repro.train.trainer import init_state, make_train_step
    cfg = reduced_arch(arch_name)
    m = build_model(cfg)
    rc = RunConfig(arch=cfg.name)
    state = init_state(m, jax.random.key(0), rc)
    step = jax.jit(make_train_step(m, rc))
    batch = {"tokens": tokens_for(cfg)}
    if cfg.frontend.kind == "vision_patches":
        batch["patch_embeds"] = patch_for(cfg)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually moved
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


# ---------------------------------------------------------------------------
# decode == forward (teacher forcing) for every family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["tinyllama-1.1b", "mixtral-8x7b",
                                  "xlstm-125m", "jamba-v0.1-52b",
                                  "musicgen-medium", "qwen3-moe-235b-a22b"])
def test_prefill_decode_matches_forward(name):
    cfg = override(reduced_arch(name), dtype="float32",
                   param_dtype="float32")
    m = build_model(cfg)
    m.cache_dtype = jnp.float32
    p = m.init(jax.random.key(0))
    S, S0 = 24, 16
    toks = tokens_for(cfg, batch=2, seq=S)
    full_logits, _ = m.forward(p, toks)

    logits0, caches = m.prefill(p, toks[:, :S0], max_len=S)
    np.testing.assert_allclose(np.asarray(logits0[:, -1]),
                               np.asarray(full_logits[:, S0 - 1]),
                               atol=2e-3, rtol=1e-3)
    logits = logits0
    for t in range(S0, S):
        logits, caches = m.decode_step(p, caches, toks[:, t:t + 1],
                                       jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, -1]),
                                   np.asarray(full_logits[:, t]),
                                   atol=5e-3, rtol=1e-2)


def test_sliding_window_decode_ring():
    """Mixtral-style SWA: ring cache beyond the window matches forward."""
    cfg = override(reduced_arch("mixtral-8x7b"), sliding_window=8,
                   dtype="float32", param_dtype="float32")
    m = build_model(cfg)
    m.cache_dtype = jnp.float32
    p = m.init(jax.random.key(0))
    S, S0 = 24, 4
    toks = tokens_for(cfg, batch=1, seq=S)
    full_logits, _ = m.forward(p, toks)
    logits, caches = m.prefill(p, toks[:, :S0], max_len=S)
    for t in range(S0, S):
        logits, caches = m.decode_step(p, caches, toks[:, t:t + 1],
                                       jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, -1]),
                                   np.asarray(full_logits[:, t]),
                                   atol=5e-3, rtol=1e-2)


# ---------------------------------------------------------------------------
# family-specific equivalences
# ---------------------------------------------------------------------------

def test_mlstm_three_forms_agree():
    B, H, L, dh = 2, 2, 64, 16
    ks = jax.random.split(jax.random.key(5), 5)
    q = jax.random.normal(ks[0], (B, H, L, dh))
    k = jax.random.normal(ks[1], (B, H, L, dh))
    v = jax.random.normal(ks[2], (B, H, L, dh))
    li = jax.random.normal(ks[3], (B, H, L)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, L)))
    h_par, fin_par = xl.mlstm_parallel(q, k, v, li, lf)
    h_rec, fin_rec = xl.mlstm_recurrent(q, k, v, li, lf)
    h_chk, fin_chk = xl.mlstm_chunkwise(q, k, v, li, lf, chunk=16)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_rec),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_rec),
                               atol=1e-4)
    for a, b in zip(fin_chk, fin_rec):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_mamba_scan_vs_associative():
    from repro.models.ssm import mamba_forward, ssm_specs
    from repro.models.common import init_params
    cfg = reduced_arch("jamba-v0.1-52b")
    specs = ssm_specs(cfg)
    p = init_params(jax.random.key(0), specs)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y1, s1 = mamba_forward(p, x, cfg, mode="scan")
    y2, s2 = mamba_forward(p, x, cfg, mode="assoc")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1.ssm), np.asarray(s2.ssm),
                               atol=1e-3, rtol=1e-3)


def test_moe_ref_vs_tp_strategy():
    from repro.models.moe import moe_dense_ref, moe_specs, moe_tp
    from repro.models.common import init_params
    cfg = reduced_arch("mixtral-8x7b")
    specs = moe_specs(cfg)
    p = init_params(jax.random.key(0), specs)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_ref, aux_ref = moe_dense_ref(p, x, cfg)
    y_tp, aux_tp = moe_tp(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_tp),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(aux_ref), float(aux_tp), rtol=1e-5)


def test_block_pattern_jamba_interleave():
    cfg = get_arch("jamba-v0.1-52b")
    from repro.models.model import block_pattern
    pat = block_pattern(cfg)
    assert len(pat) == 8
    assert sum(1 for m, _ in pat if m == "attn") == 1        # 1:7 interleave
    assert sum(1 for _, f in pat if f == "moe") == 4         # every other
    assert cfg.num_layers % len(pat) == 0


def test_block_pattern_xlstm():
    cfg = get_arch("xlstm-125m")
    from repro.models.model import block_pattern
    pat = block_pattern(cfg)
    assert len(pat) == 4
    assert sum(1 for m, _ in pat if m == "slstm") == 1


def test_param_count_analytic_close_to_specs():
    """Analytic count (roofline MODEL_FLOPS) vs actual spec count."""
    for name in ASSIGNED_ARCHS:
        cfg = get_arch(name)
        m = build_model(cfg)
        analytic = cfg.param_count()
        exact = m.n_params()
        assert abs(analytic - exact) / exact < 0.15, (name, analytic, exact)


def test_full_config_exactness():
    """Assignment numbers transcribed exactly."""
    c = get_arch("qwen3-moe-235b-a22b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == \
        (94, 4096, 64, 4)
    assert c.moe.num_experts == 128 and c.moe.top_k == 8
    assert c.vocab_size == 151_936 and c.moe.d_ff_expert == 1536
    c = get_arch("starcoder2-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (32, 4608, 36, 4, 18432, 49152)
    c = get_arch("pixtral-12b")
    assert (c.num_layers, c.d_model, c.vocab_size) == (40, 5120, 131072)
    c = get_arch("mixtral-8x7b")
    assert c.sliding_window == 4096 and c.moe.num_experts == 8
    c = get_arch("jamba-v0.1-52b")
    assert c.attn_every == 8 and c.moe.num_experts == 16 and c.moe.every == 2


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "jamba-v0.1-52b"])
def test_paged_decode_matches_dense(name):
    """vLLM-style paged decode: logits identical to the contiguous cache."""
    cfg = override(reduced_arch(name), dtype="float32",
                   param_dtype="float32")
    m = build_model(cfg)
    m.cache_dtype = jnp.float32
    p = m.init(jax.random.key(0))
    S, S0, page = 32, 8, 8          # S0 on a page boundary
    toks = tokens_for(cfg, batch=2, seq=S)
    full_logits, _ = m.forward(p, toks)

    # dense prefill, then convert the cache to pages
    _, caches = m.prefill(p, toks[:, :S0], max_len=S)
    bigs, acts = m.init_paged_cache(2, S, page=page)
    for key in list(bigs):
        if bigs[key] is None:                      # recurrent state block
            acts[key] = caches[key]
            continue
        k, v = caches[key].k, caches[key].v        # (R, B, Hkv, S, hd)
        R, B, Hkv, Smax, hd = k.shape
        from repro.models.layers import BigKV
        bigs[key] = BigKV(k=k.reshape(R, B, Hkv, Smax // page, page, hd),
                          v=v.reshape(R, B, Hkv, Smax // page, page, hd))

    from repro.models.layers import commit_page
    for t in range(S0, S):
        logits, acts = m.decode_step_paged(p, bigs, acts, toks[:, t:t + 1],
                                           jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, -1]),
                                   np.asarray(full_logits[:, t]),
                                   atol=5e-3, rtol=1e-2)
        if t % page == page - 1:                   # page filled: commit
            for key in list(bigs):
                if bigs[key] is not None:
                    bigs[key] = jax.vmap(commit_page, in_axes=(0, 0, None))(
                        bigs[key], acts[key], t)
