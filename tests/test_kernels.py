"""Per-kernel correctness sweeps: every Pallas kernel (interpret mode on CPU)
against its pure-jnp oracle over shapes x dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (
    decode_attention, decode_reference)
from repro.kernels.verify_attention.ops import (
    verify_attention, verify_reference)
from repro.kernels.flash_attention.ops import (
    attention_reference, flash_attention)
from repro.kernels.gmm.ops import (
    expert_mlp, expert_mlp_reference, gmm, gmm_reference)
from repro.kernels.mlstm_chunk.ops import (
    mlstm_chunk, mlstm_chunk_reference, mlstm_recurrent_reference)
from repro.kernels.ssm_scan.ops import (
    selective_scan, selective_scan_reference)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,S,hd", [
    (2, 4, 2, 128, 64),       # GQA
    (1, 8, 8, 256, 32),       # MHA
    (2, 4, 1, 96, 64),        # MQA + padding (96 % 64 != 0)
    (1, 2, 2, 64, 128),       # head_dim 128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(B, H, Hkv, S, hd, dtype):
    ks = jax.random.split(jax.random.key(S + hd), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = attention_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


@pytest.mark.parametrize("window", [16, 64, 128])
def test_flash_attention_sliding_window(window):
    B, H, Hkv, S, hd = 1, 4, 2, 256, 32
    ks = jax.random.split(jax.random.key(window), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    ref = attention_reference(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-3)


def test_flash_attention_block_shape_independence():
    """Numerical result must not depend on the BlockSpec tiling."""
    B, H, S, hd = 1, 2, 256, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,S,hd,pos,ring", [
    (2, 8, 2, 256, 64, 100, False),
    (1, 4, 4, 512, 32, 511, False),
    (2, 8, 2, 128, 64, 300, True),      # wrapped ring (SWA)
    (2, 8, 2, 128, 64, 60, True),       # unwrapped ring
    (1, 16, 1, 256, 64, 0, False),      # first token
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_oracle(B, H, Hkv, S, hd, pos, ring, dtype):
    ks = jax.random.split(jax.random.key(S + pos), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    out = decode_attention(q, k, v, pos, ring=ring, block_k=64)
    ref = decode_reference(q, k, v, pos, ring=ring)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


def test_decode_matches_flash_last_row():
    """Decoding the final position == last row of full flash attention."""
    B, H, S, hd = 1, 4, 128, 32
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    full = flash_attention(q, k, v, block_q=32, block_k=32)
    dec = decode_attention(q[:, :, -1], k, v, S - 1, block_k=32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1]),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# verify attention (multi-token speculative verify)
# ---------------------------------------------------------------------------

def _verify_inputs(B, H, Hkv, S, hd, K, dtype, seed):
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, K, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    bk = jax.random.normal(ks[3], (B, K, Hkv, hd), dtype)
    bv = jax.random.normal(ks[4], (B, K, Hkv, hd), dtype)
    return q, k, v, bk, bv


@pytest.mark.parametrize("B,H,Hkv,S,hd,K,ring,pos", [
    (2, 8, 2, 256, 64, 4, False, (100, 3)),    # per-row positions (GQA)
    (1, 4, 4, 128, 32, 5, False, (120,)),      # MHA, near the cache end
    (2, 8, 2, 64, 64, 4, True, (200, 30)),     # wrapped + unwrapped rows
    (2, 4, 2, 64, 32, 3, True, (62, 64)),      # ring wraps mid-block
    (1, 16, 1, 128, 64, 2, False, (1,)),       # single-token prompt
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_attention_matches_oracle(B, H, Hkv, S, hd, K, ring, pos,
                                         dtype):
    q, k, v, bk, bv = _verify_inputs(B, H, Hkv, S, hd, K, dtype, S + K)
    pos = jnp.asarray(pos, jnp.int32)
    out = verify_attention(q, k, v, bk, bv, pos, ring=ring, block_k=32)
    ref = verify_reference(q, k, v, bk, bv, pos, ring=ring)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


@pytest.mark.parametrize("S,ring,pos", [
    (128, False, (40, 3)),
    (64, True, (90, 30)),       # wrapped ring: the case write-then-mask
    (64, True, (63, 66)),       # formulations get wrong
])
def test_verify_reference_is_sequentially_exact(S, ring, pos):
    """The verify oracle == K iterations of the one-token decode oracle
    with the block's k/v written progressively — query i sees exactly the
    cache state the i-th sequential step would, including ring slots that
    later block tokens overwrite."""
    B, K, H, Hkv, hd = 2, 4, 4, 2, 32
    q, k, v, bk, bv = _verify_inputs(B, H, Hkv, S, hd, K, jnp.float32, 11)
    posv = np.asarray(pos, np.int32)
    ref = np.asarray(verify_reference(q, k, v, bk, bv,
                                      jnp.asarray(posv), ring=ring))
    kk, vv = np.array(k), np.array(v)
    for i in range(K):
        p = posv + i
        slot = p % S if ring else np.minimum(p, S - 1)
        for b in range(B):
            kk[b, :, slot[b]] = np.asarray(bk)[b, i]
            vv[b, :, slot[b]] = np.asarray(bv)[b, i]
        step = decode_reference(q[:, i], jnp.asarray(kk), jnp.asarray(vv),
                                jnp.asarray(p), ring=ring)
        np.testing.assert_allclose(ref[:, i], np.asarray(step), atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention (per-row page tables over one shared page pool)
# ---------------------------------------------------------------------------

def _paged_from_rows(k, v, page, seed, spare_pages=3):
    """Scatter a contiguous (B, Hkv, S, hd) row cache into a SHUFFLED
    shared page pool: non-contiguous, interleaved-across-rows tables are
    the case a paged kernel must get right.  Page 0 stays the park page;
    ``spare_pages`` extra pages hold garbage (never referenced)."""
    B, Hkv, S, hd = k.shape
    P = S // page
    NP = B * P + 1 + spare_pages
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.arange(1, NP))[:B * P]
    table = perm.reshape(B, P)
    kp = rng.normal(size=(NP, Hkv, page, hd)).astype(np.asarray(k).dtype)
    vp = rng.normal(size=(NP, Hkv, page, hd)).astype(np.asarray(v).dtype)
    for b in range(B):
        for j in range(P):
            kp[table[b, j]] = np.asarray(k[b, :, j * page:(j + 1) * page])
            vp[table[b, j]] = np.asarray(v[b, :, j * page:(j + 1) * page])
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(table, jnp.int32)


@pytest.mark.parametrize("B,H,Hkv,S,hd,page,pos", [
    (2, 8, 2, 256, 64, 64, (100, 255)),    # GQA, per-row positions
    (1, 4, 4, 512, 32, 128, 511),          # MHA, last position
    (3, 16, 1, 128, 64, 32, (0, 60, 127)),  # MQA, first token in the mix
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_matches_row_oracle(B, H, Hkv, S, hd, page,
                                                   pos, dtype):
    """Kernel AND paged ref against the contiguous-row oracle, through a
    shuffled non-contiguous page table."""
    from repro.kernels.paged_attention.ops import (
        paged_decode_attention, paged_decode_reference)
    ks = jax.random.split(jax.random.key(S + page), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    kp, vp, table = _paged_from_rows(k, v, page, seed=S)
    pos = jnp.asarray(pos, jnp.int32)
    ref = decode_reference(q, k, v, pos, ring=False)
    pref = paged_decode_reference(q, kp, vp, table, pos)
    np.testing.assert_allclose(np.asarray(pref, np.float32),
                               np.asarray(ref, np.float32), atol=1e-6)
    out = paged_decode_attention(q, kp, vp, table, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


@pytest.mark.parametrize("B,H,Hkv,S,hd,page,K,pos", [
    (2, 8, 2, 256, 64, 64, 4, (100, 3)),
    (1, 4, 2, 128, 32, 32, 5, 0),          # admission chunk at pos 0
    (2, 4, 4, 128, 64, 64, 3, (126, 40)),  # block reaches the row's end
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_verify_attention_matches_row_oracle(B, H, Hkv, S, hd, page,
                                                   K, pos, dtype):
    from repro.kernels.paged_attention.ops import (
        paged_verify_attention, paged_verify_reference)
    q, k, v, bk, bv = _verify_inputs(B, H, Hkv, S, hd, K, dtype, S + K)
    kp, vp, table = _paged_from_rows(k, v, page, seed=S + 1)
    pos = jnp.asarray(pos, jnp.int32)
    ref = verify_reference(q, k, v, bk, bv, pos, ring=False)
    pref = paged_verify_reference(q, kp, vp, bk, bv, table, pos)
    np.testing.assert_allclose(np.asarray(pref, np.float32),
                               np.asarray(ref, np.float32), atol=1e-6)
    out = paged_verify_attention(q, kp, vp, bk, bv, table, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,d_in,N", [
    (2, 64, 128, 16), (1, 128, 64, 8), (2, 96, 192, 16), (1, 256, 32, 4),
])
@pytest.mark.parametrize("with_init", [False, True])
def test_ssm_scan_matches_oracle(B, L, d_in, N, with_init):
    ks = jax.random.split(jax.random.key(L + d_in), 7)
    u = jax.random.normal(ks[0], (B, L, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, d_in)))
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    A = -jnp.exp(jax.random.normal(ks[4], (d_in, N)) * 0.5)
    D = jax.random.normal(ks[5], (d_in,))
    s0 = jax.random.normal(ks[6], (B, d_in, N)) if with_init else None
    y, s = selective_scan(u, dt, Bm, Cm, A, D, s0, block_d=64, block_l=32)
    yr, sr = selective_scan_reference(u, dt, Bm, Cm, A, D, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-4)


def test_ssm_scan_chunk_handoff():
    """Scanning [0:L] == scanning [0:L/2] then [L/2:L] with carried state."""
    B, L, d_in, N = 1, 64, 32, 8
    ks = jax.random.split(jax.random.key(11), 6)
    u = jax.random.normal(ks[0], (B, L, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, d_in)))
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    A = -jnp.exp(jax.random.normal(ks[4], (d_in, N)) * 0.5)
    D = jax.random.normal(ks[5], (d_in,))
    y_full, s_full = selective_scan(u, dt, Bm, Cm, A, D, block_l=16)
    h = L // 2
    y1, s1 = selective_scan(u[:, :h], dt[:, :h], Bm[:, :h], Cm[:, :h], A, D,
                            block_l=16)
    y2, s2 = selective_scan(u[:, h:], dt[:, h:], Bm[:, h:], Cm[:, h:], A, D,
                            s1, block_l=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


# ---------------------------------------------------------------------------
# chunkwise mLSTM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,L,dh,c", [
    (2, 2, 64, 32, 16), (1, 4, 128, 64, 32), (2, 1, 96, 48, 32),
])
def test_mlstm_chunk_matches_recurrent_oracle(B, H, L, dh, c):
    ks = jax.random.split(jax.random.key(L + dh), 5)
    q = jax.random.normal(ks[0], (B, H, L, dh))
    k = jax.random.normal(ks[1], (B, H, L, dh))
    v = jax.random.normal(ks[2], (B, H, L, dh))
    li = jax.random.normal(ks[3], (B, H, L)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, L)) + 1.0)
    h, (C, n, m) = mlstm_chunk(q, k, v, li, lf, chunk=c)
    hr, (Cr, nr, mr) = mlstm_recurrent_reference(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=5e-4)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cr), atol=5e-4)
    np.testing.assert_allclose(np.asarray(n), np.asarray(nr), atol=5e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), atol=1e-5)


def test_mlstm_chunk_matches_chunkwise_oracle():
    B, H, L, dh = 1, 2, 128, 32
    ks = jax.random.split(jax.random.key(3), 5)
    q = jax.random.normal(ks[0], (B, H, L, dh))
    k = jax.random.normal(ks[1], (B, H, L, dh))
    v = jax.random.normal(ks[2], (B, H, L, dh))
    li = jax.random.normal(ks[3], (B, H, L)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, L)))
    h, _ = mlstm_chunk(q, k, v, li, lf, chunk=32)
    hr, _ = mlstm_chunk_reference(q, k, v, li, lf, 32)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-4)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,D,F", [
    (4, 64, 128, 256), (2, 128, 64, 96), (8, 32, 32, 64), (1, 16, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_matches_oracle(E, C, D, F, dtype):
    ks = jax.random.split(jax.random.key(E + C), 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    out = gmm(x, w, block_c=32, block_f=32, block_d=32)
    ref = gmm_reference(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=(0.5 if dtype == jnp.bfloat16 else 1e-4))


def test_expert_mlp_matches_oracle():
    E, C, D, F = 4, 32, 64, 128
    ks = jax.random.split(jax.random.key(9), 4)
    x = jax.random.normal(ks[0], (E, C, D))
    wg = jax.random.normal(ks[1], (E, D, F)) / 8
    wu = jax.random.normal(ks[2], (E, D, F)) / 8
    wd = jax.random.normal(ks[3], (E, F, D)) / 8
    out = expert_mlp(x, wg, wu, wd, block_c=16, block_f=32, block_d=32)
    ref = expert_mlp_reference(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# end-to-end: whole models with kernels in interpret mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["tinyllama-1.1b", "mixtral-8x7b",
                                  "xlstm-125m", "jamba-v0.1-52b"])
def test_model_forward_kernel_vs_reference(name):
    import repro.kernels as kernels
    from repro.configs import get_arch, override, reduced
    from repro.models.model import build_model
    cfg = override(reduced(get_arch(name)), dtype="float32")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    try:
        kernels.set_mode("off")
        l0, _ = m.forward(p, toks)
        kernels.set_mode("interpret")
        l1, _ = m.forward(p, toks)
    finally:
        kernels.set_mode("off")
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=5e-4,
                               rtol=1e-3)
