"""Paged slot pool: per-slot page tables over one shared KV page pool.

Covers the PagePool allocator contract (FIFO recycling, failed-admit
restore, leak freedom under randomized traffic), the token-identity
matrix against the row engine (greedy + seeded temperature, page sizes
{64, 256}, one-shot + chunked admission), page-granular chunk writes
(transferred-bytes check), the short-prompt admission priority with its
fairness bound, and the scheduler end to end with ``paged=True``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.engine import StepEngine
from repro.serve.pool import PagePool


@pytest.fixture(scope="module")
def f32_lm():
    """f32 end to end: the paged identity tests assert BITWISE equality
    of token streams between two cache layouts, which needs the gathered
    page view to reproduce the row math exactly (it does — same shapes,
    same masked reductions — but only in a dtype where the intermediate
    values are the same numbers)."""
    cfg = reduced_arch("tinyllama-1.1b", dtype="float32",
                       param_dtype="float32")
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(0))


def _drain(eng, p):
    while eng.live_slots():
        eng.step(p)


# ---------------------------------------------------------------------------
# PagePool allocator contract
# ---------------------------------------------------------------------------

def test_page_pool_fifo_contract():
    pool = PagePool(8)                     # page 0 = park, 7 allocatable
    assert pool.allocatable == 7
    assert pool.free_pages() == 7
    a = pool.take(3)
    assert a == [1, 2, 3]                  # front of the free-list
    b = pool.take(2)
    assert b == [4, 5]
    pool.release(a)                        # retirement: to the BACK
    assert pool.take(2) == [6, 7]          # older frees go out first...
    assert pool.take(3) == [1, 2, 3]       # ...then the recycled pages
    with pytest.raises(RuntimeError):
        pool.take(3)                       # only b's 2 pages remain free
    pool.restore(b)                        # failed admit: FRONT, in order
    assert pool.take(2) == b
    assert pool.free_pages() == 0


def test_page_pool_guards():
    with pytest.raises(ValueError):
        PagePool(1)                        # park page alone is no pool
    pool = PagePool(4)
    pool.take(3)
    pool.reset()
    assert pool.free_pages() == 3


def test_paged_engine_guards(f32_lm):
    cfg, m, p = f32_lm
    hybrid = build_model(reduced_arch("jamba-v0.1-52b"))
    with pytest.raises(ValueError, match="all-attention"):
        StepEngine(hybrid, batch_size=2, max_len=64, paged=True)
    windowed = build_model(reduced_arch("tinyllama-1.1b",
                                        sliding_window=16))
    with pytest.raises(ValueError, match="non-ring"):
        StepEngine(windowed, batch_size=2, max_len=64, paged=True)
    with pytest.raises(ValueError, match="divide"):
        StepEngine(m, batch_size=2, max_len=96, paged=True, page_size=64)
    with pytest.raises(ValueError, match="worst-case"):
        StepEngine(m, batch_size=2, max_len=64, paged=True, page_size=16,
                   num_pages=3)            # one row needs 4 pages + park


# ---------------------------------------------------------------------------
# token-identity matrix: paged engine vs row engine
# ---------------------------------------------------------------------------

def _run_stream(eng, p, prompts, steps, seeds):
    """Admit request 0, step twice, admit request 1 (staggered admission:
    rows sit at different positions), drain.  Returns token lists."""
    gens = [eng.admit(p, prompts[0], max_new=steps, seeds=[seeds[0]])[0]]
    for _ in range(2):
        eng.step(p)
    gens.append(eng.admit(p, prompts[1], max_new=steps,
                          seeds=[seeds[1]])[0])
    _drain(eng, p)
    return [g.tokens for g in gens]


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("page", [64, 256])
@pytest.mark.parametrize("chunk", [None, 8])
def test_paged_streams_bitwise_identical_to_row(f32_lm, temperature, page,
                                                chunk):
    """The full matrix: page sizes {64, 256} x {greedy, seeded
    temperature} x {one-shot, chunked} admission — every combination
    emits bitwise the row engine's token streams.  Sampling never sees
    the cache layout; the gathered page view reproduces the row
    attention math exactly (masked garbage contributes exact zeros)."""
    cfg, m, p = f32_lm
    max_len, steps = 256, 5
    prompts = [np.asarray(tokens_for(cfg, 1, 12, seed=3)),
               np.asarray(tokens_for(cfg, 1, 40, seed=4))]
    seeds = [7, 9] if temperature > 0 else [None, None]

    row = StepEngine(m, batch_size=2, max_len=max_len,
                     temperature=temperature)
    ref = _run_stream(row, p, prompts, steps, seeds)

    eng = StepEngine(m, batch_size=2, max_len=max_len,
                     temperature=temperature, paged=True, page_size=page,
                     prefill_chunk=chunk)
    got = _run_stream(eng, p, prompts, steps, seeds)
    assert got == ref
    assert eng.free_pages() == eng._pages.allocatable   # all returned
    assert eng.free_slots() == 2


def test_inserted_pages_match_row_prefill_leaf_for_leaf(f32_lm):
    """Admission writes the SAME cache values, page-scattered: gathering
    a row's pages back through its table equals the row engine's cache
    row leaf-for-leaf over the row's whole allocation (prompt + zero
    tail — whole pages are written)."""
    from repro.models.layers import _gather_pages
    cfg, m, p = f32_lm
    max_len, page, S, steps = 256, 64, 12, 5
    prompt = np.asarray(tokens_for(cfg, 1, S, seed=3))

    row = StepEngine(m, batch_size=2, max_len=max_len)
    gr = row.admit(p, prompt, max_new=steps)[0]
    eng = StepEngine(m, batch_size=2, max_len=max_len, paged=True,
                     page_size=page)
    gp = eng.admit(p, prompt, max_new=steps)[0]
    npages = eng.pages_needed(S, steps)
    assert gp.pages is not None and len(gp.pages) == npages

    table = np.asarray(eng.state.table)[gp.slot]
    assert list(table[:npages]) == gp.pages
    span = npages * page
    for key in eng.state.caches:
        paged, rowc = eng.state.caches[key], row.state.caches[key]
        for pa, ra in ((paged.k, rowc.k), (paged.v, rowc.v)):
            g = jax.vmap(_gather_pages, in_axes=(0, None))(
                pa, jnp.asarray(table)[None])      # (R, 1, Hkv, 256, hd)
            np.testing.assert_array_equal(
                np.asarray(g[:, 0, :, :span]),
                np.asarray(ra[:, gr.slot, :, :span]))


# ---------------------------------------------------------------------------
# leak / fragmentation under randomized traffic
# ---------------------------------------------------------------------------

def _random_traffic(eng, m, p, cfg, rounds, seed):
    """Randomized admit/step/fail/retire churn; returns the emitted
    streams (determinism probe).  Failed admissions (params=None) must
    restore slots AND pages."""
    rng = np.random.default_rng(seed)
    streams = []
    for r in range(rounds):
        action = rng.integers(0, 4)
        S = int(rng.integers(4, 30))
        steps = int(rng.integers(1, 10))
        toks = rng.integers(0, cfg.vocab_size, (1, S))
        if action == 0 and eng.can_admit(toks, steps):
            g = eng.admit(p, toks, max_new=steps)[0]
            streams.append(g.tokens)       # list reference: fills later
        elif action == 1:
            before = (list(eng._free), list(eng._pages._free))
            with pytest.raises(BaseException):
                eng.admit(None, toks, max_new=steps)
            assert (list(eng._free), list(eng._pages._free)) == before
        else:
            eng.step(p)
    _drain(eng, p)
    return streams


def test_failed_multirow_chunk_restores_pages_in_take_order(f32_lm):
    """A failed chunk abandons the whole multi-row request; its pages go
    back to the FRONT of the free-list in their original take order
    (one restore call, not one per row — the retry must draw exactly
    what the failed admission drew)."""
    cfg, m, p = f32_lm
    eng = StepEngine(m, batch_size=4, max_len=64, paged=True, page_size=16,
                     prefill_chunk=4)
    slot_order = list(eng._free)
    page_order = list(eng._pages._free)
    eng.admit(p, np.asarray(tokens_for(cfg, 2, 20, seed=3)), max_new=10)
    with pytest.raises(BaseException):
        eng.prefill_tick(None)             # params=None: chunk fails
    assert list(eng._free) == slot_order
    assert list(eng._pages._free) == page_order


def test_generate_paged_falls_back_for_unsupported_models():
    """Models the page pool cannot express (hybrid/recurrent mixers)
    keep working through generate_paged — row-engine fallback, same
    output contract as generate()."""
    from repro.serve.engine import ServingEngine
    cfg = reduced_arch("jamba-v0.1-52b")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    eng = ServingEngine(m, p, max_len=48)
    prompt = np.asarray(tokens_for(cfg, 2, 8))
    np.testing.assert_array_equal(eng.generate_paged(prompt, steps=4),
                                  eng.generate(prompt, steps=4))


def test_page_pool_batched_release_under_multistep(f32_lm):
    """A fused multi-step tick can retire SEVERAL slots in one host call
    — one ``_retire_done`` batch, several page releases back to back.
    The batch must land on the BACK of the free-list row-by-row in slot
    order (exactly what that tick's single-step equivalent does), and
    the randomized churn invariants — ``free_pages == allocatable``,
    deterministic replay of streams and free-list order — hold under
    fused ticks too."""
    cfg, m, p = f32_lm
    # deterministic batch retire: 3 equal-budget rows finish on the SAME
    # fused tick
    eng = StepEngine(m, batch_size=4, max_len=64, paged=True,
                     page_size=16, num_pages=13, seed=5, multi_step=8)
    free0 = list(eng._pages._free)
    gens = [eng.admit(p, np.asarray(tokens_for(cfg, 1, 8, seed=s)),
                      max_new=4)[0] for s in (1, 2, 3)]
    owned = [g.pages[:] for g in gens]     # 1 page each (8+4-1 < 16)
    finished = eng.step(p)                 # the 3 remaining tokens ...
    assert sorted(g.rid for g in finished) == sorted(g.rid for g in gens)
    assert eng.stats["host_ticks"] == 1    # ... in ONE fused tick
    assert eng.stats["device_steps"] == 3
    assert eng.free_pages() == eng._pages.allocatable
    # FIFO after a batched release: survivors first, then the batch's
    # pages in slot order
    assert list(eng._pages._free) == \
        free0[3:] + owned[0] + owned[1] + owned[2]

    final = []
    for attempt in range(2):               # randomized churn, replayed
        e2 = StepEngine(m, batch_size=4, max_len=64, paged=True,
                        page_size=16, num_pages=10, seed=5, multi_step=4)
        streams = _random_traffic(e2, m, p, cfg, rounds=40, seed=123)
        assert e2.free_slots() == 4
        assert e2.free_pages() == e2._pages.allocatable == 9
        final.append((streams, list(e2._pages._free)))
    assert final[0] == final[1]


def test_page_pool_no_leak_no_fragmentation(f32_lm):
    """N rounds of randomized admit/retire/fail traffic end with every
    page back on the free-list (free_pages == allocatable) and every
    slot free — nothing leaks through failures, instant retires, or
    EOS-free drains.  The same traffic replayed is bit-identical
    (streams AND final free-list order): FIFO recycling makes the
    allocator deterministic."""
    cfg, m, p = f32_lm
    final = []
    for attempt in range(2):
        eng = StepEngine(m, batch_size=4, max_len=64, paged=True,
                         page_size=16, num_pages=10, seed=5)
        streams = _random_traffic(eng, m, p, cfg, rounds=40, seed=123)
        assert eng.free_slots() == 4
        assert eng.free_pages() == eng._pages.allocatable == 9
        final.append((streams, list(eng._pages._free)))
    assert final[0] == final[1]            # deterministic recycling


# ---------------------------------------------------------------------------
# page-granular chunk writes: O(C) moved bytes, not O(max_len)
# ---------------------------------------------------------------------------

def _scatter_update_bytes(jaxpr, scale=1):
    """Sum the bytes of every scatter / dynamic-update-slice UPDATE
    operand in a (closed) jaxpr, recursing into inner jaxprs and
    multiplying by scan trip counts — i.e. the bytes a program actually
    MOVES into its state buffers, which buffer-level cost analysis hides
    behind whole-buffer scatter accounting."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name.startswith("scatter"):
            upd = eqn.invars[2].aval       # (operand, indices, updates)
            total += scale * upd.size * upd.dtype.itemsize
        elif name == "dynamic_update_slice":
            upd = eqn.invars[1].aval
            total += scale * upd.size * upd.dtype.itemsize
        inner_scale = scale * eqn.params.get("length", 1) \
            if name == "scan" else scale
        for v in eqn.params.values():
            for j in (v if isinstance(v, (list, tuple)) else [v]):
                if hasattr(j, "eqns") or hasattr(j, "jaxpr"):
                    total += _scatter_update_bytes(j, inner_scale)
    return total


def _chunk_update_bytes(eng, p):
    C = eng.prefill_chunk
    b = 1
    args = (p, eng.state, jnp.zeros((b, C), jnp.int32),
            jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32),
            jnp.zeros((b, eng.pages_per_row), jnp.int32))
    jaxpr = jax.make_jaxpr(lambda *a: eng._chunk_fn(*a))(*args)
    return _scatter_update_bytes(jaxpr)


def test_chunk_scatter_is_page_granular(f32_lm):
    """Transferred-bytes check for page-granular chunk writes: the
    row-layout chunk program re-scatters WHOLE (R, b, max_len) cache
    rows per chunk — O(max_len) moved bytes regardless of C — while the
    paged program scatters only the chunk's (pos, pos+C) positions into
    the row's pages: O(C), independent of max_len."""
    cfg, m, p = f32_lm
    C = 8
    got = {}
    for max_len in (256, 512):
        row = StepEngine(m, batch_size=2, max_len=max_len,
                         prefill_chunk=C)
        paged = StepEngine(m, batch_size=2, max_len=max_len, paged=True,
                           page_size=64, prefill_chunk=C)
        got[max_len] = (_chunk_update_bytes(row, p),
                        _chunk_update_bytes(paged, p))
        row_b, paged_b = got[max_len]
        assert paged_b * 4 < row_b, (max_len, paged_b, row_b)
    # O(max_len) vs O(C): doubling max_len ~doubles the row program's
    # moved bytes and leaves the paged program's unchanged
    assert got[512][0] > 1.8 * got[256][0]
    assert got[512][1] == got[256][1]


# ---------------------------------------------------------------------------
# admission priority: short prompts jump queued chunk work, fairly
# ---------------------------------------------------------------------------

def test_short_prompt_jumps_long_chunk_stream(f32_lm):
    """With a long prompt mid-stream, a later-admitted single-chunk
    prompt is prefilled first: its first token arrives while the long
    prompt is still streaming, and both streams stay correct (greedy:
    identical to their solo runs)."""
    cfg, m, p = f32_lm
    C = 4
    long_p = np.asarray(tokens_for(cfg, 1, 30, seed=5))
    short_p = np.asarray(tokens_for(cfg, 1, 3, seed=6))

    def solo(prompt, steps):
        e = StepEngine(m, batch_size=2, max_len=64)
        g = e.admit(p, prompt, max_new=steps)[0]
        _drain(e, p)
        return g.tokens

    ref_long, ref_short = solo(long_p, 5), solo(short_p, 5)
    eng = StepEngine(m, batch_size=2, max_len=64, prefill_chunk=C)
    gl = eng.admit(p, long_p, max_new=5)[0]
    eng.prefill_tick(p)                    # long starts streaming
    gs = eng.admit(p, short_p, max_new=5)[0]
    eng.prefill_tick(p)                    # priority: short's final chunk
    assert len(gs.tokens) == 1             # short sampled its first token
    assert len(gl.tokens) == 0             # long still mid-prefill
    _drain(eng, p)
    assert gl.tokens == ref_long and gs.tokens == ref_short


def test_admission_priority_fairness_bound(f32_lm):
    """A stream of shorts cannot starve the long prompt: after
    ``admit_jump_limit`` consecutive jumps the long head MUST run a
    chunk.  Feed a fresh short every tick and assert the long's
    streaming still progresses at >= 1/(limit+1) chunks per tick."""
    cfg, m, p = f32_lm
    C, limit = 4, 2
    eng = StepEngine(m, batch_size=8, max_len=64, prefill_chunk=C,
                     admit_jump_limit=limit)
    gl = eng.admit(p, np.asarray(tokens_for(cfg, 1, 24, seed=5)),
                   max_new=2)[0]           # 6 chunks of streaming
    ticks = 0
    while len(gl.tokens) == 0:             # until the long's final chunk
        if eng.free_slots():
            eng.admit(p, np.asarray(tokens_for(cfg, 1, 3, seed=ticks)),
                      max_new=1)           # short: retires instantly
        eng.prefill_tick(p)
        ticks += 1
        assert ticks <= 6 * (limit + 1) + 1, "long prompt starved"
    assert ticks > 6                       # some shorts did jump ahead

    strict = StepEngine(m, batch_size=8, max_len=64, prefill_chunk=C,
                        admit_jump_limit=0)
    gl = strict.admit(p, np.asarray(tokens_for(cfg, 1, 24, seed=5)),
                      max_new=2)[0]
    strict.admit(p, np.asarray(tokens_for(cfg, 1, 3, seed=7)), max_new=1)
    for _ in range(6):
        strict.prefill_tick(p)             # strict FIFO: long first
    assert len(gl.tokens) == 1


# ---------------------------------------------------------------------------
# density: the same memory admits more concurrent short requests
# ---------------------------------------------------------------------------

def test_paged_pool_outconcurrents_row_pool_at_equal_memory(f32_lm):
    """The tradeoff the refactor breaks: a row pool with B slots serves
    at most B requests no matter how short they are; a paged pool with
    the SAME token capacity (B * max_len) serves one request per
    ~pages_needed."""
    cfg, m, p = f32_lm
    B_row, max_len, page = 2, 64, 16
    toks = np.asarray(tokens_for(cfg, 1, 8, seed=1))

    row = StepEngine(m, batch_size=B_row, max_len=max_len)
    n_row = 0
    while row.can_admit(toks, 7):
        row.admit(p, toks, max_new=7)
        n_row += 1
    # equal memory: B_row * max_len tokens = 8 pages (+1 park)
    eng = StepEngine(m, batch_size=8, max_len=max_len, paged=True,
                     page_size=page, num_pages=B_row * max_len // page + 1)
    n_paged = 0
    while eng.can_admit(toks, 7):          # 8+7-1 = 14 -> 1 page each
        eng.admit(p, toks, max_new=7)
        n_paged += 1
    assert n_row == B_row
    assert n_paged >= 2 * n_row
    _drain(row, p)
    _drain(eng, p)
    assert eng.free_pages() == eng._pages.allocatable


# ---------------------------------------------------------------------------
# scheduler end to end
# ---------------------------------------------------------------------------

def test_continuous_scheduler_paged():
    """ContinuousScheduler(paged=True): mixed-context, mixed-length
    greedy traffic through paged pools produces the run-to-completion
    reference outputs, and every context's pages drain back."""
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    names = ["supersub-super", "supersub-sub"]
    server, cfgs = build_server(names, 2, 64, load_delay_s=0.01,
                                arch_overrides={"dtype": "float32",
                                                "param_dtype": "float32"})
    rng = np.random.default_rng(0)
    reqs = [(names[r % 2],
             rng.integers(0, cfgs[names[r % 2]].vocab_size,
                          (2, [8, 40, 16][r % 3])))
            for r in range(6)]
    with ContinuousScheduler(server, batch_size=4, paged=True,
                             page_size=16) as sched:
        futs = [sched.submit(n, t, steps=4) for n, t in reqs]
        outs = [f.result(timeout=300) for f in futs]
    assert all(o.shape == (2, 4) for o in outs)
    for (name, toks), out in zip(reqs, outs):
        ref = server.serve_batch(name, toks, steps=4)
        np.testing.assert_array_equal(out, ref)
    for key, eng in server._step_engines.items():
        assert key.page_size == 16 and eng.paged
        assert eng.free_pages() == eng._pages.allocatable
    server.shutdown()
