"""Device-resident multi-step decode: ``StepEngine(multi_step=T)`` runs
up to T decode steps in ONE jitted device loop per tick.

The contract under test: the fused loop commits EXACTLY the device-step
sequence T iterated single steps would — bitwise-identical token
streams (greedy + seeded temperature, row + paged engines), retirement
at the same step boundaries (the on-device EOS / token-budget bitmaps
early-exit the loop the moment any slot would change occupancy), and
the host tick count amortized by up to T.  Bitwise comparisons run in
f32 end to end, same reason as the paged identity matrix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_arch, tokens_for
from repro.models.model import build_model
from repro.serve.engine import StepEngine


@pytest.fixture(scope="module")
def f32_lm():
    cfg = reduced_arch("tinyllama-1.1b", dtype="float32",
                       param_dtype="float32")
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(0))


def _drain(eng, p):
    while eng.live_slots():
        eng.step(p)


def _engine(m, multi_step, paged, temperature=0.0, **kw):
    return StepEngine(m, batch_size=3, max_len=64, temperature=temperature,
                      seed=5, paged=paged, page_size=16,
                      multi_step=multi_step, **kw)


def _mixed_stream(eng, p, cfg, temperature):
    """Admit A (short budget) + B at t=0, drain until A's retirement
    early-exits the loop, admit C at that boundary, drain.  Admissions
    land at identical device-step counts in the single-step and fused
    engines BECAUSE retirement early-exits the fused loop — which is the
    occupancy-change contract itself."""
    seeds = [7, 9, 11] if temperature > 0 else [None, None, None]
    ga = eng.admit(p, np.asarray(tokens_for(cfg, 1, 8, seed=1)),
                   max_new=3, seeds=[seeds[0]])[0]
    gb = eng.admit(p, np.asarray(tokens_for(cfg, 1, 20, seed=2)),
                   max_new=9, seeds=[seeds[1]])[0]
    while not ga.done:                     # A retires at device step 2
        eng.step(p)
    gc = eng.admit(p, np.asarray(tokens_for(cfg, 1, 12, seed=3)),
                   max_new=5, seeds=[seeds[2]])[0]
    _drain(eng, p)
    return [g.tokens for g in (ga, gb, gc)]


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("paged", [False, True])
def test_multistep_streams_bitwise_identical(f32_lm, temperature, paged):
    """multi_step=4 == 4 iterated single steps, bitwise: greedy and
    seeded temperature, row and paged pools, with a mid-stream admission
    at a retirement boundary (the early-exit keeps the two engines'
    admission keys and positions in lockstep)."""
    cfg, m, p = f32_lm
    ref_eng = _engine(m, 1, paged, temperature)
    ref = _mixed_stream(ref_eng, p, cfg, temperature)
    eng = _engine(m, 4, paged, temperature)
    got = _mixed_stream(eng, p, cfg, temperature)
    assert got == ref
    # the same device steps were committed — in fewer host ticks
    assert eng.stats["device_steps"] == ref_eng.stats["device_steps"]
    assert eng.stats["host_ticks"] < ref_eng.stats["host_ticks"]
    if paged:
        assert eng.free_pages() == eng._pages.allocatable


@pytest.mark.parametrize("paged", [False, True])
def test_multistep_mid_loop_eos_retire(f32_lm, paged):
    """A row hitting EOS inside the fused loop exits the loop AT that
    step: the stream stops exactly where the single-step engine stops,
    the slot frees, and the co-resident row's tokens are untouched."""
    cfg, m, p = f32_lm
    prompt = np.asarray(tokens_for(cfg, 1, 8, seed=1))
    probe = _engine(m, 1, paged)
    g = probe.admit(p, prompt, max_new=8)[0]
    _drain(probe, p)
    eos = g.tokens[2]                      # greedy: this token becomes EOS
    cut = g.tokens[:g.tokens.index(eos) + 1]   # stream up to FIRST hit
    assert 1 < len(cut) < len(g.tokens)    # mid-loop for T=8, mid-stream

    runs = []
    for T in (1, 8):
        eng = _engine(m, T, paged, eos_id=eos)
        ge = eng.admit(p, prompt, max_new=8)[0]
        gn = eng.admit(p, np.asarray(tokens_for(cfg, 1, 12, seed=2)),
                       max_new=8)[0]
        _drain(eng, p)
        assert ge.done and ge.tokens == cut   # retired AT the EOS step
        assert eng.free_slots() == 3
        runs.append((ge.tokens, gn.tokens, eng.stats["device_steps"]))
    assert runs[0] == runs[1]              # streams AND step count


def test_multistep_amortizes_host_ticks(f32_lm):
    """Steady state (no retirement in sight): one host tick per T
    committed steps — 16 decode steps in exactly ceil(16/8)=2 ticks."""
    cfg, m, p = f32_lm
    eng = _engine(m, 8, False)
    eng.admit(p, np.asarray(tokens_for(cfg, 3, 8)), max_new=17)
    _drain(eng, p)
    assert eng.stats["device_steps"] == 16
    assert eng.stats["host_ticks"] == 2


def test_multistep_single_steps_while_prefill_pending(f32_lm):
    """Chunked-prefill interaction: while a prompt is streaming chunks
    the engine drops to single decode steps (the streaming prompt keeps
    its one-chunk-per-tick admission latency); fused ticks resume once
    the queue drains.  Streams stay bitwise equal to the single-step
    engine driven tick-for-tick."""
    cfg, m, p = f32_lm

    def run(T):
        eng = _engine(m, T, False, prefill_chunk=4)
        ga = eng.admit(p, np.asarray(tokens_for(cfg, 1, 12, seed=1)),
                       max_new=8)[0]
        for _ in range(3):                 # 2 stream chunks + final
            eng.step(p)
        assert not eng._pending and ga.tokens   # A live, queue drained
        gb = eng.admit(p, np.asarray(tokens_for(cfg, 1, 20, seed=2)),
                       max_new=6)[0]
        d0 = eng.stats["device_steps"]
        eng.step(p)                        # B pending -> exactly 1 step
        assert eng.stats["device_steps"] == d0 + 1
        _drain(eng, p)
        return [ga.tokens, gb.tokens]

    # Per-row greedy streams don't depend on tick alignment (attention is
    # per-row, the pool program is fixed-shape), so even though T=4 fuses
    # A's early steps before B arrives, the streams must match exactly.
    assert run(4) == run(1)


def test_multistep_guards(f32_lm):
    cfg, m, p = f32_lm
    with pytest.raises(ValueError, match="multi_step"):
        StepEngine(m, batch_size=2, max_len=64, multi_step=0)


def test_continuous_scheduler_multistep():
    """ContinuousScheduler(multi_step=4) end to end: greedy outputs
    equal the run-to-completion server reference, and the snapshot
    reports the realized amortization (steps_per_tick > 1)."""
    from repro.launch.serve import build_server
    from repro.serve.scheduler import ContinuousScheduler

    names = ["supersub-super", "supersub-sub"]
    server, cfgs = build_server(names, 2, 32, load_delay_s=0.01,
                                arch_overrides={"dtype": "float32",
                                                "param_dtype": "float32"})
    rng = np.random.default_rng(0)
    reqs = [(names[r % 2],
             rng.integers(0, cfgs[names[r % 2]].vocab_size, (2, 12)))
            for r in range(4)]
    with ContinuousScheduler(server, batch_size=2,
                             multi_step=4) as sched:
        futs = [sched.submit(n, t, steps=8) for n, t in reqs]
        outs = [f.result(timeout=300) for f in futs]
    snap = sched.snapshot()
    assert snap["device_steps"] > snap["host_ticks"]
    assert snap["steps_per_tick"] > 1.0
    for (name, toks), out in zip(reqs, outs):
        ref = server.serve_batch(name, toks, steps=8)
        np.testing.assert_array_equal(out, ref)
    server.shutdown()
