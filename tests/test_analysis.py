"""Analysis-layer units: HLO collective parser, roofline terms, kernel
cost model."""
import pytest

from repro.analysis.hlo import collective_bytes, parse_collectives
from repro.analysis.kernelcost import flash_attention_cost
from repro.analysis.roofline import (
    model_flops, roofline_terms, utilization)
from repro.configs import SHAPES, get_arch


HLO = """
ENTRY %main {
  %ag = bf16[128,4096]{1,0} all-gather(%p0), replica_groups={{0,1}}
  %ar = f32[1024]{0} all-reduce(%p1), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%big), dimensions={0}
  %a2a = bf16[16,256]{1,0} all-to-all(%p2), dimensions={0}
  %cp = f32[8]{0} collective-permute(%p3), source_target_pairs={{0,1}}
  %ags = bf16[2,2]{1,0} all-gather-start(%p4), replica_groups={{0,1}}
}
"""


def test_parse_collectives_counts_and_kinds():
    per = parse_collectives(HLO)
    assert per["all-gather"]["count"] == 2        # incl. the -start form
    assert per["all-reduce"]["count"] == 1
    assert per["reduce-scatter"]["count"] == 1
    assert per["all-to-all"]["count"] == 1
    assert per["collective-permute"]["count"] == 1


def test_collective_moved_bytes_model():
    per = parse_collectives(HLO)
    # all-gather moved ~= result bytes
    assert per["all-gather"]["moved_bytes"] >= 128 * 4096 * 2
    # all-reduce moved ~= 2x payload (ring reduce-scatter + all-gather)
    assert per["all-reduce"]["moved_bytes"] == pytest.approx(2 * 1024 * 4)
    total, _ = collective_bytes(HLO)
    assert total > 0


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 100e9, 1e9)        # 1s compute, tiny rest
    assert t["dominant"] == "compute"
    assert t["roofline_fraction"] == pytest.approx(1.0)
    t = roofline_terms(1e12, 819e9 * 2, 1e9)      # 2s memory
    assert t["dominant"] == "memory"
    assert t["bound_s"] == pytest.approx(2.0)
    t = roofline_terms(1e12, 1e9, 50e9 * 3)       # 3s collective
    assert t["dominant"] == "collective"


def test_model_flops_train_vs_serve():
    assert model_flops(1e9, 1e6, training=True) == 6e15
    assert model_flops(1e9, 1e6, training=False) == 2e15
    assert utilization(6e15, 6e15 / 256, 256) == pytest.approx(1.0)


def test_flash_cost_monotonic_and_windowed():
    cfg = get_arch("deepseek-7b")
    tr = flash_attention_cost(cfg, SHAPES["train_4k"], 256, training=True)
    pf = flash_attention_cost(cfg, SHAPES["train_4k"], 256, training=False)
    assert tr["flops"] > pf["flops"]              # bwd + remat
    assert tr["bytes"] > pf["bytes"]
    # sliding window bounds the score work
    mx = get_arch("mixtral-8x7b")                 # window 4096
    full = flash_attention_cost(cfg, SHAPES["prefill_32k"], 256,
                                training=False)
    win = flash_attention_cost(mx, SHAPES["prefill_32k"], 256,
                               training=False)
    # mixtral's windowed fraction: 4096/32768 vs causal 0.5
    assert win["flops"] / win["bytes"] < full["flops"] / full["bytes"]


def test_flash_cost_decode_reads_cache_once():
    cfg = get_arch("deepseek-7b")
    c = flash_attention_cost(cfg, SHAPES["decode_32k"], 256, training=False)
    cache = (2 * 128 * cfg.num_kv_heads * 32768 * cfg.head_dim * 2 *
             cfg.num_layers / 256)
    assert c["bytes"] == pytest.approx(cache, rel=0.05)
