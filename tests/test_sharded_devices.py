"""Sharded page bank over REAL (faked) devices: mesh placement and
shard_map local reads need more than one device, so the checks run in a
subprocess that forces ``--xla_force_host_platform_device_count=4``
before importing jax (this process's backend is already initialized and
cannot be re-split).  See ``_sharded_worker.py`` for the checks."""
import json
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "_sharded_worker.py")


@pytest.fixture(scope="module")
def worker_results():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, WORKER], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS_JSON:")]
    assert line, out.stdout + out.stderr[-2000:]
    return json.loads(line[-1][len("RESULTS_JSON:"):])


@pytest.mark.parametrize("check", [
    "bank_placed_over_mesh", "mesh_streams_bitwise",
    "mesh_prefix_bitwise", "local_read_greedy_streams",
    "local_read_chunked_streams"])
def test_sharded_device_check(worker_results, check):
    res = worker_results.get(check)
    assert res is not None, f"check {check} did not run: {worker_results}"
    assert res["ok"], res
