"""Property-based tests (hypothesis) for the reconfiguration scheduler —
the paper's timing model invariants must hold for *arbitrary* schedules."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (hermetic env); "
    "seeded-random policy properties run in test_policy.py")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.scheduler import (
    Run, simulate_conventional, simulate_dynamic, simulate_preloaded,
    time_saving)

nets = st.sampled_from(["n0", "n1", "n2"])
runs = st.lists(
    st.builds(Run, net=nets,
              exec_time=st.floats(0.1, 50.0, allow_nan=False),
              repeat=st.integers(1, 4)),
    min_size=1, max_size=12)
loads = st.fixed_dictionaries({
    "n0": st.floats(0.1, 30.0), "n1": st.floats(0.1, 30.0),
    "n2": st.floats(0.1, 30.0)})


@given(runs, loads)
@settings(max_examples=200, deadline=None)
def test_preloaded_never_slower_and_bounded(schedule, load_time):
    conv = simulate_conventional(schedule, load_time)
    pre = simulate_preloaded(schedule, load_time)
    assert pre <= conv + 1e-9
    s = time_saving(conv, pre)
    assert 0.0 <= s < 1.0          # paper: ideal bound 100 %


@given(runs, loads)
@settings(max_examples=200, deadline=None)
def test_dynamic_between_preloaded_and_conventional(schedule, load_time):
    conv = simulate_conventional(schedule, load_time)
    dyn = simulate_dynamic(schedule, load_time, num_slots=2)
    pre = simulate_preloaded(schedule, load_time)
    assert pre <= dyn + 1e-9 <= conv + 1e-9


@given(runs, loads, st.integers(2, 4))
@settings(max_examples=150, deadline=None)
def test_more_slots_never_hurt(schedule, load_time, slots):
    d2 = simulate_dynamic(schedule, load_time, num_slots=slots)
    d3 = simulate_dynamic(schedule, load_time, num_slots=slots + 1)
    assert d3 <= d2 + 1e-9


@given(loads, st.floats(0.1, 40.0), st.floats(0.1, 40.0),
       st.floats(0.1, 40.0), st.integers(1, 6))
@settings(max_examples=150, deadline=None)
def test_cyclic_three_net_saving_bounded_half(load_time, e0, e1, e2, reps):
    """Paper Fig 6(f): cycling three nets through two slots means every run
    needs a fresh (overlapped) load; the ideal saving bound is 50 %."""
    execs = [e0, e1, e2]
    schedule = [Run(f"n{i % 3}", execs[i % 3]) for i in range(3 * reps)]
    conv = simulate_conventional(schedule, load_time)
    dyn = simulate_dynamic(schedule, load_time, num_slots=2)
    s = time_saving(conv, dyn)
    assert -1e-9 <= s <= 0.5 + 1e-9


@given(runs, loads)
@settings(max_examples=100, deadline=None)
def test_zero_load_time_makes_all_equal(schedule, load_time):
    zero = {k: 0.0 for k in load_time}
    conv = simulate_conventional(schedule, zero)
    dyn = simulate_dynamic(schedule, zero)
    pre = simulate_preloaded(schedule, zero)
    assert abs(conv - dyn) < 1e-9
    assert abs(conv - pre) < 1e-9


def test_paper_case2_exact_numbers():
    """Fig 6(c/d) structure: two preloaded nets, switch ~0: saving equals
    reconfig_fraction of the conventional total."""
    load = {"a": 10.0, "b": 10.0}
    sched = [Run("a", 1.0), Run("b", 1.0)] * 5
    conv = simulate_conventional(sched, load)
    pre = simulate_preloaded(sched, load)
    # conventional: 10 loads (every change) + 10 exec = 110; ours: 10
    assert conv == pytest.approx(110.0)
    assert pre == pytest.approx(10.0)
    assert time_saving(conv, pre) == pytest.approx(100 / 110, rel=1e-6)


def test_dynamic_hides_load_behind_exec():
    """Fig 6(e): load(next) < exec(current) => fully hidden."""
    load = {"a": 2.0, "b": 2.0, "c": 2.0}
    sched = [Run("a", 5.0), Run("b", 5.0), Run("c", 5.0)]
    dyn = simulate_dynamic(sched, load, num_slots=2)
    # first load visible (2) + 3 x 5 exec; b,c loads hidden
    assert dyn == pytest.approx(17.0)
    conv = simulate_conventional(sched, load)
    assert conv == pytest.approx(21.0)
