"""Continuous-batching vs streak-batched serving under mixed traffic.

The streak scheduler (``SwitchScheduler``) already amortizes context
switches, but each coalesced streak runs to completion: a batch pads to
its slowest request, nothing joins mid-decode, and the shadow-slot load
only overlaps whole batches.  The continuous scheduler
(``ContinuousScheduler``) moves the paper's hide-the-load principle down
to token granularity: admission/retirement at every decode step, context
choice re-decided at step boundaries, preload overlapping *steps*.

Workload: a mixed-length, multi-context request stream (short and long
decodes interleaved over 3 models on 2 slots) at temperature > 0 —
production sampling traffic.  That combination is where run-to-completion
batching structurally loses: the streak scheduler cannot stack
temperature>0 requests (stacked rows would share one sampling key and
correlate the draws), so every request pays its own full decode loop,
while the step engine pools them into one fixed-shape batch with
independent per-row draws, retires each row the moment it finishes, and
backfills the freed slot from the queue.

Reported per mode: throughput, p50/p99 request latency, context changes,
loads, hidden-load fraction.  Gates: continuous must beat streak on
throughput AND p99 latency, with hidden-load fraction > 0.
"""
from __future__ import annotations

import time

import numpy as np

MODELS = ["supersub-super", "supersub-sub", "tinyllama-1.1b"]
LOAD_EMU_S = 0.03     # emulated weight-streaming time per context load
POOL = 8              # continuous engine slot-pool size
MAX_LEN = 64
TEMPERATURE = 0.7     # sampling traffic: the streak scheduler can't stack


def _build(names, slots):
    from repro.launch.serve import build_server
    return build_server(names, slots, MAX_LEN, temperature=TEMPERATURE,
                        load_delay_s=LOAD_EMU_S)


def _reset_stats(server):
    for k, v in server.engine.stats.items():
        server.engine.stats[k] = 0 if isinstance(v, int) else 0.0


def mixed_stream(names, cfgs, n_requests, seq, seed):
    """Round-robin contexts with alternating short/long decode lengths —
    the padding worst case for run-to-completion batching."""
    rng = np.random.default_rng(seed)
    for r in range(n_requests):
        name = names[r % len(names)]
        steps = [4, 24, 8, 16][r % 4]
        toks = rng.integers(0, cfgs[name].vocab_size, (2, seq))
        yield name, toks, steps


def _drive(sched, reqs):
    done_at = [0.0] * len(reqs)
    t0 = time.perf_counter()
    futs = []
    for i, (n, t, steps) in enumerate(reqs):
        f = sched.submit(n, t, steps=steps)
        f.add_done_callback(
            lambda _, i=i: done_at.__setitem__(i, time.perf_counter()))
        futs.append(f)
    for i, f in enumerate(futs):
        f.result()
        if done_at[i] == 0.0:        # result() can beat the done-callback
            done_at[i] = time.perf_counter()
    return time.perf_counter() - t0, [d - t0 for d in done_at]


def _run_mode(mode, n_requests, seq, slots, seed):
    from repro.serve.scheduler import ContinuousScheduler, SwitchScheduler
    server, cfgs = _build(MODELS, slots)
    reqs = list(mixed_stream(MODELS, cfgs, n_requests, seq, seed))

    def make():
        if mode == "continuous":
            return ContinuousScheduler(server, batch_size=POOL)
        return SwitchScheduler(server)

    with make() as sched:                    # warm pass: jit + first loads
        _drive(sched, reqs)
    _reset_stats(server)
    with make() as sched:
        wall, lat = _drive(sched, reqs)
        snap = sched.snapshot()
    server.shutdown()
    return wall, lat, snap


def run(n_requests: int = 24, seq: int = 16, slots: int = 2,
        seed: int = 0) -> list[tuple]:
    rows = []
    results = {}
    n_tokens = sum(2 * [4, 24, 8, 16][r % 4] for r in range(n_requests))
    for mode in ("streak", "continuous"):
        wall, lat, snap = _run_mode(mode, n_requests, seq, slots, seed)
        results[mode] = {
            "wall_s": round(wall, 3),
            "req_per_s": round(n_requests / wall, 2),
            "tok_per_s": round(n_tokens / wall, 1),
            "latency_p50_s": round(float(np.percentile(lat, 50)), 4),
            "latency_p99_s": round(float(np.percentile(lat, 99)), 4),
            "context_changes": snap["context_changes"],
            "loads": snap["loads"],
            "hidden_load_fraction": round(snap["hidden_load_fraction"], 3),
        }
        if "steps_per_tick" in snap:
            # step-engine modes report realized host-tick amortization
            # (1.0 at multi_step=1; the fused engine pushes it toward T)
            results[mode]["steps_per_tick"] = snap["steps_per_tick"]
        for k, v in results[mode].items():
            note = (f"{n_requests} mixed-length reqs x {len(MODELS)} models, "
                    f"{slots} slots" if k == "wall_s" else "")
            rows.append((f"serve_{mode}_{k}", v, note))

    c, s = results["continuous"], results["streak"]
    rows.append(("continuous_throughput_beats_streak",
                 int(c["req_per_s"] > s["req_per_s"]),
                 f"{c['req_per_s']} vs {s['req_per_s']} req/s"))
    rows.append(("continuous_p99_beats_streak",
                 int(c["latency_p99_s"] < s["latency_p99_s"]),
                 f"{c['latency_p99_s']} vs {s['latency_p99_s']} s"))
    rows.append(("continuous_hidden_load_fraction_positive",
                 int(c["hidden_load_fraction"] > 0),
                 "switches still hidden at token granularity"))
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(*row, sep=",")
