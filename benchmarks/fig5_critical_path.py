"""Fig 5(c): critical-path delay of SRAM/RRAM/MTJ/FeFET FPGAs over the 7
VTR benchmarks (composition model calibrated to the published deltas)."""
from __future__ import annotations

from repro.core import hwmodel as hw


def run() -> list[tuple]:
    rows = []
    for bench in hw.VTR_BENCHMARKS:
        base = hw.critical_path_ps("sram_1cfg", bench)
        for tech in ("sram_1cfg", "rram_1cfg", "mtj_1cfg", "fefet_1cfg",
                     "fefet_2cfg"):
            t = hw.critical_path_ps(tech, bench)
            rows.append((f"fig5c_{bench}_{tech}_ps", round(t, 1),
                         f"delta={100 * (t - base) / base:+.1f}%"))
    for tech, claim in hw.CRITICAL_PATH_CLAIMS.items():
        got = hw.critical_path_delta(tech)
        ok = abs(got - claim) < 0.02
        rows.append((f"fig5c_avg_delta_{tech}", round(got, 4),
                     f"claim={claim:+.3f} {'OK' if ok else 'MISS'}"))
    return rows
