"""Prefix cache: N requests sharing one long prompt prefix, fixed HBM.

The serving pattern this targets is system-prompt traffic: every request
opens with the same ~2k-token preamble and diverges only in a short
user-specific suffix.  Cold, each admission prefills the full prompt and
holds its own pages for it; with ``prefix_cache=True`` the first
admission indexes its fully-written prompt pages, and every later
request maps them read-only (refcounted; copy-on-write on the boundary
page) and prefills *only its divergent suffix* — attention cost
``O(suffix * S)`` instead of ``O(S^2)``, page cost ``owned`` instead of
``pages_needed(S)``.

Three measurements plus the correctness gate, all at one page budget:

  * ``*_ttft_s`` — time from ``admit`` to the first sampled token, best
    of 3 (compiles warmed).  Gate: hit TTFT < 0.35x cold TTFT.
  * ``*_peak_concurrency`` — admit-greedy drive of shared-prefix
    requests at a page budget sized for ~2 cold requests.  Gate: the
    prefix engine admits strictly more than cold (the shared pages are
    paid once, not per request).
  * ``*_decode_tok_per_s`` — steady-state decode with the feature on vs
    off (same shapes; the decode path is untouched — only admission
    bookkeeping differs).  Gate: within 10%.
  * ``prefix_stream_identical`` — the hit stream is bitwise-identical
    to the cold stream for the same request (greedy; the engine's
    headline invariant, asserted exhaustively in
    ``tests/test_prefix_cache.py``).

The emitted ``BENCH_bench_prefix.json`` also carries the engine's
``prefix_hits`` / ``prefix_pages_mapped`` / ``cow_copies`` /
``cache_evictions`` counters so the sharing actually realized is
visible in the perf trajectory, and CI's bench-smoke job asserts every
gate.
"""
from __future__ import annotations

import time

import numpy as np

PAGE = 256
PREFIX = 2048                    # 8 exact pages shared by every request
SUFFIX = 32                      # per-request divergent tail
STEPS = 16
MAX_LEN = 2304                   # 9 pages: prompt + steps headroom
N_REQS = 10
CONC_PAGES = 2 * (MAX_LEN // PAGE) + 1   # budget: ~2 cold requests
DECODE_STEPS = 32


def _build(**extra):
    import jax
    from repro.configs import get_arch, reduced
    from repro.models.model import build_model
    cfg = reduced(get_arch("tinyllama-1.1b"), **extra)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.key(0))


def _requests(cfg, n=N_REQS, seed=0):
    """n prompts: one shared PREFIX-token preamble + unique suffixes."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (1, PREFIX))
    return [np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, (1, SUFFIX))], axis=1)
        for _ in range(n)]


def _ttft(eng, p, toks, passes=3):
    """admit -> first token wall time, best of ``passes`` (a fresh
    engine reset per pass; the donor request that populates the cache is
    admitted outside the timed region)."""
    import jax
    best = float("inf")
    for _ in range(passes):
        jax.block_until_ready(eng.state.tok)
        t0 = time.perf_counter()
        gens = eng.admit(p, toks, max_new=STEPS)
        jax.block_until_ready(eng.state.tok)
        assert gens[0].tokens                # first token sampled at admit
        best = min(best, time.perf_counter() - t0)
        for g in eng.drain(p):
            pass
    return best


def _peak_concurrency(eng, p, reqs):
    queue = [(t, STEPS) for t in reqs]
    peak = 0
    while queue or eng.live_slots():
        while queue and eng.can_admit(queue[0][0], queue[0][1]):
            toks, steps = queue.pop(0)
            eng.admit(p, toks, max_new=steps)
        peak = max(peak, eng.live_slots())
        if eng.live_slots():
            eng.step(p)
    return peak


def _decode_pass(eng, p, toks):
    import jax
    eng.reset()
    eng.admit(p, toks, max_new=DECODE_STEPS)
    jax.block_until_ready(eng.state.tok)
    b = toks.shape[0]
    t0 = time.perf_counter()
    n = 0
    while eng.live_slots():
        eng.step(p)
        n += b
    jax.block_until_ready(eng.state.tok)
    return n / (time.perf_counter() - t0)


def run() -> list[tuple]:
    from repro.serve.engine import StepEngine
    cfg, m, p = _build()
    reqs = _requests(cfg)

    # --- TTFT: cold full-prompt prefill vs suffix-only hit prefill ----
    cold = StepEngine(m, batch_size=2, max_len=MAX_LEN, paged=True,
                      page_size=PAGE)
    hot = StepEngine(m, batch_size=2, max_len=MAX_LEN, paged=True,
                     page_size=PAGE, prefix_cache=True)
    for g in hot.admit(p, reqs[0], max_new=STEPS):
        pass
    hot.drain(p)                           # donor populates the index
    # warm every compile outside the timed region (cold S=2080 program,
    # hit suffix program, decode step)
    _ttft(cold, p, reqs[1], passes=1)
    _ttft(hot, p, reqs[1], passes=1)
    ttft_cold = _ttft(cold, p, reqs[2])
    ttft_hot = _ttft(hot, p, reqs[2])
    ratio_ttft = ttft_hot / ttft_cold if ttft_cold else 1.0

    # --- bitwise gate: hit stream == cold stream ----------------------
    cold.reset()
    cold.admit(p, reqs[3], max_new=STEPS)
    ref = cold.drain(p)[0].tokens
    hot.admit(p, reqs[3], max_new=STEPS)
    out = hot.drain(p)[0].tokens
    identical = int(out == ref)
    hot_stats = dict(hot.stats)

    # --- concurrency at a ~2-cold-request page budget -----------------
    conc_cold = StepEngine(m, batch_size=N_REQS, max_len=MAX_LEN,
                           paged=True, page_size=PAGE,
                           num_pages=CONC_PAGES)
    conc_hot = StepEngine(m, batch_size=N_REQS, max_len=MAX_LEN,
                          paged=True, page_size=PAGE,
                          num_pages=CONC_PAGES, prefix_cache=True)
    peak_cold = _peak_concurrency(conc_cold, p, reqs)
    peak_hot = _peak_concurrency(conc_hot, p, reqs)

    # --- decode throughput parity (feature on vs off) -----------------
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, (4, SUFFIX))
    d_cold = StepEngine(m, batch_size=4, max_len=512, paged=True,
                        page_size=64)
    d_hot = StepEngine(m, batch_size=4, max_len=512, paged=True,
                       page_size=64, prefix_cache=True)
    for eng in (d_cold, d_hot):
        _decode_pass(eng, p, toks)         # warm pass
    tps_cold = tps_hot = 0.0
    for _ in range(5):                     # interleaved best-of-5
        tps_cold = max(tps_cold, _decode_pass(d_cold, p, toks))
        tps_hot = max(tps_hot, _decode_pass(d_hot, p, toks))
    ratio_tps = tps_hot / tps_cold if tps_cold else 0.0

    note = (f"{PREFIX}t shared prefix + {SUFFIX}t suffix, page {PAGE}, "
            f"{N_REQS} requests")
    rows = [
        ("cold_ttft_s", round(ttft_cold, 4), f"full {PREFIX + SUFFIX}t "
         "prefill, best of 3"),
        ("hit_ttft_s", round(ttft_hot, 4), f"suffix-only prefill, "
         f"ratio {ratio_ttft:.3f}"),
        ("cold_peak_concurrency", peak_cold,
         f"{CONC_PAGES - 1} allocatable pages"),
        ("hit_peak_concurrency", peak_hot, note),
        ("cold_decode_tok_per_s", round(tps_cold, 1), ""),
        ("hit_decode_tok_per_s", round(tps_hot, 1),
         f"prefix_cache on, ratio {ratio_tps:.3f}"),
        ("prefix_hits", hot_stats["prefix_hits"],
         "TTFT engine counters"),
        ("prefix_pages_mapped", hot_stats["prefix_pages_mapped"], ""),
        ("cow_copies", hot_stats["cow_copies"], ""),
        ("cache_evictions", hot_stats["cache_evictions"], ""),
        ("prefix_ttft_speedup", int(ratio_ttft < 0.35),
         f"hit/cold TTFT {ratio_ttft:.3f} (gate < 0.35)"),
        ("prefix_concurrency_gain", int(peak_hot > peak_cold),
         f"{peak_hot} vs {peak_cold} admitted at equal memory"),
        ("prefix_decode_within_10pct", int(ratio_tps >= 0.9),
         f"on/off decode tok/s ratio {ratio_tps:.3f}"),
        ("prefix_stream_identical", identical,
         "hit stream bitwise == cold stream (greedy)"),
    ]
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(*row, sep=",")
